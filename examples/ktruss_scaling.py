"""Fig. 2 analog: fine-over-coarse speedup tracks the imbalance statistic.

Sweeps graph families (uniform grid → heavy-tail) and prints measured
speedup next to the W/avg-degree prediction — the mechanism behind the
paper's graph-dependent speedups (roadNet ≈ 1×, soc-* ≫ 1×).

    PYTHONPATH=src python examples/ktruss_scaling.py
"""

import time

import jax

from repro.core import KTrussEngine
from repro.graphs import barabasi, erdos, imbalance_stats, rmat, road


def support_ms(engine) -> float:
    fn = jax.jit(engine.support)
    alive = engine.initial_alive()
    fn(alive).block_until_ready()
    t0 = time.perf_counter()
    fn(alive).block_until_ready()
    return (time.perf_counter() - t0) * 1e3


def main() -> None:
    graphs = [
        road(48, 0.08, seed=1),  # uniform degree (roadNet regime)
        erdos(3_000, 8.0, seed=2),  # near-uniform (p2p regime)
        barabasi(3_000, 4, seed=3),  # heavy tail (oregon regime)
        rmat(11, 6, seed=4),  # heavier tail (soc-* regime)
    ]
    print(f"{'graph':>14} {'maxdeg':>7} {'pred W/avg':>10} {'coarse ms':>10} "
          f"{'fine ms':>8} {'speedup':>8}")
    for g in graphs:
        st = imbalance_stats(g)
        pred = g.max_degree() / max(g.nnz / g.n, 1e-9)
        c = support_ms(KTrussEngine(g, granularity="coarse"))
        f = support_ms(KTrussEngine(g, granularity="fine"))
        print(
            f"{g.name:>14} {g.max_degree():>7} {pred:>10.1f} {c:>10.1f} "
            f"{f:>8.1f} {c/f:>7.1f}x"
        )
    print("\nspeedup grows with the imbalance statistic — the paper's Fig. 2/3.")


if __name__ == "__main__":
    main()
