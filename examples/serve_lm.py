"""Batched serving example: prefill + decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import run_serving


def main() -> None:
    out = run_serving(
        arch="qwen2-0.5b",
        smoke=True,
        batch=8,
        prompt_len=32,
        max_new=48,
        temperature=0.7,
    )
    print(f"generated tokens: {out['tokens'].shape}")
    print(f"prefill: {out['prefill_s']*1e3:.1f} ms")
    print(f"decode throughput: {out['decode_tok_s']:.1f} tok/s (batch total)")
    print("first two rows:\n", out["tokens"][:2])


if __name__ == "__main__":
    main()
