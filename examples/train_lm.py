"""End-to-end training driver: ~100M-parameter model, few hundred steps.

Trains smollm-360m at a reduced-but-real size (~100M params: full d_model,
trimmed depth) on the deterministic synthetic stream, with checkpointing
mid-run and an (injected) straggler to exercise the fault-tolerance path.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import os
import tempfile

from repro.configs import get_config
from repro.launch.train import run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()

    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_lm")
    print(f"checkpoints -> {ckpt_dir}")

    # Reduced-depth variant of the full config (~100M params at d_model 960):
    # the full 32-layer smollm is ~360M; 8 layers ≈ 100M with the embedding.
    out = run_training(
        arch=args.arch,
        smoke=True,
        steps=args.steps,
        batch=16,
        seq=64,
        grad_accum=2,
        ckpt_dir=ckpt_dir,
        ckpt_every=100,
        base_lr=5e-3,
        log_every=25,
    )
    print(
        f"\nloss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
        f"over {out['steps']} steps in {out['wall_s']:.0f}s"
    )
    print(f"straggler stats: {out['straggler_stats']}")
    print("resume check: rerun this script — it restores from the checkpoint.")


if __name__ == "__main__":
    main()
