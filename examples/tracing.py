"""Observability in one screen: trace a mixed batch, read the telemetry.

Runs a mixed-workload solve with span tracing on, exports the Chrome
trace-event JSON (open it in Perfetto or ``chrome://tracing``), and
prints the metrics the same run recorded — including the paper's
load-imbalance statistic observed per (bucket, backend) on the real
dispatches.

    PYTHONPATH=src python examples/tracing.py

Tracing can also be forced process-wide without touching code:

    REPRO_TRACE=trace.json PYTHONPATH=src python your_script.py
"""

import json

from repro.api import Session, TrussQuery
from repro.graphs import barabasi, rmat, road
from repro.obs import imbalance_summary


def main() -> None:
    # trace="path" records spans AND auto-exports after solve()/flush().
    s = Session(kernel="xla", max_batch=4, chunk=64, trace="trace.json")
    s.solve(
        [
            TrussQuery.decompose(rmat(6, 6, seed=0)),  # heavy tail -> fine
            TrussQuery.decompose(barabasi(120, 4, seed=1)),
            TrussQuery.decompose(road(8, 0.1, seed=2)),  # balanced -> coarse
            TrussQuery.kmax(rmat(6, 6, seed=3)),
        ]
    )

    # The exported trace: plan -> pack -> compile -> dispatch ->
    # device-wait -> unpack spans, nested under one "solve".
    events = json.load(open("trace.json"))["traceEvents"]
    print(f"wrote trace.json ({len(events)} events)")
    for name in ("solve", "plan", "pack", "compile", "dispatch", "device-wait"):
        ev = next(e for e in events if e["name"] == name)
        print(f"  {name:<12} {ev['dur'] / 1e3:8.2f} ms  args={ev.get('args', {})}")

    # Counters/gauges/histograms for the same run (also available as
    # s.prometheus_text() for scraping).
    snap = s.metrics_snapshot()
    print("\ncounters:")
    for key in ("requests_served", "batches_run", "dispatches", "cache_compiles"):
        print(f"  {key} = {snap['counters'].get(key, 0)}")
    occ = snap["histograms"]["batch_occupancy"]
    print(f"  batch_occupancy mean = {occ['mean']:.2f} over {occ['count']} batches")

    # The paper's max/mean work statistic, measured per (bucket, backend):
    # heavy-tail buckets show spread, balanced ones sit near 1.0.
    print("\nobserved peel imbalance (max/mean slot iterations):")
    for row in imbalance_summary(s.obs.metrics):
        print(
            f"  {row['bucket']:<20} {row['backend']:<20} "
            f"mean={row['mean_imbalance']:<7} slot_iters_max={row['slot_iters_max']}"
        )


if __name__ == "__main__":
    main()
