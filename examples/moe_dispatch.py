"""The paper's decomposition applied to MoE expert routing (beyond-paper).

Shows side by side, under growing router skew:
  * coarse (per-expert capacity buckets = Alg. 2 row tasks): dropped tokens
    grow with skew;
  * fine (flat sorted buffer = Alg. 3 nonzero tasks): dropless.

    PYTHONPATH=src python examples/moe_dispatch.py
"""

from benchmarks.moe_dispatch import run_moe_dispatch


def main() -> None:
    rows = run_moe_dispatch(tokens=2048)
    print(f"{'skew':>6} {'dispatch':>8} {'ms':>8} {'drop%':>7} {'imbalance':>10}")
    for r in rows:
        print(
            f"{r['skew']:>6} {r['dispatch']:>8} {r['ms_per_call']:>8} "
            f"{100*r['drop_frac']:>6.1f}% {r['load_imbalance']:>9}x"
        )
    print(
        "\nfine == the paper's flat nonzero task space; coarse == per-row "
        "buckets.\nSame router, same experts — only the decomposition differs."
    )


if __name__ == "__main__":
    main()
