"""The declarative front door in one screen: TrussQuery -> solve/Session.

Mixed workloads over mixed graph families, lowered through the planner's
backend registry (formulation x kernel x layout, auto-chosen per shape
bucket from the paper's imbalance statistics) onto one device dispatch
per batch.

    PYTHONPATH=src python examples/declarative_queries.py
"""

from repro.api import Session, TrussQuery, solve
from repro.graphs import erdos, rmat, road


def main() -> None:
    # One-shot: a single declarative query, auto-planned.
    g = rmat(8, 5, seed=7)
    dec = solve(TrussQuery.decompose(g), chunk=64, max_batch=1)
    print(f"{g.name}: kmax={dec.kmax} levels={dec.levels}")

    # Serving: one session, mixed workloads, per-bucket backend choice.
    s = Session(kernel="xla", max_batch=4, chunk=64)
    queries = [
        TrussQuery.ktruss(erdos(100, 6.0, seed=0), k=4),
        TrussQuery.kmax(erdos(100, 6.0, seed=1)),
        TrussQuery.decompose(road(8, 0.1, seed=0)),  # balanced -> coarse rows
        TrussQuery.kmax(rmat(6, 4, seed=2)),  # heavy tail -> fine nonzeros
    ]
    results = s.solve(queries)
    print("ktruss(4) edges:", results[0].edges_remaining)
    print("kmax:", results[1], "| road kmax:", results[2].kmax, "| rmat kmax:", results[3])

    st = s.stats()
    print(
        f"dispatches={st['device_dispatches']} "
        f"plan_overhead={st['planner_plan_us_per_query']:.0f}us/query"
    )
    for choice in st["planner_backends"]:
        print(
            f"  bucket {choice['bucket']} -> {choice['backend']} "
            f"({choice['queries']} queries)"
        )


if __name__ == "__main__":
    main()
