"""Streaming K-truss demo: maintain a decomposition under live edge updates.

Opens a :class:`repro.stream.StreamingTrussSession` on a planted-community
graph, then feeds it insert/delete batches.  Each update re-peels only the
affected-edge frontier (one device dispatch — or zero when the update
touches no triangles at a relevant level), and the maintained trussness is
bit-identical to a from-scratch ``decompose()`` of the mutated graph,
which the demo verifies at every step.

Run:  PYTHONPATH=src python examples/streaming_updates.py
"""

import numpy as np

from repro.core import KTrussEngine
from repro.graphs import clustered
from repro.service import TrussService
from repro.stream import EdgeBatch


def random_batch(rng, g, n_ins, n_del):
    existing = set(map(tuple, (g.edge_list() - 1)))
    ins = []
    while len(ins) < n_ins:
        a, b = int(rng.integers(0, g.n)), int(rng.integers(0, g.n))
        if a != b and (min(a, b), max(a, b)) not in existing:
            ins.append((a, b))
            existing.add((min(a, b), max(a, b)))
    eids = rng.permutation(g.nnz)[:n_del]
    return EdgeBatch.of(ins, [tuple(e - 1) for e in g.edge_list()[eids]])


def main():
    rng = np.random.default_rng(0)
    g = clustered(4, 16, 0.6, seed=3)
    svc = TrussService(max_batch=2, chunk=64)
    sess = svc.open_stream(g)  # initial full decompose via the batched path
    print(f"opened stream: {g.nnz} edges, kmax={sess.kmax}")

    for step in range(8):
        res = sess.update(random_batch(rng, sess.graph, n_ins=2, n_del=1))
        ref = KTrussEngine(sess.graph, chunk=64).decompose().trussness
        assert np.array_equal(res.trussness, ref), "incremental != from-scratch"
        print(
            f"step {step}: +{res.num_inserts}/-{res.num_deletes} edges -> "
            f"frontier {res.frontier_size}/{res.num_edges} "
            f"({100 * res.frontier_frac:.1f}%), {res.dispatches} dispatch(es), "
            f"kmax={res.kmax}  [exact ✓]"
        )

    print("session:", sess.stats())
    print("service:", svc.stats())


if __name__ == "__main__":
    main()
