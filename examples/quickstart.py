"""Quickstart: the paper's algorithm in five minutes.

Builds a power-law graph, runs Eager K-truss with the coarse (Alg. 2) and
fine-grained (Alg. 3, the paper's contribution) decompositions plus the
Pallas kernel backend, checks they agree, and prints the ME/s comparison —
the paper's Table I in miniature.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro.core import KTrussEngine
from repro.graphs import imbalance_stats, rmat


def main() -> None:
    g = rmat(10, 6, seed=7)
    st = imbalance_stats(g)
    print(f"graph: {g.name}  |V|={g.n} |E|={g.nnz} max_deg={g.max_degree()}")
    print(
        f"coarse-task imbalance {st.coarse_imbalance:.0f}x vs fine "
        f"{st.fine_imbalance:.1f}x  (the paper's §III-A premise)\n"
    )

    results = {}
    for gran, mode, backend in [
        ("coarse", "eager", "xla"),
        ("fine", "eager", "xla"),
        ("fine", "owner", "xla"),
        ("fine", "owner", "pallas"),
    ]:
        eng = KTrussEngine(g, granularity=gran, mode=mode, backend=backend)
        res = eng.ktruss(k=4)
        fn = jax.jit(eng.support)
        alive = eng.initial_alive()
        fn(alive).block_until_ready()
        t0 = time.perf_counter()
        fn(alive).block_until_ready()
        dt = time.perf_counter() - t0
        tag = f"{gran}/{mode}/{backend}"
        results[tag] = res.alive
        print(
            f"{tag:24s} support: {dt*1e3:8.1f} ms  "
            f"({g.nnz/dt/1e6:6.2f} ME/s)   4-truss edges: {res.edges_remaining}"
        )

    base = results["fine/eager/xla"]
    assert all(np.array_equal(base, a) for a in results.values())
    print("\nall decompositions agree ✓  (the paper's Table I, in miniature)")


if __name__ == "__main__":
    main()
