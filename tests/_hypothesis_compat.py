"""Guarded ``hypothesis`` import with a deterministic example-based fallback.

The property tests prefer the real hypothesis engine (shrinking, example
databases, coverage-guided generation).  When it is not installed — the
bare container only ships jax/numpy/pytest — the same test code runs
against a tiny deterministic re-implementation of the strategy surface the
suite actually uses (``integers``, ``lists``, ``tuples``, ``sampled_from``,
``data``): each
``@given`` test executes ``max_examples`` seeded draws, so property tests
degrade to example-based tests instead of erroring at import time.

Usage in test modules::

    from _hypothesis_compat import given, settings, st

``requirements-dev.txt`` lists the real dependency for dev machines/CI.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:


    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A strategy is just a seeded-draw function."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _DataObject:
        """Stand-in for hypothesis's interactive ``data()`` draws."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.example(self._rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(size)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(
                lambda rng: tuple(e.example(rng) for e in elements)
            )

        @staticmethod
        def sampled_from(elements):
            pool = list(elements)
            return _Strategy(lambda rng: pool[int(rng.integers(len(pool)))])

        @staticmethod
        def data():
            return _Strategy(lambda rng: _DataObject(rng))

    st = _Strategies()

    def settings(**kwargs):
        def deco(fn):
            fn._compat_settings = kwargs
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            max_examples = getattr(fn, "_compat_settings", {}).get(
                "max_examples", 20
            )

            # Deliberately NOT functools.wraps: the wrapper must present a
            # zero-parameter signature so pytest does not mistake the
            # strategy keywords for fixtures.
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(max_examples):
                    drawn = {
                        k: s.example(rng) for k, s in strategy_kwargs.items()
                    }
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
