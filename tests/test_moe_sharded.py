"""Sharded (shard_map EP, tiled grouped GEMM) MoE vs local path.

Runs in a subprocess so the 8-device host-platform override never leaks
into the rest of the suite (tests must see 1 device).
"""

import json
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.config import MoEConfig
from repro.models.moe import moe_apply, moe_init
from repro.distributed.context import sharding_context

base = get_config("kimi-k2-1t-a32b", smoke=True)
out = {}
for disp in ("fine", "coarse"):
    cfg = base.replace(d_model=64, moe=MoEConfig(
        num_experts=8, top_k=2, d_ff_expert=32, dispatch=disp,
        buffer_factor=4.0, capacity_factor=8.0))
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, 64)), jnp.float32)
    y_local, _ = moe_apply(p, x, cfg)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with sharding_context(mesh):
        y_shard, aux = jax.jit(lambda pp, xx: moe_apply(pp, xx, cfg))(p, x)
    out[disp] = {
        "err": float(jnp.max(jnp.abs(y_local.astype(jnp.float32) - y_shard.astype(jnp.float32)))),
        "drop": float(aux["moe_drop_frac"]),
    }
print("RESULT " + __import__("json").dumps(out))
"""


def test_sharded_moe_matches_local():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".",
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    for disp in ("fine", "coarse"):
        assert out[disp]["err"] < 2e-2, (disp, out)
        assert out[disp]["drop"] == 0.0, (disp, out)
