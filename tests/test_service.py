"""Serving subsystem: batched results == per-graph engine, cache counters,
decompose vs ktruss sweeps, packing, and bucketed-window coverage."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    KTrussEngine,
    bucket_tasks,
    prepare_fine,
    support_fine_bucketed,
    support_fine_eager,
    support_fine_stacked,
    support_numpy,
)
from repro.graphs import (
    barabasi,
    clustered,
    erdos,
    pack_problems,
    rmat,
    road,
    stack_problems,
)
from repro.service import TrussService, bucket_for


def _stream():
    """20 small graphs spanning every generator-suite family."""
    out = []
    for s in range(4):
        out += [
            erdos(100, 6.0, seed=s),
            barabasi(120, 3, seed=s),
            clustered(3, 16, 0.6, seed=s),
            road(10, 0.1, seed=s),
            rmat(6, 4, seed=s),
        ]
    return out


# ------------------------------------------------------------------ #
# (a) Batched service == per-graph engine across the generator suite
# ------------------------------------------------------------------ #
def test_service_matches_engine_across_suite():
    graphs = _stream()
    svc = TrussService(max_batch=4, chunk=64)
    futs = []
    for i, g in enumerate(graphs):
        if i % 10 == 3:
            futs.append(("kmax", g, svc.submit_kmax(g)))
        elif i % 10 == 7:
            futs.append(("decompose", g, svc.submit_decompose(g)))
        else:
            k = 3 + (i % 2)
            futs.append((f"ktruss{k}", g, svc.submit_ktruss(g, k)))
    svc.flush()

    for label, g, fut in futs:
        eng = KTrussEngine(g, chunk=64)
        if label == "kmax":
            assert fut.result() == eng.kmax()
        elif label == "decompose":
            dec = fut.result()
            edec = eng.decompose()
            assert np.array_equal(dec.trussness, edec.trussness)
            assert dec.kmax == edec.kmax
        else:
            k = int(label[-1])
            res = fut.result()
            ref = eng.ktruss(k)
            assert np.array_equal(res.alive, ref.alive), g.name
            assert np.array_equal(res.support, ref.support), g.name
            assert res.edges_remaining == ref.edges_remaining

    # Steady-state traffic: a second wave of the same mix must be served
    # entirely from the compile cache, pushing the hit rate above 1/2.
    for g in graphs:
        svc.submit_ktruss(g, 3)
    for g in graphs:
        svc.submit_ktruss(g, 4)
    svc.flush()
    st = svc.stats()
    assert st["pending"] == 0
    assert st["cache_hit_rate"] > 0.5, st


# ------------------------------------------------------------------ #
# (b) Compile cache compiles exactly once per bucket
# ------------------------------------------------------------------ #
def test_cache_compiles_once_per_bucket():
    g1 = erdos(80, 5.0, seed=0)
    g2 = road(8, 0.1, seed=1)  # different bucket (tiny window)
    assert bucket_for(g1, chunk=64) != bucket_for(g2, chunk=64)
    svc = TrussService(max_batch=1, chunk=64)
    for _ in range(3):
        svc.submit_ktruss(g1, 3)
    svc.submit_ktruss(g2, 3)
    svc.flush()
    assert svc.cache.stats.compiles == 2  # one per distinct bucket
    assert svc.cache.stats.hits == 2  # the two repeats of g1's bucket
    assert len(svc.cache) == 2
    # Same buckets again: no new compiles.
    svc.submit_ktruss(g1, 5)
    svc.submit_kmax(g2)
    svc.flush()
    assert svc.cache.stats.compiles == 2
    assert svc.cache.stats.hits == 4


def test_request_stats_populated():
    g = erdos(60, 5.0, seed=3)
    svc = TrussService(max_batch=2, chunk=64)
    f1 = svc.submit_ktruss(g, 3)
    f2 = svc.submit_ktruss(g, 3)
    f1.result()
    s1, s2 = f1.stats, f2.stats
    assert s1.batch_size == 2 and s2.batch_size == 2
    assert s1.bucket == bucket_for(g, chunk=64)
    assert not s1.compile_hit  # first batch for this bucket compiles
    assert s1.device_time_s > 0 and s1.queue_time_s >= 0
    assert s1.rounds >= 1


# ------------------------------------------------------------------ #
# (c) decompose() == repeated ktruss(k) sweeps
# ------------------------------------------------------------------ #
@pytest.mark.parametrize(
    "g",
    [clustered(3, 12, 0.8, seed=0), erdos(70, 7.0, seed=1), barabasi(80, 3, seed=2)],
    ids=["clustered", "er", "ba"],
)
def test_decompose_matches_ktruss_sweeps(g):
    eng = KTrussEngine(g, chunk=64)
    dec = eng.decompose()
    # trussness[e] = 2 + #{k >= 3 : e in the k-truss} by truss nesting.
    expect = np.full(g.nnz, 2, np.int64)
    k = 3
    while True:
        res = eng.ktruss(k)  # cold start each k: independent of the peel
        if not res.edges_remaining:
            break
        expect += res.alive
        k += 1
    assert np.array_equal(dec.trussness, expect)
    assert dec.kmax == int(expect.max(initial=0))


def test_decompose_trussless_graph():
    g = road(6, 0.0, seed=0)  # pure grid: no triangles at all
    dec = KTrussEngine(g, chunk=64).decompose()
    assert np.all(dec.trussness == 2)
    assert dec.kmax == 2  # the 2-truss is the graph itself


# ------------------------------------------------------------------ #
# Block-diagonal packing + stacked batched entry points
# ------------------------------------------------------------------ #
def test_pack_problems_supports_match_members():
    gs = [erdos(50, 6.0, seed=0), clustered(2, 14, 0.7, seed=1), road(6, 0.2, seed=2)]
    w = max(
        8, -(-max(int(g.undirected_csr().max_degree()) for g in gs) // 8) * 8
    )
    pp = pack_problems(gs, slot_n=64, slot_nnz=256, slots=4, chunk=64)
    assert pp.problem.nnz_pad == 4 * 256
    assert pp.problem.rowptr.shape[0] == 4 * 64 + 1
    alive = jnp.asarray(pp.problem.colidx != 0)
    s = np.asarray(support_fine_eager(pp.problem, alive, window=w, chunk=64))
    for g, (a, b) in zip(gs, pp.edge_ranges):
        assert np.array_equal(s[a:b], support_numpy(g)), g.name
    # Edges outside every member's range are padding.
    ends = max(b for _, b in pp.edge_ranges)
    assert not np.any(s[ends:])


def test_stacked_entry_matches_single():
    gs = [erdos(60, 6.0, seed=0), erdos(60, 7.0, seed=5)]
    w = max(8, -(-max(int(g.undirected_csr().max_degree()) for g in gs) // 8) * 8)
    ps = [prepare_fine(g, chunk=64, nnz_pad=256, unnz_pad=512) for g in gs]
    sp = stack_problems(ps)
    alive = jnp.stack([jnp.asarray(p.colidx != 0) for p in ps])
    for mode in ("eager", "owner"):
        out = np.asarray(
            support_fine_stacked(sp, alive, window=w, chunk=64, mode=mode)
        )
        for i, g in enumerate(gs):
            assert np.array_equal(out[i][: g.nnz], support_numpy(g)), mode


def test_pack_validates_capacity():
    g = erdos(50, 6.0, seed=0)
    with pytest.raises(ValueError):
        pack_problems([g], slot_n=16, slot_nnz=256, chunk=64)  # n > slot_n
    with pytest.raises(ValueError):
        pack_problems([g], slot_n=64, slot_nnz=64, chunk=64)  # nnz > capacity


# ------------------------------------------------------------------ #
# bucket_tasks / support_fine_bucketed direct coverage
# ------------------------------------------------------------------ #
def test_bucket_tasks_partition_every_edge():
    g = barabasi(150, 4, seed=7)
    buckets = bucket_tasks(g, chunk=64)
    seen = np.concatenate([ids[ids < g.nnz] for _, ids in buckets])
    assert len(seen) == g.nnz
    assert np.array_equal(np.sort(seen), np.arange(g.nnz))
    deg = g.degrees()
    rows, pos = g.row_of_edge(), g.pos_in_row()
    need = np.maximum(deg[rows] - pos - 1, deg[g.colidx])
    for wb, ids in buckets:
        assert wb & (wb - 1) == 0 and wb >= 8  # power-of-two windows
        assert len(ids) % 64 == 0  # chunk-padded
        real = ids[ids < g.nnz]
        assert np.all(need[real] <= wb)


def test_support_fine_bucketed_matches_eager_on_pruned_mask():
    g = rmat(7, 4, seed=3)
    p = prepare_fine(g, chunk=64)
    rng = np.random.default_rng(0)
    alive_np = (rng.random(p.nnz_pad) < 0.8) & (np.asarray(p.colidx) != 0)
    alive = jnp.asarray(alive_np)
    buckets = [(wb, jnp.asarray(ids)) for wb, ids in bucket_tasks(g, chunk=64)]
    s_b = np.asarray(support_fine_bucketed(p, alive, buckets, chunk=64))
    w = max(8, -(-g.max_degree() // 8) * 8)
    s_e = np.asarray(support_fine_eager(p, alive, window=w, chunk=64))
    assert np.array_equal(s_b, s_e)


# ------------------------------------------------------------------ #
# prepare_fine explicit padding targets
# ------------------------------------------------------------------ #
def test_prepare_fine_explicit_pads():
    g = erdos(40, 5.0, seed=0)
    p = prepare_fine(g, chunk=64, nnz_pad=512, unnz_pad=1024)
    assert p.nnz_pad == 512 and p.ucolidx.shape[0] == 1024
    with pytest.raises(ValueError):
        prepare_fine(g, chunk=64, nnz_pad=g.nnz - 1)
    with pytest.raises(ValueError):
        prepare_fine(g, chunk=64, nnz_pad=512, unnz_pad=8)
