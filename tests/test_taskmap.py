"""Flat-task index math (shared by K-truss and MoE dispatch)."""

import numpy as np
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import batched_searchsorted, row_of_task, segment_offsets, window_gather


@given(
    rows=st.lists(st.integers(0, 6), min_size=1, max_size=20),
)
@settings(max_examples=50, deadline=None)
def test_row_of_task_inverts_rowptr(rows):
    rowptr = np.concatenate([[0], np.cumsum(rows)]).astype(np.int32)
    nnz = int(rowptr[-1])
    if nnz == 0:
        return
    t = jnp.arange(nnz, dtype=jnp.int32)
    got = np.asarray(row_of_task(jnp.asarray(rowptr), t))
    want = np.searchsorted(rowptr, np.arange(nnz), side="right")
    assert np.array_equal(got, want)
    # Every task's row contains it: rowptr[r-1] <= t < rowptr[r].
    assert np.all(rowptr[got - 1] <= np.arange(nnz))
    assert np.all(np.arange(nnz) < rowptr[got])


@given(
    data=st.data(),
    e=st.integers(1, 8),
    w=st.integers(1, 33),
    q=st.integers(1, 17),
)
@settings(max_examples=60, deadline=None)
def test_batched_searchsorted_matches_numpy(data, e, w, q):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    b = np.sort(rng.integers(0, 50, size=(e, w)), axis=1).astype(np.int32)
    queries = rng.integers(-5, 55, size=(e, q)).astype(np.int32)
    got = np.asarray(batched_searchsorted(jnp.asarray(b), jnp.asarray(queries)))
    want = np.stack([np.searchsorted(b[i], queries[i]) for i in range(e)])
    assert np.array_equal(got, want)


def test_segment_offsets_roundtrip():
    ids = jnp.asarray(np.repeat(np.arange(5), [3, 0, 2, 4, 1]).astype(np.int32))
    offs = np.asarray(segment_offsets(ids, 5))
    assert np.array_equal(np.diff(offs), [3, 0, 2, 4, 1])


def test_window_gather_bounds():
    flat = jnp.arange(10, dtype=jnp.int32)
    out = np.asarray(window_gather(flat, jnp.asarray([-2, 8]), 4, fill=-1))
    assert np.array_equal(out[0], [-1, -1, 0, 1])
    assert np.array_equal(out[1], [8, 9, -1, -1])
