"""repro.api: the declarative front door + planner/backend registry.

Pins the PR-level contracts:

* every registered (formulation × kernel × layout) backend returns
  bit-identical trussness on R-MAT and paper-style skewed graphs;
* the public surface (``repro.api.__all__``) is snapshot-locked so
  accidental breakage fails CI;
* a mixed ktruss/kmax/decompose/stream query set resolves in ONE device
  dispatch through ``Session.solve()``;
* ``TrussFuture.result(timeout=...)`` raises a named
  ``TrussTimeoutError`` carrying the bucket and queue depth;
* the auto rule picks formulations from the paper's imbalance stats.
"""

import numpy as np
import pytest

import repro.api as api
from repro.api import (
    BackendKey,
    Session,
    TrussQuery,
    TrussTimeoutError,
    available_backends,
    bucket_for,
    choose_backend,
    solve,
)
from repro.core import KTrussResult, TrussDecomposition, trussness_numpy
from repro.graphs import barabasi, erdos, imbalance_stats, rmat, road


def _same_bucket(factory, count, *, chunk=64, tries=64):
    groups = {}
    for s in range(tries):
        g = factory(s)
        groups.setdefault(bucket_for(g, chunk=chunk), []).append(g)
        if len(groups[bucket_for(g, chunk=chunk)]) == count:
            return groups[bucket_for(g, chunk=chunk)]
    raise AssertionError(f"no bucket reached {count} graphs in {tries} tries")


# ------------------------------------------------------------------ #
# (a) Registry parity: every backend, bit-identical trussness
# ------------------------------------------------------------------ #
@pytest.mark.parametrize(
    "backend", available_backends(), ids=[str(k) for k in available_backends()]
)
def test_backend_parity_bit_identical(backend):
    """R-MAT (the paper's heavy-tail regime) + Barabási (power-law) must
    decompose identically on every registered backend — the formulation /
    kernel / layout axes are performance choices, never semantic ones."""
    for g in [rmat(6, 4, seed=2), barabasi(70, 3, seed=0)]:
        dec = solve(
            TrussQuery.decompose(g), backend=backend, chunk=64, max_batch=2
        )
        assert isinstance(dec, TrussDecomposition)
        oracle = trussness_numpy(g)
        assert np.array_equal(dec.trussness, oracle), (str(backend), g.name)
        assert dec.kmax == int(oracle.max(initial=0))


@pytest.mark.parametrize(
    "backend", available_backends(), ids=[str(k) for k in available_backends()]
)
def test_warm_path_compiles_nothing(backend):
    """The runtime half of the R2 recompile lint: an identical query mix
    re-solved on a live session must hit the compile cache exactly — zero
    XLA compilations on the warm pass.  A failure here means some
    attribute the executor builder closes over leaked out of the
    compile-cache variant key (see ``Planner.cache_variant``)."""
    from repro.analysis.sentinel import assert_no_compiles

    s = Session(backend=backend, chunk=64, max_batch=2)
    gs = [rmat(6, 4, seed=3), erdos(40, 3.0, seed=1)]

    def mix():
        return s.solve([TrussQuery.decompose(g) for g in gs])

    cold = mix()
    with assert_no_compiles(f"warm solve on {backend}"):
        warm = mix()
    for c, w in zip(cold, warm):
        assert np.array_equal(c.trussness, w.trussness)


# ------------------------------------------------------------------ #
# (b) API surface snapshot
# ------------------------------------------------------------------ #
def test_api_surface_snapshot():
    """The public surface is part of the contract: additions are deliberate
    (update this snapshot), removals/renames fail CI."""
    assert sorted(api.__all__) == sorted(
        [
            "TrussQuery",
            "WORKLOADS",
            "PLACEMENTS",
            "solve",
            "Session",
            "TrussFuture",
            "TrussError",
            "InvalidGraphError",
            "CompileError",
            "DeviceError",
            "QueryFailedError",
            "TrussTimeoutError",
            "CheckpointError",
            "Planner",
            "Plan",
            "PlannedBatch",
            "QueryState",
            "QueryQueue",
            "RequestStats",
            "BackendKey",
            "BackendSpec",
            "FORMULATIONS",
            "KERNELS",
            "LAYOUTS",
            "register_backend",
            "get_backend",
            "available_backends",
            "choose_backend",
            "default_kernel",
            "fallback_backends",
            "Bucket",
            "bucket_for",
            "build_peel",
            "CompileCache",
            "enable_persistent_cache",
            "KTrussResult",
            "TrussDecomposition",
        ]
    )
    for name in api.__all__:
        assert hasattr(api, name), name


def test_default_backends_registered():
    keys = available_backends()
    # coarse×pallas/fused is invalid (the hand kernels are fine-only) and
    # fused×contig is invalid (the megakernel tiles the aligned layout's
    # slot bands); every other point of the grid is registered for both
    # layouts.
    assert len(keys) == 7
    assert BackendKey("fine", "xla", "aligned") in keys
    assert BackendKey("coarse", "xla", "contig") in keys
    assert BackendKey("fine", "fused", "aligned") in keys
    assert BackendKey("fine", "fused", "contig") not in keys
    assert BackendKey("coarse", "pallas", "aligned") not in keys
    assert BackendKey("coarse", "fused", "aligned") not in keys


# ------------------------------------------------------------------ #
# (c) Mixed workloads through solve(): ONE dispatch per batch
# ------------------------------------------------------------------ #
def test_mixed_query_set_one_dispatch_via_solve():
    graphs = _same_bucket(lambda s: erdos(80, 6.0, seed=s), 4)
    g_stream = graphs[3]
    ref_stream = trussness_numpy(g_stream)
    s = Session(backend="fine/xla/aligned", max_batch=4, chunk=64)
    results = s.solve(
        [
            TrussQuery.ktruss(graphs[0], k=4),
            TrussQuery.kmax(graphs[1]),
            TrussQuery.decompose(graphs[2]),
            # An all-free frontier re-peel is exactly a decompose.
            TrussQuery.stream_update(
                g_stream,
                frontier=np.ones(g_stream.nnz, bool),
                frozen_truss=np.zeros(g_stream.nnz, np.int32),
            ),
        ]
    )
    st = s.stats()
    assert st["device_dispatches"] == 1, st  # the whole mixed set: once
    assert st["batches_run"] == 1 and st["pending"] == 0

    r_kt, r_km, r_dc, r_st = results
    assert isinstance(r_kt, KTrussResult) and r_kt.k == 4
    assert r_km == int(trussness_numpy(graphs[1]).max(initial=0))
    assert np.array_equal(r_dc.trussness, trussness_numpy(graphs[2]))
    assert np.array_equal(r_st, ref_stream)


def test_different_backends_split_batches():
    """Queries forcing different backends cannot share an executable, so
    they form separate dispatches even inside one bucket."""
    graphs = _same_bucket(lambda s: erdos(80, 6.0, seed=s), 2)
    s = Session(max_batch=4, chunk=64)
    s.solve(
        [
            TrussQuery.kmax(graphs[0], backend="fine/xla/aligned"),
            TrussQuery.kmax(graphs[1], backend="coarse/xla/aligned"),
        ]
    )
    assert s.stats()["device_dispatches"] == 2


# ------------------------------------------------------------------ #
# (d) result(timeout=...) raises the named error with context
# ------------------------------------------------------------------ #
def test_future_timeout_sheds_query_and_reclaims_slot():
    """Timeout marks the query dead (default shed_on_timeout=True): its
    queue slot is reclaimed — no leak — and batch-mates still resolve."""
    graphs = _same_bucket(lambda s: erdos(60, 5.0, seed=s), 2)
    s = Session(backend="fine/xla/aligned", max_batch=1, chunk=64)
    f1 = s.submit(TrussQuery.kmax(graphs[0]))
    f2 = s.submit(TrussQuery.kmax(graphs[1]))
    with pytest.raises(TrussTimeoutError) as ei:
        f2.result(timeout=0)
    err = ei.value
    assert err.bucket == bucket_for(graphs[1], chunk=64)
    assert err.queue_depth == 2  # both queries were still queued
    assert err.request_id is not None
    assert err.shed is True
    assert "queue_depth" in str(err) and isinstance(err, TimeoutError)
    # The dead query's slot was reclaimed; it re-raises, never re-runs.
    assert len(s.queue) == 1
    with pytest.raises(TrussTimeoutError):
        f2.result(timeout=None)
    assert s.stats()["queries_shed"] == 1
    # The batch-mate is unaffected.
    assert f1.result(timeout=None) == int(
        trussness_numpy(graphs[0]).max(initial=0)
    )
    assert s.stats()["pending"] == 0


def test_future_timeout_without_shedding_keeps_query_resolvable():
    """shed_on_timeout=False is the legacy escape hatch: a timed-out query
    stays queued and a later result() still resolves it."""
    g = erdos(60, 5.0, seed=0)
    s = Session(
        backend="fine/xla/aligned", max_batch=1, chunk=64, shed_on_timeout=False
    )
    fut = s.submit(TrussQuery.kmax(g))
    with pytest.raises(TrussTimeoutError) as ei:
        fut.result(timeout=0)
    assert ei.value.shed is False
    assert fut.result(timeout=None) == int(trussness_numpy(g).max(initial=0))
    assert s.stats()["queries_shed"] == 0


def test_deadline_is_default_result_budget():
    g = erdos(60, 5.0, seed=0)
    s = Session(
        backend="fine/xla/aligned", max_batch=1, chunk=64, shed_on_timeout=False
    )
    fut = s.submit(TrussQuery.kmax(g, deadline_s=0.0))
    with pytest.raises(TrussTimeoutError):
        fut.result()  # expired deadline is the default timeout
    assert fut.result(timeout=None) >= 0  # explicit timeout overrides


# ------------------------------------------------------------------ #
# (e) Auto rule: formulation keyed on the paper's imbalance statistics
# ------------------------------------------------------------------ #
def test_auto_rule_tracks_imbalance():
    skew = choose_backend(imbalance_stats(rmat(8, 5, seed=1)), kernel="xla")
    assert skew.formulation == "fine"  # heavy tail -> nonzero tasks
    grid = choose_backend(imbalance_stats(road(8, 0.1, seed=0)), kernel="xla")
    assert grid.formulation == "coarse"  # balanced -> row tasks
    # Pallas implements the fine formulation only.
    forced = choose_backend(imbalance_stats(road(8, 0.1, seed=0)), kernel="pallas")
    assert forced.formulation == "fine"


def test_auto_rule_end_to_end_identical_results():
    """Whatever the auto rule picks, results equal the oracle."""
    for g in [road(8, 0.1, seed=0), rmat(6, 4, seed=3)]:
        dec = solve(TrussQuery.decompose(g), chunk=64, max_batch=1)
        assert np.array_equal(dec.trussness, trussness_numpy(g)), g.name


# ------------------------------------------------------------------ #
# (f) Query validation
# ------------------------------------------------------------------ #
def test_query_validation():
    g = erdos(30, 4.0, seed=0)
    with pytest.raises(ValueError):
        TrussQuery(graph=g, workload="nope")
    with pytest.raises(ValueError):
        TrussQuery.ktruss(g, k=2)
    with pytest.raises(ValueError):
        TrussQuery(graph=g, workload="stream_update")  # missing frontier
    with pytest.raises(ValueError):
        TrussQuery.stream_update(
            g,
            frontier=np.ones(3, bool),  # wrong length
            frozen_truss=np.zeros(3, np.int32),
        )
    with pytest.raises(ValueError):
        TrussQuery.ktruss(g, k=3, frontier=np.ones(g.nnz, bool))
    with pytest.raises(ValueError):
        TrussQuery.ktruss(g, k=3, placement="everywhere")


def test_solve_single_query_roundtrip():
    g = erdos(50, 5.0, seed=1)
    km = solve(TrussQuery.kmax(g), backend="fine/xla/aligned", chunk=64, max_batch=1)
    assert km == int(trussness_numpy(g).max(initial=0))
