"""Checkpointing: roundtrip, atomicity, async, keep-policy, data resume."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.train import (
    Checkpointer,
    SyntheticLM,
    TokenShardStore,
    TrainStepConfig,
    init_train_state,
    latest_step,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
    batch_for,
)

KEY = jax.random.PRNGKey(0)


def _tiny_state():
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = Model(cfg)
    tcfg = TrainStepConfig()
    return model, tcfg, init_train_state(model, KEY, tcfg)


def test_roundtrip_exact(tmp_path):
    model, tcfg, state = _tiny_state()
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_partial_pickup(tmp_path):
    model, tcfg, state = _tiny_state()
    save_checkpoint(str(tmp_path), 3, state)
    # Simulate a crash mid-write: a stale tmp dir must be invisible.
    os.makedirs(tmp_path / ".tmp-9")
    (tmp_path / ".tmp-9" / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 3


def test_async_and_keep_policy(tmp_path):
    model, tcfg, state = _tiny_state()
    ck = Checkpointer(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    ck.wait()
    ck.gc()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("ckpt_"))
    assert steps == [3, 4]


def test_resume_continues_training(tmp_path):
    """Train 4 steps, checkpoint, restore, continue — state must match a
    continuous 6-step run (bitwise, given deterministic data)."""
    model, tcfg, state = _tiny_state()
    cfg = model.cfg
    step_fn = jax.jit(make_train_step(model, tcfg))

    def run(state, a, b):
        for s in range(a, b):
            batch = jax.tree.map(jnp.asarray, batch_for(cfg, 4, 16, s))
            state, _ = step_fn(state, batch)
        return state

    s_cont = run(jax.tree.map(lambda x: x, state), 0, 6)
    s_part = run(jax.tree.map(lambda x: x, state), 0, 4)
    save_checkpoint(str(tmp_path), 4, s_part)
    s_rest, at = restore_checkpoint(str(tmp_path), s_part)
    s_resumed = run(s_rest, at, 6)
    for a, b in zip(jax.tree.leaves(s_cont["params"]), jax.tree.leaves(s_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6)


def test_synthetic_data_deterministic():
    d = SyntheticLM(vocab=101, batch=4, seq=16, seed=5)
    b1, b2 = d.batch_at(42), d.batch_at(42)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch_at(42)["tokens"], d.batch_at(43)["tokens"])
    # LM shift property
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_token_shard_store(tmp_path):
    path = str(tmp_path / "shard.bin")
    TokenShardStore.write(path, np.arange(1000))
    store = TokenShardStore(path)
    b = store.batch_at(0, batch=2, seq=7)
    assert b["tokens"].shape == (2, 7)
    assert np.array_equal(store.batch_at(3, 2, 7)["tokens"], store.batch_at(3, 2, 7)["tokens"])
