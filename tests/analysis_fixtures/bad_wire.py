"""R6 fixture: doubles as both the "wire" file (whitelists) and the
"errors" file (taxonomy) for the rule's three checks."""

__all__ = ["AlphaError", "BetaError"]

_ERROR_CONTEXT = (
    "slot",
    "phantom",  # stale: no class has a phantom param or attribute
)

_ERROR_CONTEXT_EXCLUDED = ()


class AlphaError(Exception):
    def __init__(self, message: str, *, slot: int | None = None, depth: int = 0):
        super().__init__(message)
        self.slot = slot
        self.depth = depth  # scalar, neither whitelisted nor excluded


class BetaError(Exception):
    def __init__(self, message: str, code):  # second required positional
        super().__init__(message)
        self.code = code
