# Known-bad fixture corpus for tests/test_analysis.py.  Every file here
# violates exactly one rule on purpose; the default analysis config
# excludes this directory, and tests/test_analysis.py re-points each
# rule at its fixture and asserts the exact findings.
