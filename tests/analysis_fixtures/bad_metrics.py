"""R5 fixture: metric names the registry never declared."""


class FakeRegistry:
    def inc(self, name, value=1, **labels):
        return None

    def ingest(self, snapshot, **labels):
        return None

    def observe(self, name, value, **labels):
        return None


def work(m: FakeRegistry):
    m.inc("requests_served")  # declared: fine
    m.inc("requests_servd")  # typo
    m.observe("peel_device_time_ms", 0.1)  # wrong unit suffix
    m.ingest({"replica_requests_servd": 1})  # typo'd ingest key
