"""R4 fixture: a typo'd inject() site plus a declared-but-untested one.

``FAULT_SITES`` here shadows the real declaration when the fixture
config points R4 at this file.
"""

FAULT_SITES = ("compile", "ghost_town")


def inject(site, **ctx):
    return None


def work():
    inject("compile")  # declared: fine
    inject("dispatchh")  # typo'd site: can never be armed
    inject(site="poisonn")  # keyword form is checked too
