"""R2 fixture: a builder closing over attributes its variant key does
not fold.  ``self.mode`` is keyed, ``self.mesh`` rides via an alias;
``self.chunk`` and ``self.window`` are the leaks."""


class LeakyPlanner:
    def __init__(self, mode, chunk, window, mesh):
        self.mode = mode
        self.chunk = chunk
        self.window = window
        self.mesh = mesh
        self._mesh_key = str(mesh)

    def cache_variant(self):
        return (self.mode, self._mesh_key)

    def build_executor(self, bucket):
        return {
            "mode": self.mode,
            "chunk": self.chunk,  # not in cache_variant: leak
            "window": self.window,  # not in cache_variant: leak
            "mesh": self.mesh,  # covered by the _mesh_key alias
        }
