"""R1 fixture: host impurities inside a traced function, and a
device->host readback on the dispatch path.  Never imported."""

import jax
import numpy as np


def make(step):
    def body(x: jax.Array, n: int):
        if x > 0:  # Python branch on a traced value
            x = x + 1
        y = np.abs(x)  # host numpy on a traced array
        z = float(x)  # scalar coercion of a traced value
        w = x.item()  # explicit host sync
        pad = np.zeros(x.shape)  # shape-derived: static, must NOT flag
        if n > 2:  # plain-int param: must NOT flag
            z = z + 1
        return y + z + w + pad.sum()

    return jax.jit(body)


def dispatch(exe, packed):
    mask = np.asarray(packed.problem.colidx) != 0  # readback pre-dispatch
    st = exe.peel(mask)
    return np.asarray(st.alive)  # post-dispatch readback: fine
