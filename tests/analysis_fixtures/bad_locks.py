"""R3 fixture: one unguarded access, one blocking call under a lock,
one requires-lock method called bare."""

import threading


def send_msg(sock, msg):
    return None


class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = {}  # guarded-by: _lock
        self._sock = None

    def good(self, k):
        with self._lock:
            return self._items.get(k)

    def bad_unlocked(self, k):
        return self._items.get(k)

    def bad_io_under_lock(self, msg):
        with self._lock:
            self._items["last"] = msg
            send_msg(self._sock, msg)

    def _helper(self):  # requires-lock: _lock
        return len(self._items)

    def good_requires_call(self):
        with self._lock:
            return self._helper()

    def bad_requires_call(self):
        return self._helper()
