"""repro.analysis: each rule catches its fixture, fingerprints are
stable, suppression works, and the shipped baseline is consistent.

The fixture corpus under ``tests/analysis_fixtures/`` holds one
known-bad file per rule; each test re-points that rule's config at its
fixture and asserts the *exact* set of findings — so a rule that stops
firing (or starts over-firing) fails here before it silently rots.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    AnalysisConfig,
    Finding,
    apply_baseline,
    load_baseline,
    run,
)
from repro.analysis import (
    rules_faults,
    rules_locks,
    rules_metrics,
    rules_recompile,
    rules_trace,
    rules_wire,
)

REPO_ROOT = Path(__file__).resolve().parents[1]
FIXTURES = REPO_ROOT / "tests" / "analysis_fixtures"
NAMES_REL = "src/repro/obs/names.py"


def fixture_config(**over) -> AnalysisConfig:
    files = sorted(FIXTURES.glob("bad_*.py")) + [REPO_ROOT / NAMES_REL]
    base = dict(
        root=REPO_ROOT,
        files=files,
        trace_files=[],
        dispatch_files=[],
        recompile_files=[],
        lock_files=[],
        faults_file="",
        test_files=[],
        names_file=NAMES_REL,
        metric_ref_files=[],
        wire_file="",
        errors_file="",
    )
    base.update(over)
    return AnalysisConfig(**base)


def _summaries(findings):
    return sorted((f.rule, f.scope, f.message.split(" (")[0]) for f in findings)


# ------------------------------------------------------------------ #
# Per-rule fixture coverage
# ------------------------------------------------------------------ #
def test_r1_trace_purity_catches_fixture():
    rel = "tests/analysis_fixtures/bad_trace.py"
    cfg = fixture_config(trace_files=[rel], dispatch_files=[rel])
    found = run(cfg, rules=[rules_trace])
    assert all(f.rule == "R1" and f.path == rel for f in found)
    messages = sorted(f.message for f in found)
    assert len(found) == 5, messages
    assert sum("`if` on a traced value" in m for m in messages) == 1
    assert sum("np.abs() on a traced array" in m for m in messages) == 1
    assert sum("float() coerces" in m for m in messages) == 1
    assert sum(".item() forces a host sync" in m for m in messages) == 1
    assert sum("before dispatch" in m for m in messages) == 1
    # the static uses (x.shape, the plain-int branch, the post-dispatch
    # readback) must NOT appear
    lines = {f.line for f in found}
    src = (REPO_ROOT / rel).read_text().splitlines()
    for i, text in enumerate(src, 1):
        if "must NOT flag" in text or "post-dispatch" in text:
            assert i not in lines, text


def test_r2_recompile_hazard_catches_fixture():
    rel = "tests/analysis_fixtures/bad_recompile.py"
    cfg = fixture_config(recompile_files=[rel])
    found = run(cfg, rules=[rules_recompile])
    leaked = sorted(f.message.split("self.")[1].split(" ")[0] for f in found)
    assert leaked == ["chunk", "window"]  # mode is keyed, mesh is aliased
    assert all(
        f.rule == "R2" and f.scope == "LeakyPlanner.build_executor"
        for f in found
    )


def test_r3_lock_discipline_catches_fixture():
    rel = "tests/analysis_fixtures/bad_locks.py"
    cfg = fixture_config(lock_files=[rel])
    found = run(cfg, rules=[rules_locks])
    assert _summaries(found) == [
        ("R3", "Box.bad_io_under_lock", "blocking call send_msg() while holding _lock"),
        ("R3", "Box.bad_requires_call", "self._helper() requires-lock _lock but is called without it"),
        ("R3", "Box.bad_unlocked", "self._items is guarded-by _lock but accessed without it"),
    ]


def test_r4_fault_sites_catches_fixture():
    rel = "tests/analysis_fixtures/bad_faults.py"
    # The fixture doubles as its own "test file": its inject("compile")
    # literal covers that site, leaving ghost_town untested.
    cfg = fixture_config(faults_file=rel, test_files=[rel])
    found = run(cfg, rules=[rules_faults])
    messages = sorted(f.message for f in found)
    assert len(found) == 3, messages
    assert sum("'dispatchh' is not declared" in m for m in messages) == 1
    assert sum("'poisonn' is not declared" in m for m in messages) == 1
    assert sum("'ghost_town' is declared but no test" in m for m in messages) == 1


def test_r5_metric_names_catches_fixture():
    rel = "tests/analysis_fixtures/bad_metrics.py"
    cfg = fixture_config(metric_ref_files=[rel])
    found = run(cfg, rules=[rules_metrics])
    names = sorted(f.message.split("'")[1] for f in found)
    assert names == [
        "peel_device_time_ms",
        "replica_requests_servd",
        "requests_servd",
    ]


def test_r6_wire_schema_catches_fixture():
    rel = "tests/analysis_fixtures/bad_wire.py"
    cfg = fixture_config(wire_file=rel, errors_file=rel)
    found = run(cfg, rules=[rules_wire])
    messages = sorted(f.message for f in found)
    assert len(found) == 3, messages
    assert sum("'phantom' matches no parameter" in m for m in messages) == 1
    assert sum("'depth' is neither" in m for m in messages) == 1
    assert sum("BetaError is not constructible" in m for m in messages) == 1


# ------------------------------------------------------------------ #
# Engine mechanics
# ------------------------------------------------------------------ #
def test_fingerprints_are_line_independent_and_occurrence_stable():
    a = Finding("R1", "p.py", 10, "f", "msg", "snippet x")
    b = Finding("R1", "p.py", 99, "f", "msg", "snippet x")
    assert a.fingerprint == b.fingerprint  # line moves don't churn
    c = Finding("R1", "p.py", 99, "f", "msg", "snippet x", occurrence=1)
    d = Finding("R1", "p.py", 99, "g", "msg", "snippet x")
    assert len({a.fingerprint, c.fingerprint, d.fingerprint}) == 3


def _tmp_metrics_config(tmp_path, name, source):
    """Config rooted at tmp_path: one bad file + a copy of the registry."""
    bad = tmp_path / name
    bad.write_text(source)
    names = tmp_path / NAMES_REL
    names.parent.mkdir(parents=True, exist_ok=True)
    names.write_text((REPO_ROOT / NAMES_REL).read_text())
    return fixture_config(
        root=tmp_path, files=[bad, names], metric_ref_files=[name]
    )


def test_inline_suppression_silences_one_line(tmp_path):
    cfg = _tmp_metrics_config(
        tmp_path,
        "sup.py",
        "def work(m):\n"
        '    m.inc("not_a_metric_a")\n'
        '    m.inc("not_a_metric_b")  # trusslint: disable=R5\n',
    )
    found = run(cfg, rules=[rules_metrics])
    assert [f.message.split("'")[1] for f in found] == ["not_a_metric_a"]


def test_occurrence_index_disambiguates_identical_lines(tmp_path):
    cfg = _tmp_metrics_config(
        tmp_path,
        "twice.py",
        "def work(m):\n"
        '    m.inc("nope")\n'
        '    m.inc("nope")\n',
    )
    found = run(cfg, rules=[rules_metrics])
    assert len(found) == 2
    assert sorted(f.occurrence for f in found) == [0, 1]
    assert found[0].fingerprint != found[1].fingerprint


# ------------------------------------------------------------------ #
# The real tree and its baseline
# ------------------------------------------------------------------ #
def test_repo_is_clean_against_checked_in_baseline():
    cfg = AnalysisConfig.default(REPO_ROOT)
    findings = run(cfg)
    baseline = load_baseline(REPO_ROOT / "analysis" / "baseline.json")
    new, _old, stale = apply_baseline(findings, baseline)
    assert not new, [f.to_dict() for f in new]
    assert not stale, sorted(stale)


def test_baseline_file_is_well_formed_and_empty():
    """The dispatch-path and serve layers ship lint-clean: the baseline
    exists (CI depends on it) and grandfathers nothing."""
    data = json.loads((REPO_ROOT / "analysis" / "baseline.json").read_text())
    assert data["version"] == 1
    assert data["findings"] == []


def test_cli_reports_and_exits_zero(tmp_path):
    report = tmp_path / "ANALYSIS_report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--report", str(report)],
        cwd=REPO_ROOT,
        env={"PYTHONPATH": str(REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(report.read_text())
    assert data["counts"]["new"] == 0
    assert data["counts"]["stale_baseline"] == 0
    assert data["files_scanned"] > 20


def test_default_config_excludes_the_fixture_corpus():
    cfg = AnalysisConfig.default(REPO_ROOT)
    rels = {p.relative_to(REPO_ROOT).as_posix() for p in cfg.files}
    assert not any(r.startswith("tests/analysis_fixtures/") for r in rels)
    assert NAMES_REL in rels


# ------------------------------------------------------------------ #
# Recompile sentinel (runtime half of R2)
# ------------------------------------------------------------------ #
def test_sentinel_counts_cold_compile_and_warm_silence():
    import jax
    import jax.numpy as jnp

    from repro.analysis.sentinel import assert_no_compiles, count_compiles

    @jax.jit
    def f(x):
        return x * 2 + 1

    x = jnp.arange(7)
    with count_compiles() as log:
        f(x).block_until_ready()
    assert log.compiles >= 1  # cold call compiled

    with assert_no_compiles("warm jit call"):
        f(x).block_until_ready()

    with pytest.raises(AssertionError, match="warm"):
        with assert_no_compiles("warm (sic) call"):
            f(jnp.arange(11)).block_until_ready()  # new shape -> recompile
