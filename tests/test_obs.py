"""repro.obs: tracing, metrics, peel telemetry, and the stats() contracts.

Locks the observable surface other tooling depends on:

* key sets of ``Session.stats()`` / ``CacheStats.snapshot()`` /
  ``obs.metrics_snapshot()`` (extend, don't rename);
* a traced ``solve()`` writes Chrome trace-event JSON that
  ``json.loads`` with well-formed ``ph``/``ts``/``dur`` fields;
* deadline handling runs on the obs clock (fake-able, no sleeping);
* per-session metric isolation (the ``ENUM_COUNTS`` global is only a
  deprecated aggregate view);
* the removed ``repro.service.cache`` / ``repro.service.batcher`` shims
  stay gone (ImportError, not a silent resurrection).
"""

import importlib
import json
import sys
import warnings

import pytest

from repro import obs
from repro.api import Session, TrussQuery, solve
from repro.api.cache import CacheStats
from repro.api.errors import TrussTimeoutError
from repro.graphs import erdos, rmat

SESSION_STATS_KEYS = {
    "requests_served",
    "batches_run",
    "device_dispatches",
    "deadline_misses",
    "pending",
    "device_time_s",
    "retries",
    "backend_fallbacks",
    "queries_quarantined",
    "batch_bisects",
    "queries_failed",
    "queries_shed",
    "faults_injected",
    "cache_compiles",
    "cache_hits",
    "cache_hit_rate",
    "planner_queries_planned",
    "planner_plan_time_s",
    "planner_plan_us_per_query",
    "planner_backends",
}

CACHE_SNAPSHOT_KEYS = {"compiles", "hits", "hit_rate"}

STREAM_STATS_KEYS = {
    "updates_applied",
    "update_dispatches",
    "edges_repeeled",
    "edges",
    "kmax",
    "cached_triangles",
    "checkpoints_written",
}

SPAN_NAMES = {"solve", "plan", "pack", "compile", "dispatch", "device-wait", "unpack"}


@pytest.fixture(scope="module")
def graphs():
    return [erdos(60, 6.0, seed=3), rmat(6, 6, seed=4)]


@pytest.fixture(scope="module")
def traced(tmp_path_factory, graphs):
    """One traced mixed-workload solve, shared across assertions."""
    path = tmp_path_factory.mktemp("obs") / "trace.json"
    s = Session(trace=str(path), max_batch=4)
    res = s.solve(
        [
            TrussQuery.decompose(graphs[0]),
            TrussQuery.decompose(graphs[1]),
            TrussQuery.kmax(graphs[0]),
            TrussQuery.ktruss(graphs[1], k=3),
        ]
    )
    return s, res, path


# --------------------------------------------------------------------- #
# stats() key-set snapshots
# --------------------------------------------------------------------- #
def test_session_stats_keys_locked(traced):
    s, res, _ = traced
    assert len(res) == 4
    assert set(s.stats().keys()) == SESSION_STATS_KEYS


def test_session_stats_values_are_metric_views(traced):
    s, _, _ = traced
    st = s.stats()
    assert st["requests_served"] == 4
    assert st["device_dispatches"] == st["batches_run"] >= 1
    assert st["deadline_misses"] == 0
    assert st["device_time_s"] > 0
    # the same numbers via the registry directly
    assert s.obs.metrics.value("requests_served") == 4
    assert s.obs.metrics.value("dispatches") == st["device_dispatches"]


def test_cache_stats_snapshot_keys():
    cs = CacheStats()
    assert set(cs.snapshot().keys()) == CACHE_SNAPSHOT_KEYS
    cs.record_compile()
    cs.record_hit()
    assert cs.compiles == 1 and cs.hits == 1
    assert cs.snapshot()["hit_rate"] == 0.5


def test_stream_stats_keys(graphs):
    s = Session(max_batch=2)
    stream = s.open_stream(graphs[0])
    assert set(stream.stats().keys()) == STREAM_STATS_KEYS


def test_metrics_snapshot_structure(traced):
    s, _, _ = traced
    snap = s.metrics_snapshot()
    assert set(snap.keys()) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["requests_served"] == 4
    assert "queue_depth" in snap["gauges"]
    occ = snap["histograms"]["batch_occupancy"]
    # histogram rows carry the full summary, cumulative buckets included
    for field in ("count", "sum", "min", "max", "mean", "buckets"):
        assert field in occ
    assert occ["count"] >= 1


# --------------------------------------------------------------------- #
# Chrome trace JSON
# --------------------------------------------------------------------- #
def test_traced_solve_exports_chrome_trace(traced):
    _, _, path = traced
    data = json.loads(path.read_text())
    events = data["traceEvents"]
    assert events, "traced solve produced no events"
    names = set()
    for ev in events:
        assert ev["ph"] in {"X", "i"}
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
        if ev["ph"] == "X":
            assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0
        assert "pid" in ev and "tid" in ev
        names.add(ev["name"])
    # every stage of the query path shows up as a span
    assert SPAN_NAMES <= names
    # spans carry their workload attributes
    plan = next(e for e in events if e["name"] == "plan")
    assert "workload" in plan["args"] and "backend" in plan["args"]
    compile_ev = next(e for e in events if e["name"] == "compile")
    assert "hit" in compile_ev["args"]


def test_trace_env_var(tmp_path, graphs, monkeypatch):
    path = tmp_path / "env_trace.json"
    monkeypatch.setenv(obs.TRACE_ENV_VAR, str(path))
    solve(TrussQuery.decompose(graphs[0]))
    data = json.loads(path.read_text())
    assert any(e["name"] == "solve" for e in data["traceEvents"])


def test_trace_disabled_is_noop(graphs):
    s = Session(trace=False, max_batch=2)
    s.solve([TrussQuery.decompose(graphs[0])])
    assert s.obs.tracer is obs.NULL_TRACER
    assert not s.obs.tracing
    assert s.export_trace() is None


# --------------------------------------------------------------------- #
# Peel telemetry: the paper's imbalance statistic, observed at runtime
# --------------------------------------------------------------------- #
def test_peel_telemetry_recorded_per_bucket_backend(traced):
    s, _, _ = traced
    hists = s.metrics_snapshot()["histograms"]
    imb = {k: v for k, v in hists.items() if k.startswith("peel_batch_imbalance")}
    assert imb, "no peel_batch_imbalance histograms recorded"
    for key, row in imb.items():
        assert "bucket=" in key and "backend=" in key
        assert row["min"] >= 1.0  # max/mean per-slot iters is >= 1 by definition
    rows = obs.imbalance_summary(s.obs.metrics)
    assert rows and all("bucket" in r and "backend" in r for r in rows)
    # per-slot and per-level histograms ride along
    assert any(k.startswith("peel_slot_iters") for k in hists)
    assert any(k.startswith("peel_level_edges") for k in hists)


# --------------------------------------------------------------------- #
# Prometheus text exposition
# --------------------------------------------------------------------- #
def test_prometheus_text_format(traced):
    s, _, _ = traced
    text = s.prometheus_text()
    lines = text.splitlines()
    assert "# TYPE requests_served counter" in lines
    assert "requests_served 4" in lines
    assert "# TYPE queue_depth gauge" in lines
    assert "# TYPE batch_occupancy histogram" in lines
    assert any(
        line.startswith("batch_occupancy_bucket{") and 'le="+Inf"' in line
        for line in lines
    )
    assert any(line.startswith("batch_occupancy_sum ") for line in lines)
    assert any(line.startswith("batch_occupancy_count ") for line in lines)


# --------------------------------------------------------------------- #
# Deadlines run on the obs clock (fake-able: no sleeping in this test)
# --------------------------------------------------------------------- #
def test_deadline_miss_on_fake_clock(graphs):
    clock = obs.FakeClock()
    with obs.use_clock(clock):
        s = Session(max_batch=2)
        fut = s.submit(TrussQuery.decompose(graphs[0], deadline_s=5.0))
        assert fut.request.time_remaining() == pytest.approx(5.0)
        clock.advance(10.0)  # deadline blown without any wall time passing
        assert fut.request.time_remaining() == 0.0
        with pytest.raises(TrussTimeoutError) as ei:
            fut.result()  # default timeout = remaining deadline budget
        assert s.deadline_misses == 1
        assert s.stats()["deadline_misses"] == 1
        # shed_on_timeout (the default): the query was marked dead and its
        # queue slot reclaimed; a later result() re-raises, never re-runs.
        assert ei.value.shed is True
        assert len(s.queue) == 0
        assert s.stats()["queries_shed"] == 1
        with pytest.raises(TrussTimeoutError):
            fut.result(timeout=None)


def test_remaining_is_the_one_deadline_rule():
    clock = obs.FakeClock()
    with obs.use_clock(clock):
        t0 = obs.now()
        assert obs.remaining(t0, None) is None
        assert obs.remaining(t0, 2.0) == pytest.approx(2.0)
        clock.advance(1.5)
        assert obs.remaining(t0, 2.0) == pytest.approx(0.5)
        clock.advance(1.0)
        assert obs.remaining(t0, 2.0) == 0.0  # clamped, never negative


# --------------------------------------------------------------------- #
# Per-session metric isolation (the ENUM_COUNTS satellite)
# --------------------------------------------------------------------- #
def test_stream_enumerations_per_session(graphs):
    from repro.stream.frontier import ENUM_COUNTS

    base_full = ENUM_COUNTS["full"]
    s = Session(max_batch=2)
    st_a = s.open_stream(graphs[0])
    st_b = s.open_stream(graphs[1])
    from repro.stream.delta import EdgeBatch

    st_a.update(EdgeBatch.of(inserts=[(1, 2)]), strict=False)
    # each stream's full enumeration landed in its own registry...
    assert st_a.metrics.value("stream_enumerations", kind="full") == 1
    assert st_b.metrics.value("stream_enumerations", kind="full") == 0
    # ...while the deprecated global alias still sees the aggregate
    assert ENUM_COUNTS["full"] >= base_full + 1
    assert set(iter(ENUM_COUNTS)) == {"full", "incident"}
    assert len(ENUM_COUNTS) == 2


def test_stream_counters_are_metric_views(graphs):
    from repro.stream.delta import EdgeBatch

    s = Session(max_batch=2)
    stream = s.open_stream(graphs[0])
    stream.update(EdgeBatch.of(inserts=[(2, 3)]), strict=False)
    assert stream.updates_applied == 1
    assert stream.metrics.value("stream_updates") == 1
    assert stream.update_dispatches == stream.metrics.value(
        "stream_update_dispatches"
    )
    # frontier fraction histogram observed on the stream's registry
    hists = stream.metrics.snapshot()["histograms"]
    assert any(k.startswith("stream_frontier_frac") for k in hists)


# --------------------------------------------------------------------- #
# Deprecation shims (removed in PR 9)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("mod", ["repro.service.cache", "repro.service.batcher"])
def test_service_shims_are_gone(mod):
    sys.modules.pop(mod, None)
    with pytest.raises(ImportError):
        importlib.import_module(mod)


def test_service_package_import_is_warning_free():
    sys.modules.pop("repro.service", None)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        import repro.service  # noqa: F401

        # the documented surface resolves without any shim
        assert callable(repro.service.bucket_for)
        assert repro.service.TrussService is not None
    assert "MicroBatcher" not in repro.service.__all__
    # the batcher's replacement lives in repro.api now
    from repro.api import QueryQueue

    assert QueryQueue is not None


# --------------------------------------------------------------------- #
# Registry mechanics the wiring relies on
# --------------------------------------------------------------------- #
def test_metric_key_escaping_roundtrips_hostile_labels():
    """Label values containing the key format's own separators — commas,
    equals signs, backslashes — must survive the fmt/parse roundtrip
    byte-exact, not shift into neighbouring labels."""
    from repro.obs.metrics import _fmt_key, _label_key, _parse_key

    hostile = {
        "graph": "road,usa=west\\v2",
        "note": "a=b,c=d",
        "plain": "fine",
        "trail": "ends with backslash\\",
    }
    key = _fmt_key("requests_served", _label_key(hostile))
    name, labels = _parse_key(key)
    assert name == "requests_served"
    assert labels == hostile

    # legacy unescaped keys (pre-escaping snapshots) still parse
    name, labels = _parse_key("cache_bucket_hits{bucket=b64x2}")
    assert name == "cache_bucket_hits" and labels == {"bucket": "b64x2"}


def test_registry_escaped_labels_are_distinct_series():
    """Two label sets that would collide without escaping stay separate."""
    r = obs.MetricsRegistry()
    r.inc("requests_served", 1, graph="a,b", note="c")
    r.inc("requests_served", 5, graph="a", note="b,c")
    assert r.value("requests_served", graph="a,b", note="c") == 1
    assert r.value("requests_served", graph="a", note="b,c") == 5
    counters = r.snapshot()["counters"]
    assert sum(v for k, v in counters.items() if k.startswith("requests_served{")) == 6


def test_registry_parent_chaining():
    parent = obs.MetricsRegistry()
    child = obs.MetricsRegistry(parent=parent)
    child.inc("x", 2, where="here")  # trusslint: disable=R5
    assert child.value("x", where="here") == 2  # trusslint: disable=R5
    assert parent.value("x", where="here") == 2  # propagated up; trusslint: disable=R5
    parent.inc("x", 1, where="here")  # trusslint: disable=R5
    assert child.value("x", where="here") == 2  # isolation downward; trusslint: disable=R5


def test_session_metrics_chain_to_global(graphs):
    before = obs.get_registry().value("requests_served")
    solve(TrussQuery.decompose(graphs[0]))
    assert obs.get_registry().value("requests_served") == before + 1


def test_fake_clock_drives_trace_timestamps():
    clock = obs.FakeClock()
    with obs.use_clock(clock):
        tr = obs.Tracer()
        with obs.use_tracer(tr):
            with obs.current_tracer().span("work"):
                clock.advance(0.25)
        (ev,) = tr.events()
        assert ev["dur"] == pytest.approx(0.25e6)  # microseconds


# ------------------------------------------------------------------ #
# Fused megakernel: one kernel launch per truss level
# ------------------------------------------------------------------ #
def test_fused_peel_one_kernel_per_level(tmp_path):
    """Chrome traces show one "peel-level" span per fused launch, the
    `peel_fused_levels` counter ticks once per launch, and the batch
    still costs ONE dispatch — the megakernel contract: a whole level
    completes inside a single kernel launch."""
    path = tmp_path / "fused_trace.json"
    s = Session(
        trace=str(path), backend="fine/fused/aligned", chunk=64, max_batch=2
    )
    s.submit(TrussQuery.decompose(rmat(6, 4, seed=2)))
    s.flush()
    stats = s.stats()
    assert stats["device_dispatches"] == 1
    levels = int(s.obs.metrics.value("peel_fused_levels"))
    assert levels >= 1
    events = json.loads(path.read_text())["traceEvents"]
    level_spans = [e for e in events if e["name"] == "peel-level"]
    # the dispatch/span-counter invariant: counter == launches == spans
    assert len(level_spans) == levels
    assert [e["args"]["level"] for e in level_spans] == list(range(levels))
    # and the per-level launches all nest inside the ONE dispatch span
    assert sum(1 for e in events if e["name"] == "dispatch") == 1
