"""repro.serve: wire codec, health reports, router policy, thread-safe
Session, in-process replica ops, and the multi-process fleet proof.

The expensive piece is ``test_fleet_integration`` — it spawns three real
replica processes (separate interpreters, real sockets, real SIGKILL)
and asserts the tier's whole contract in one pass: mixed queries over
three shape buckets come back bit-identical to a local ``solve()``, a
replica killed mid-stream hands its streaming session off warm to a
survivor with identical trussness, and the router's affinity accounting
adds up.  Everything else runs in-process.
"""

import json
import socket
import threading

import numpy as np
import pytest

from repro.api import Session, TrussQuery, solve
from repro.api.cache import bucket_for, bucket_str
from repro.errors import (
    InvalidGraphError,
    QueryFailedError,
    TrussTimeoutError,
)
from repro.graphs import erdos, rmat
from repro.serve import (
    Fleet,
    FleetClient,
    HealthReport,
    Replica,
    ReplicaConfig,
    ReplicaHandle,
    Router,
    health_report,
)
from repro.serve.replica import _WARMUP_KINDS, _warm_graph
from repro.serve.wire import (
    MAX_FRAME_BYTES,
    WireError,
    decode_array,
    decode_graph,
    decode_query,
    decode_result,
    encode_array,
    encode_error,
    encode_graph,
    encode_query,
    encode_result,
    raise_remote_error,
    recv_msg,
    send_msg,
)
from repro.stream import EdgeBatch


def _fresh_edge(g):
    """One (u, v) not in ``g`` (0-based), deterministic."""
    existing = set(map(tuple, (g.edge_list() - 1)))
    for u in range(g.n):
        for v in range(u + 1, g.n):
            if (u, v) not in existing:
                return (u, v)
    raise AssertionError("graph is complete")


# ------------------------------------------------------------------ #
# Wire protocol
# ------------------------------------------------------------------ #
def test_wire_framing_roundtrip():
    a, b = socket.socketpair()
    try:
        send_msg(a, {"op": "ping", "payload": [1, 2, 3]})
        send_msg(a, {"op": "second"})
        assert recv_msg(b) == {"op": "ping", "payload": [1, 2, 3]}
        assert recv_msg(b) == {"op": "second"}
        a.close()
        assert recv_msg(b) is None  # clean EOF at a frame boundary
    finally:
        b.close()


def test_wire_rejects_oversized_frames():
    a, b = socket.socketpair()
    try:
        # A hostile/corrupt length prefix must not allocate 4 GiB.
        a.sendall((MAX_FRAME_BYTES + 1).to_bytes(4, "big"))
        with pytest.raises(WireError):
            recv_msg(b)
    finally:
        a.close()
        b.close()


def test_array_and_graph_roundtrip_bit_exact():
    rng = np.random.default_rng(3)
    for arr in (
        rng.integers(-(2**31), 2**31, size=(17,), dtype=np.int32),
        rng.integers(0, 2, size=(4, 9)).astype(bool),
        np.zeros((0, 2), np.int64),
    ):
        out = decode_array(json.loads(json.dumps(encode_array(arr))))
        assert out.dtype == arr.dtype and np.array_equal(out, arr)

    g = rmat(6, 5, seed=1)
    g2 = decode_graph(json.loads(json.dumps(encode_graph(g))))
    assert g2.n == g.n
    assert np.array_equal(g2.rowptr, g.rowptr)
    assert np.array_equal(g2.colidx, g.colidx)


def test_query_roundtrip_preserves_fields():
    g = erdos(40, 5.0, seed=0)
    q = TrussQuery.ktruss(g, k=4, deadline_s=2.5)
    q2 = decode_query(json.loads(json.dumps(encode_query(q))))
    assert (q2.workload, q2.k, q2.deadline_s) == ("ktruss", 4, 2.5)
    assert np.array_equal(q2.graph.colidx, g.colidx)

    frontier = np.zeros(g.nnz, bool)
    frontier[:3] = True
    frozen = np.arange(g.nnz, dtype=np.int32)
    qs = TrussQuery.stream_update(g, frontier=frontier, frozen_truss=frozen)
    qs2 = decode_query(json.loads(json.dumps(encode_query(qs))))
    assert np.array_equal(qs2.frontier, frontier)
    assert np.array_equal(qs2.frozen_truss, frozen)


def test_result_roundtrip_all_kinds():
    g = erdos(48, 6.0, seed=0)
    dec, km, kt = solve(
        [TrussQuery.decompose(g), TrussQuery.kmax(g), TrussQuery.ktruss(g, k=3)]
    )
    dec2 = decode_result(json.loads(json.dumps(encode_result(dec))))
    assert np.array_equal(dec2.trussness, dec.trussness)
    assert (dec2.kmax, dec2.levels) == (dec.kmax, dec.levels)
    assert decode_result(json.loads(json.dumps(encode_result(km)))) == km
    kt2 = decode_result(json.loads(json.dumps(encode_result(kt))))
    assert np.array_equal(kt2.alive, kt.alive)
    assert np.array_equal(kt2.support, kt.support)
    assert kt2.edges_remaining == kt.edges_remaining
    arr = dec.trussness
    assert np.array_equal(
        decode_result(json.loads(json.dumps(encode_result(arr)))), arr
    )


def test_remote_errors_reraise_typed_with_context():
    # The shed signal must survive the hop: a replica's admission shed
    # arrives as TrussTimeoutError(shed=True), not a lookalike message.
    frame = json.loads(
        json.dumps(encode_error(TrussTimeoutError("full", shed=True, queue_depth=7)))
    )
    with pytest.raises(TrussTimeoutError) as ei:
        raise_remote_error(frame)
    assert ei.value.shed is True
    assert ei.value.queue_depth == 7
    assert "[remote]" in str(ei.value)

    with pytest.raises(InvalidGraphError):
        raise_remote_error(encode_error(InvalidGraphError("bad", kind="self_loop")))

    # Unknown names never import anything — they degrade to RuntimeError.
    with pytest.raises(RuntimeError, match="NoSuchError"):
        raise_remote_error({"error": {"type": "NoSuchError", "message": "x"}})
    with pytest.raises(RuntimeError):
        raise_remote_error({"error": {"type": "os.system", "message": "x"}})


# ------------------------------------------------------------------ #
# HealthReport (the shed/quarantine roundtrip the router depends on)
# ------------------------------------------------------------------ #
def test_health_report_roundtrip_preserves_shed_and_quarantine():
    report = HealthReport(
        name="replica-1",
        queue_depth=3,
        live_queries=5,
        requests_served=41,
        queries_shed=7,
        queries_failed=2,
        queries_quarantined=4,
        retries=9,
        warmup_queries=2,
        draining=False,
        streams=("stream-0", "stream-3"),
        compiled_buckets=("n64-nnz256-w16",),
        cache_bucket_hits={"n64-nnz256-w16": 12},
        imbalance=({"bucket": "n64-nnz256-w16", "max_over_mean": 1.5},),
    )
    # Through JSON, like the health op sends it.
    back = HealthReport.from_dict(json.loads(json.dumps(report.to_dict())))
    assert back == report
    assert back.queries_shed == 7 and back.queries_quarantined == 4


def test_health_report_reads_session_counters():
    s = Session(max_batch=2)
    g = erdos(48, 6.0, seed=0)
    s.submit(TrussQuery.decompose(g)).result(timeout=None)
    s.submit(TrussQuery.kmax(g)).result(timeout=None)
    rep = health_report(s, name="r0", streams=("s1",))
    assert rep.requests_served == s.requests_served == 2
    assert rep.queries_shed == s.queries_shed
    assert rep.queries_quarantined == s.queries_quarantined
    label = bucket_str(bucket_for(g, chunk=s.chunk))
    assert label in rep.compiled_buckets
    # Second query hit the compiled bucket at least once.
    assert rep.cache_bucket_hits.get(label, 0) >= 1
    assert rep.streams == ("s1",)
    back = HealthReport.from_dict(json.loads(json.dumps(rep.to_dict())))
    assert back == rep


def test_warmup_specs_are_allowlisted():
    g = _warm_graph({"kind": "erdos", "n": 32, "avg_degree": 4.0, "seed": 1})
    assert g.n == 32
    with pytest.raises(ValueError, match="unknown warmup generator"):
        _warm_graph({"kind": "os.system"})
    with pytest.raises(ValueError):
        _warm_graph({})
    assert "erdos" in _WARMUP_KINDS


# ------------------------------------------------------------------ #
# Router policy (fake handles — no sockets)
# ------------------------------------------------------------------ #
class _StubHandle(ReplicaHandle):
    """Handle whose RPCs are canned: submit counts, health is scripted."""

    def __init__(self, name, report=None):
        super().__init__(name, "127.0.0.1", 0)
        self.report = report
        self.submitted = 0

    def submit(self, qmsg):
        self.submitted += 1
        return self.submitted

    def health(self):
        if self.report is None:
            raise ConnectionError(f"{self.name} is down")
        return self.report

    def close(self):
        pass


def _report(name, **over):
    base = dict(
        name=name,
        queue_depth=0,
        live_queries=0,
        requests_served=0,
        queries_shed=0,
        queries_failed=0,
        queries_quarantined=0,
        retries=0,
        warmup_queries=0,
        draining=False,
        streams=(),
        compiled_buckets=(),
        cache_bucket_hits={},
        imbalance=(),
    )
    base.update(over)
    return HealthReport(**base)


def test_router_affinity_sticks_and_counts():
    r = Router([_StubHandle("r0"), _StubHandle("r1")], spill_depth=100)
    h, affine = r.pick("bucketA")
    assert affine is False  # cold assignment
    home = h.name
    for _ in range(5):
        h2, affine = r.pick("bucketA")
        assert h2.name == home and affine is True
    st = r.stats()
    assert st["cold_assignments"] == 1 and st["affinity_hits"] == 5
    assert st["routed"] == 6
    assert st["affinity"]["bucketA"] == home
    assert st["affinity_hit_rate"] == round(5 / 6, 4)


def test_router_spills_past_depth_and_sheds_at_saturation():
    r = Router(
        [_StubHandle("r0"), _StubHandle("r1")], spill_depth=2, shed_depth=2
    )
    home = r.pick("b")[0].name  # cold: depth home=1
    r.pick("b")  # hit: home=2
    spill, affine = r.pick("b")  # home at spill_depth -> least-loaded
    assert spill.name != home and affine is False
    r.pick("b")  # other still strictly less loaded -> spills again
    assert r.stats()["spillovers"] == 2
    with pytest.raises(TrussTimeoutError) as ei:
        r.pick("b")  # every replica at shed_depth
    assert ei.value.shed is True
    assert r.stats()["queries_shed"] == 1
    r.release(home)  # one slot frees -> admission resumes
    assert r.pick("b")[0] is not None


def test_router_learns_warm_home_from_health():
    warm = _report("r1", compiled_buckets=("bucketX",))
    r = Router([_StubHandle("r0"), _StubHandle("r1", report=warm)])
    r._replicas["r0"].report = _report("r0")
    r.poll_health()
    h, _ = r.pick("bucketX")
    assert h.name == "r1"  # adopted the replica that already compiled it


def test_router_quarantine_redistributes_and_recovers():
    h0 = _StubHandle("r0", report=_report("r0"))
    h1 = _StubHandle("r1", report=_report("r1", streams=("s7",)))
    r = Router([h0, h1], max_health_fails=1)
    assert r.pick("b")[0].name == "r0"  # cold -> least loaded = r0
    r.release("r0")
    h1.report = None  # r1 stops answering health
    r.poll_health()
    assert r.is_quarantined("r1")
    assert not r.is_quarantined("r0")
    # But r1 held no routed buckets; now kill r0 which owns "b".
    streams = r.quarantine("r0")
    assert streams == ()  # r0 reported no streams
    with pytest.raises(QueryFailedError):
        r.pick("b")  # nobody healthy
    r.reinstate("r1", _StubHandle("r1", report=_report("r1")))
    h, _ = r.pick("b")
    assert h.name == "r1"
    assert r.stats()["replicas_quarantined"] == 2


def test_router_quarantine_reports_orphaned_streams():
    h0 = _StubHandle("r0", report=_report("r0", streams=("sA", "sB")))
    h1 = _StubHandle("r1", report=_report("r1"))
    r = Router([h0, h1])
    r.poll_health()
    assert r.quarantine("r0") == ("sA", "sB")
    assert r.quarantine("r0") == ()  # idempotent


def test_network_fault_site_raises_typed_device_error():
    """The ``network`` site fires inside ``ReplicaHandle.rpc`` before any
    socket is opened, so chaos storms can break connections on demand."""
    from repro.errors import DeviceError
    from repro.resilience import FaultPlan, FaultSpec, use_plan

    handle = ReplicaHandle("r0", "127.0.0.1", 1)  # never actually connects
    with use_plan(FaultPlan([FaultSpec("network", times=1)])):
        with pytest.raises(DeviceError) as ei:
            handle.ping()
        assert ei.value.site == "network" and ei.value.injected
        assert handle._sock is None  # fault fired pre-connect


class _NetFlakyHandle(_StubHandle):
    """Stub whose submit passes through the real ``network`` fault site."""

    def submit(self, qmsg):
        from repro.resilience import inject

        inject("network", replica=self.name, op="submit")
        return super().submit(qmsg)


def test_router_reroutes_around_injected_network_fault():
    from repro.resilience import FaultPlan, FaultSpec, use_plan

    g = erdos(30, 3.0, seed=5)
    q = TrussQuery.decompose(g)
    r = Router(
        [_NetFlakyHandle("r0"), _NetFlakyHandle("r1")], max_health_fails=1
    )
    plan = FaultPlan(
        [FaultSpec("network", times=1, where=(("replica", "r0"),))]
    )
    with use_plan(plan):
        routed = r.submit(q, {"op": "submit"})
    # The injected connection failure quarantined r0 and the query
    # re-routed to the survivor — the affinity map follows.
    assert routed.replica.name == "r1"
    assert r.is_quarantined("r0")
    assert plan.fired("network") == 1


def test_replica_kill_is_a_pure_action_site():
    """``replica_kill`` must *return* its fired spec, never raise: the
    fleet monitor polls it each tick and performs the kill itself."""
    from repro.resilience import FaultPlan, FaultSpec, inject, use_plan

    plan = FaultPlan(
        [FaultSpec("replica_kill", times=1, where=(("replica", "r1"),))]
    )
    with use_plan(plan):
        assert inject("replica_kill", replica="r0") is None  # no match
        spec = inject("replica_kill", replica="r1")
        assert spec is not None and spec.site == "replica_kill"
        assert inject("replica_kill", replica="r1") is None  # times=1 spent
    assert plan.fired("replica_kill") == 1


def test_router_ingests_replica_counters():
    h0 = _StubHandle(
        "r0", report=_report("r0", queries_shed=4, requests_served=11)
    )
    r = Router([h0])
    r.poll_health()
    snap = r.metrics.snapshot()["gauges"]
    assert snap["replica_queries_shed{replica=r0}"] == 4
    assert snap["replica_requests_served{replica=r0}"] == 11


def test_route_many_is_edf_ordered():
    g = erdos(24, 4.0, seed=0)
    qs = [
        TrussQuery.kmax(g),  # no deadline -> last, submission order
        TrussQuery.kmax(g, deadline_s=5.0),
        TrussQuery.kmax(g, deadline_s=1.0),
        TrussQuery.kmax(g),
    ]
    r = Router([_StubHandle("r0")])
    assert r.route_many(qs) == [2, 1, 0, 3]


# ------------------------------------------------------------------ #
# Thread-safe Session (the substrate replicas stand on)
# ------------------------------------------------------------------ #
def test_session_threaded_hammer_matches_serial():
    g_small = erdos(48, 6.0, seed=0)
    g_big = erdos(150, 5.0, seed=1)
    queries = [
        TrussQuery.decompose(g_small if i % 2 else g_big) for i in range(12)
    ] + [TrussQuery.kmax(g_small), TrussQuery.ktruss(g_big, k=3)]
    expect = solve(list(queries), max_batch=4)

    s = Session(max_batch=4)
    results: dict[int, object] = {}
    errors: list[BaseException] = []

    def worker(idxs):
        try:
            futs = [(i, s.submit(queries[i])) for i in idxs]
            for i, f in futs:
                results[i] = f.result(timeout=None)
        except BaseException as e:  # pragma: no cover - failure detail
            errors.append(e)

    threads = [
        threading.Thread(target=worker, args=(range(t, len(queries), 4),))
        for t in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errors, errors
    assert len(results) == len(queries)
    for i, exp in enumerate(expect):
        got = results[i]
        if isinstance(exp, int):
            assert got == exp
        elif hasattr(exp, "trussness"):
            assert np.array_equal(got.trussness, exp.trussness)
        else:
            assert np.array_equal(got.alive, exp.alive)
    assert s.requests_served == len(queries)
    assert s.drain() == 0  # nothing left in flight


def test_session_drain_flushes_queued_work():
    s = Session(max_batch=4)
    g = erdos(48, 6.0, seed=0)
    futs = [s.submit(TrussQuery.kmax(g)) for _ in range(3)]
    assert s.drain() >= 1
    assert all(f.done() for f in futs)
    assert len(s.queue) == 0


# ------------------------------------------------------------------ #
# Replica ops in-process (one process, real handler paths)
# ------------------------------------------------------------------ #
@pytest.fixture()
def replica(tmp_path):
    cfg = ReplicaConfig(
        name="r-test",
        port_file=str(tmp_path / "port"),
        max_batch=2,
        max_live=2,
        checkpoint_root=str(tmp_path / "ckpt"),
        checkpoint_every=1,
    )
    return Replica(cfg)


def test_replica_admission_sheds_past_max_live(replica):
    g = erdos(48, 6.0, seed=0)
    q = encode_query(TrussQuery.kmax(g))
    qid1 = replica._handle({"op": "submit", "query": q})["qid"]
    replica._handle({"op": "submit", "query": q})
    with pytest.raises(TrussTimeoutError) as ei:
        replica._handle({"op": "submit", "query": q})  # 3rd > max_live=2
    assert ei.value.shed is True
    out = replica._handle({"op": "result", "qid": qid1, "timeout": None})
    assert isinstance(decode_result(out["result"]), int)
    rep = replica.health()
    assert rep.queries_shed >= 1
    assert rep.live_queries == 1  # one still uncollected
    with pytest.raises(KeyError):
        replica._handle({"op": "result", "qid": qid1})  # already collected


def test_replica_drain_refuses_new_work(replica):
    g = erdos(48, 6.0, seed=0)
    q = encode_query(TrussQuery.kmax(g))
    replica._handle({"op": "submit", "query": q})
    replica._handle({"op": "drain"})
    assert replica.health().draining is True
    with pytest.raises(TrussTimeoutError):
        replica._handle({"op": "submit", "query": q})


def test_replica_stream_seq_is_exactly_once(replica, tmp_path):
    g = erdos(48, 6.0, seed=0)
    opened = replica._handle(
        {"op": "open_stream", "stream_id": "s0", "graph": encode_graph(g)}
    )
    assert opened["seq"] == 0
    ins = _fresh_edge(g)
    dele = tuple(g.edge_list()[0] - 1)
    msg = {
        "op": "stream_update",
        "stream_id": "s0",
        "seq": 1,
        "inserts": encode_array(np.asarray([ins], np.int64)),
        "deletes": encode_array(np.asarray([dele], np.int64)),
    }
    first = replica._handle(msg)
    assert first["seq"] == 1 and "replayed" not in first
    # The exact frame again (a client retry after a lost ack): replayed,
    # not re-applied — committed state comes back unchanged.
    again = replica._handle(msg)
    assert again["replayed"] is True and again["seq"] == 1
    assert again["trussness"] == first["trussness"]
    with pytest.raises(ValueError, match="expects seq 2"):
        replica._handle({**msg, "seq": 5})
    with pytest.raises(KeyError):
        replica._handle({**msg, "stream_id": "nope"})


def test_replica_restore_stream_resumes_from_checkpoint(replica):
    g = erdos(48, 6.0, seed=0)
    replica._handle(
        {"op": "open_stream", "stream_id": "s1", "graph": encode_graph(g)}
    )
    msg = {
        "op": "stream_update",
        "stream_id": "s1",
        "seq": 1,
        "inserts": encode_array(np.asarray([_fresh_edge(g)], np.int64)),
        "deletes": encode_array(np.zeros((0, 2), np.int64)),
    }
    committed = replica._handle(msg)
    # A "new" replica process (fresh Replica over the same checkpoint
    # root) restores the stream warm, at the committed seq.
    twin = Replica(replica.config)
    restored = twin._handle({"op": "restore_stream", "stream_id": "s1"})
    assert restored["seq"] == 1
    assert restored["trussness"] == committed["trussness"]
    # And the retried update is recognized as already applied.
    replay = twin._handle(msg)
    assert replay["replayed"] is True


# ------------------------------------------------------------------ #
# The multi-process fleet (the tier-1 proof)
# ------------------------------------------------------------------ #
def test_fleet_integration(tmp_path):
    g1 = erdos(48, 6.0, seed=0)
    g2 = erdos(150, 5.0, seed=1)
    g3 = rmat(7, 5, seed=2)
    buckets = {bucket_str(bucket_for(g, chunk=256)) for g in (g1, g2, g3)}
    assert len(buckets) == 3  # the mix really spans three shape buckets

    warm = [
        {"kind": "erdos", "n": 48, "avg_degree": 6.0, "seed": 0},
        {"kind": "erdos", "n": 150, "avg_degree": 5.0, "seed": 1},
        {"kind": "rmat", "scale": 7, "edge_factor": 5, "seed": 2},
    ]
    queries = [
        TrussQuery.decompose(g1),
        TrussQuery.kmax(g2),
        TrussQuery.ktruss(g3, k=3),
        TrussQuery.decompose(g2),
        TrussQuery.kmax(g1),
        TrussQuery.decompose(g3),
    ]
    expect = solve(list(queries), max_batch=2)

    ins = _fresh_edge(g1)
    dele = tuple(g1.edge_list()[0] - 1)
    local = Session(max_batch=2)
    lstream = local.open_stream(g1)
    lstream.update(EdgeBatch.of([ins]))
    lstream.update(EdgeBatch.of([], [dele]))

    with Fleet(3, workdir=str(tmp_path / "fleet"), max_batch=2, warmup=warm) as fleet:
        client = FleetClient(fleet)

        # Mixed queries over 3 buckets: bit-identical to local solve().
        got = client.solve(list(queries))
        for exp, res in zip(expect, got):
            if isinstance(exp, int):
                assert res == exp
            elif hasattr(exp, "trussness"):
                assert np.array_equal(res.trussness, exp.trussness)
                assert res.kmax == exp.kmax
            else:
                assert np.array_equal(res.alive, exp.alive)
                assert np.array_equal(res.support, exp.support)

        # Warmup seeded affinity: repeat traffic stays home.
        st = client.stats()
        assert st["routed"] >= len(queries)
        assert st["routed"] == (
            st["affinity_hits"] + st["spillovers"] + st["cold_assignments"]
        )
        assert st["affinity_hits"] > 0

        # Kill a replica mid-stream: the stream resumes on a survivor
        # with trussness identical to the never-crashed local session.
        stream = client.open_stream(g1)
        owner = stream.owner
        assert owner is not None
        stream.update(EdgeBatch.of([ins]))
        fleet.kill(owner)
        reply = stream.update(EdgeBatch.of([], [dele]))
        assert stream.owner != owner
        assert stream.seq == 2 and reply["seq"] == 2
        assert np.array_equal(stream.trussness, lstream.trussness)
        assert stream.kmax == lstream.kmax
        assert fleet.stats()["replicas"][owner]["quarantined"] is True

        # The fleet accounted the warm handoff for this stream.
        assert (
            int(
                fleet.router.metrics.value(
                    "fleet_stream_handoffs", stream=stream.stream_id
                )
            )
            >= 1
        )
