"""Serving engine: greedy determinism, decode == step-by-step, EOS stop."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.serve import ServeEngine

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen2-0.5b"):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def test_greedy_reproducible():
    cfg, model, params = _setup()
    prompt = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab_size, (3, 8)), jnp.int32)}
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, max_len=24, seed=7)
        outs.append(eng.generate(prompt, max_new_tokens=8).tokens)
    assert np.array_equal(outs[0], outs[1])


def test_generate_matches_manual_decode():
    cfg, model, params = _setup()
    toks = jnp.asarray(np.random.default_rng(1).integers(1, cfg.vocab_size, (2, 6)), jnp.int32)
    eng = ServeEngine(model, params, max_len=16)
    got = eng.generate({"tokens": toks}, max_new_tokens=4).tokens

    last, states = model.prefill(params, {"tokens": toks}, max_len=16)
    cur = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    manual = [np.asarray(cur)]
    for i in range(3):
        lg, states = model.decode(params, cur, states, 6 + i)
        cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        manual.append(np.asarray(cur))
    assert np.array_equal(got, np.concatenate(manual, 1))


def test_eos_early_stop():
    cfg, model, params = _setup()
    toks = jnp.asarray(np.random.default_rng(2).integers(1, cfg.vocab_size, (2, 4)), jnp.int32)
    eng = ServeEngine(model, params, max_len=64, eos_id=None)
    full = eng.generate({"tokens": toks}, max_new_tokens=10).tokens
    # Pick the token generated at position 1 as "EOS" — generation must halt.
    eos = int(full[0, 1])
    eng2 = ServeEngine(model, params, max_len=64, eos_id=eos)
    short = eng2.generate({"tokens": toks}, max_new_tokens=10).tokens
    assert short.shape[1] <= full.shape[1]


def test_temperature_sampling_runs():
    cfg, model, params = _setup()
    toks = jnp.asarray(np.random.default_rng(3).integers(1, cfg.vocab_size, (2, 4)), jnp.int32)
    eng = ServeEngine(model, params, max_len=16, seed=3)
    out = eng.generate({"tokens": toks}, max_new_tokens=4, temperature=1.0)
    assert out.tokens.shape == (2, 4)
    assert out.decode_tokens_per_s() > 0
