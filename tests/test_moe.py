"""MoE dispatch: the paper's coarse→fine axis applied to expert routing."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.config import MoEConfig
from repro.models.moe import moe_apply, moe_init, router_topk

KEY = jax.random.PRNGKey(1)


def _cfg(dispatch, capacity_factor=1.25, top_k=2, experts=8, shared=0):
    base = get_config("kimi-k2-1t-a32b", smoke=True)
    return base.replace(
        moe=MoEConfig(
            num_experts=experts,
            top_k=top_k,
            d_ff_expert=32,
            num_shared_experts=shared,
            dispatch=dispatch,
            capacity_factor=capacity_factor,
        )
    )


def _dense_oracle(p, x, cfg):
    """Route per token, run each expert densely — the obviously-correct
    O(T·E) reference both dispatch modes must reproduce (when dropless)."""
    m = cfg.moe
    w, ids, _ = router_topk(p, x, m)
    w = np.asarray(w)
    ids = np.asarray(ids)
    xf = np.asarray(x, np.float32)
    gate = np.asarray(p["gate"], np.float32)
    up = np.asarray(p["up"], np.float32)
    down = np.asarray(p["down"], np.float32)
    out = np.zeros_like(xf)
    silu = lambda v: v / (1 + np.exp(-v))
    for t in range(xf.shape[0]):
        for j in range(m.top_k):
            e = ids[t, j]
            h = silu(xf[t] @ gate[e]) * (xf[t] @ up[e])
            out[t] += w[t, j] * (h @ down[e])
    return out


@pytest.mark.parametrize("top_k", [1, 2])
def test_fine_dispatch_matches_dense_oracle(top_k):
    cfg = _cfg("fine", top_k=top_k)
    p = moe_init(KEY, cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, cfg.d_model)), jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    ref = _dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y, np.float32), ref, rtol=5e-2, atol=5e-2)
    assert float(aux["moe_drop_frac"]) == 0.0  # fine is dropless


def test_coarse_with_ample_capacity_matches_fine():
    """With capacity ≥ worst case, coarse == fine == oracle: the dispatch
    decomposition must not change the math — only the drop/pad behavior."""
    cfg_f = _cfg("fine")
    cfg_c = _cfg("coarse", capacity_factor=64.0)  # effectively unbounded
    p = moe_init(KEY, cfg_f)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (32, cfg_f.d_model)), jnp.float32)
    yf, _ = moe_apply(p, x, cfg_f)
    yc, auxc = moe_apply(p, x, cfg_c)
    np.testing.assert_allclose(
        np.asarray(yf, np.float32), np.asarray(yc, np.float32), rtol=3e-2, atol=3e-2
    )
    assert float(auxc["moe_drop_frac"]) == 0.0


def test_coarse_drops_under_skew_fine_does_not():
    """The paper's imbalance effect: skew the router so one expert is hot;
    coarse drops tokens at fixed capacity, fine keeps all of them."""
    cfg_c = _cfg("coarse", capacity_factor=1.0, top_k=1)
    cfg_f = _cfg("fine", top_k=1)
    p = moe_init(KEY, cfg_c)
    # Bias the router toward expert 0.
    rk = np.asarray(p["router"]["kernel"], np.float32).copy()
    rk[:, 0] += 10.0
    p["router"]["kernel"] = jnp.asarray(rk)
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (128, cfg_c.d_model)), jnp.float32)
    _, aux_c = moe_apply(p, x, cfg_c)
    _, aux_f = moe_apply(p, x, cfg_f)
    assert float(aux_c["moe_drop_frac"]) > 0.25  # hot expert overflows
    assert float(aux_f["moe_drop_frac"]) == 0.0  # flat buffer absorbs skew
    load = np.asarray(aux_c["expert_load"])
    assert load[0] > 3.0 / cfg_c.moe.num_experts  # skew confirmed


def test_shared_expert_added():
    cfg = _cfg("fine", shared=1)
    p = moe_init(KEY, cfg)
    x = jnp.zeros((8, cfg.d_model), jnp.float32)
    y, _ = moe_apply(p, x, cfg)
    assert y.shape == x.shape


def test_sharded_path_matches_local():
    """shard_map EP on a 1×1×1 mesh must equal the local path bit-for-bit
    logic (same math, degenerate mesh)."""
    import jax
    from repro.distributed.context import sharding_context

    cfg = _cfg("fine")
    p = moe_init(KEY, cfg)
    x = jnp.asarray(np.random.default_rng(3).normal(0, 1, (32, cfg.d_model)), jnp.float32)
    y_local, _ = moe_apply(p, x, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_context(mesh):
        y_sharded, _ = moe_apply(p, x, cfg)  # model_size==1 -> local path
    np.testing.assert_allclose(
        np.asarray(y_local, np.float32), np.asarray(y_sharded, np.float32), rtol=1e-5
    )
