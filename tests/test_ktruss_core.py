"""Core K-truss correctness: all decompositions/modes vs independent oracles."""

import networkx as nx
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    KTrussEngine,
    kmax_numpy,
    ktruss_dense,
    ktruss_numpy,
    prepare_fine,
    support_coarse_eager,
    support_fine_eager,
    support_fine_owner,
    support_numpy,
)
from repro.graphs import CSRGraph, from_edges

import jax.numpy as jnp


ALL_VARIANTS = [("fine", "eager"), ("fine", "owner"), ("coarse", "eager")]


def _w(g, owner=False):
    deg = g.undirected_csr().max_degree() if owner else g.max_degree()
    return max(8, ((deg + 7) // 8) * 8)


# ------------------------------------------------------------------ #
# Support computation == oracle
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("variant", ALL_VARIANTS, ids=["fine-eager", "fine-owner", "coarse"])
def test_support_matches_oracle(small_graphs, variant):
    gran, mode = variant
    for g in small_graphs:
        p = prepare_fine(g, chunk=256)
        alive = jnp.asarray(p.colidx != 0)
        if gran == "coarse":
            s = support_coarse_eager(p, alive, window=_w(g), row_chunk=16)
        elif mode == "eager":
            s = support_fine_eager(p, alive, window=_w(g), chunk=256)
        else:
            s = support_fine_owner(p, alive, window=_w(g, owner=True), chunk=256)
        assert np.array_equal(np.asarray(s)[: g.nnz], support_numpy(g)), g.name


def test_support_on_pruned_graph(small_graphs):
    """Alive-masked supports must agree across variants mid-convergence."""
    g = small_graphs[1]
    p = prepare_fine(g, chunk=256)
    rng = np.random.default_rng(0)
    alive_np = rng.random(p.nnz_pad) < 0.7
    alive_np &= np.asarray(p.colidx) != 0
    alive = jnp.asarray(alive_np)
    ref = support_numpy(g, alive_np[: g.nnz])
    s1 = np.asarray(support_fine_eager(p, alive, window=_w(g), chunk=256))[: g.nnz]
    s2 = np.asarray(support_fine_owner(p, alive, window=_w(g, True), chunk=256))[: g.nnz]
    s3 = np.asarray(support_coarse_eager(p, alive, window=_w(g), row_chunk=8))[: g.nnz]
    live = alive_np[: g.nnz]
    assert np.array_equal(s1 * live, ref * live)
    assert np.array_equal(s2 * live, ref * live)
    assert np.array_equal(s3 * live, ref * live)


# ------------------------------------------------------------------ #
# Fixed point + kmax vs oracles (incl. networkx)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("variant", ALL_VARIANTS, ids=["fine-eager", "fine-owner", "coarse"])
def test_ktruss_fixed_point(small_graphs, variant):
    gran, mode = variant
    for g in small_graphs:
        eng = KTrussEngine(g, granularity=gran, mode=mode, chunk=256)
        for k in (3, 4):
            res = eng.ktruss(k)
            alive_ref, s_ref = ktruss_numpy(g, k)
            assert np.array_equal(res.alive, alive_ref)
            assert np.array_equal(res.support, s_ref)


def test_ktruss_matches_networkx():
    g = from_edges(
        60, np.random.default_rng(3).integers(0, 60, size=(400, 2))
    )
    eng = KTrussEngine(g, granularity="fine", mode="eager", chunk=256)
    edges = g.edge_list() - 1  # back to 0-based
    nxg = nx.Graph(list(map(tuple, edges)))
    for k in (3, 4, 5):
        res = eng.ktruss(k)
        ours = {tuple(e) for e, a in zip(map(tuple, edges), res.alive) if a}
        theirs = set()
        for u, v in nx.k_truss(nxg, k).edges():
            theirs.add((min(u, v), max(u, v)))
        assert ours == theirs, f"k={k}"


def test_kmax_warm_start(small_graphs):
    for g in small_graphs[:2]:
        eng = KTrussEngine(g, granularity="fine", mode="owner", chunk=256)
        assert eng.kmax() == kmax_numpy(g)


def test_dense_reference_agrees(small_graphs):
    g = small_graphs[0]
    u = g.dense_upper()
    u = jnp.asarray(u + u.T)
    adj, s = ktruss_dense(u, 3)
    alive_ref, s_ref = ktruss_numpy(g, 3)
    rows, cols = g.row_of_edge(), g.colidx
    assert np.array_equal(np.asarray(adj)[rows, cols] > 0, alive_ref)
    assert np.array_equal(np.asarray(s)[rows, cols], s_ref)


# ------------------------------------------------------------------ #
# Properties (hypothesis)
# ------------------------------------------------------------------ #
@given(
    n=st.integers(4, 24),
    m=st.integers(0, 80),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_property_modes_agree(n, m, seed):
    rng = np.random.default_rng(seed)
    g = from_edges(n, rng.integers(0, n, size=(m, 2)))
    if g.nnz == 0:
        return
    p = prepare_fine(g, chunk=64)
    alive = jnp.asarray(p.colidx != 0)
    s_e = np.asarray(support_fine_eager(p, alive, window=_w(g), chunk=64))
    s_o = np.asarray(support_fine_owner(p, alive, window=_w(g, True), chunk=64))
    assert np.array_equal(s_e, s_o)  # ownership == eager (DESIGN §4)


@given(n=st.integers(5, 20), m=st.integers(5, 60), seed=st.integers(0, 9999))
@settings(max_examples=15, deadline=None)
def test_property_truss_is_maximal_and_stable(n, m, seed):
    rng = np.random.default_rng(seed)
    g = from_edges(n, rng.integers(0, n, size=(m, 2)))
    if g.nnz == 0:
        return
    eng = KTrussEngine(g, granularity="fine", mode="eager", chunk=64)
    res = eng.ktruss(3)
    # Every surviving edge has support ≥ 1 within the surviving subgraph.
    s = support_numpy(g, res.alive)
    assert np.all(s[res.alive] >= 1)
    # Fixed point: running again changes nothing.
    pad = eng.problem.nnz_pad - g.nnz
    res2 = eng.ktruss(3, alive0=jnp.asarray(np.pad(res.alive, (0, pad))))
    assert np.array_equal(res.alive, res2.alive)


def test_bucketed_fine_matches_oracle(small_graphs):
    """Degree-bucketed windows (beyond-paper §Perf-ktruss) are exact."""
    for g in small_graphs:
        eng = KTrussEngine(g, bucketed=True, chunk=256)
        for k in (3, 4):
            res = eng.ktruss(k)
            alive_ref, s_ref = ktruss_numpy(g, k)
            assert np.array_equal(res.alive, alive_ref), (g.name, k)
            assert np.array_equal(res.support, s_ref), (g.name, k)
