"""Per-arch smoke tests + decode-vs-full consistency + layer-plan logic."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import ShapeSpec, input_specs, materialize, SHAPES, cell_is_valid
from repro.models import Model
from repro.models.blocks import layer_plan
from repro.models.encdec import encdec_apply
from repro.models.lm import lm_apply

KEY = jax.random.PRNGKey(0)
TRAIN = ShapeSpec("t", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_train_step_shapes(arch):
    """Assignment requirement: reduced config, one forward/train step on
    CPU, output shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params = m.init(KEY)
    batch = materialize(input_specs(cfg, TRAIN), seed=1)
    logits, aux = m.train_logits(params, batch)
    assert logits.shape[:2] == batch["labels"].shape
    assert logits.shape[-1] == cfg.vocab_size
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    # one real train step moves the loss
    from repro.train import TrainStepConfig, init_train_state, make_train_step

    tcfg = TrainStepConfig(grad_accum=2)
    state = init_train_state(m, KEY, tcfg)
    step = jax.jit(make_train_step(m, tcfg))
    state2, metrics = step(state, jax.tree.map(jnp.asarray, batch))
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params = m.init(KEY)
    rng = np.random.default_rng(3)
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    extra = {}
    if cfg.is_encdec:
        extra["src_embeds"] = jnp.asarray(
            rng.normal(0, 0.5, (B, cfg.frontend_len, cfg.d_model)), jnp.bfloat16
        )
        full, _, _ = encdec_apply(params, cfg, toks, src_embeds=extra["src_embeds"])
    elif cfg.family == "vlm":
        extra["embeds"] = jnp.asarray(
            rng.normal(0, 0.5, (B, cfg.frontend_len, cfg.d_model)), jnp.bfloat16
        )
        full, _, _ = lm_apply(params, cfg, toks, embeds=extra["embeds"])
    else:
        full, _, _ = lm_apply(params, cfg, toks)

    prefix = cfg.frontend_len if cfg.family == "vlm" else 0
    split = S - 3
    last, states = m.prefill(
        params, dict(tokens=toks[:, :split], **extra), max_len=S + prefix
    )
    logs = [last]
    for i in range(3):
        lg, states = m.decode(
            params, toks[:, split + i][:, None], states, prefix + split + i
        )
        logs.append(lg)
    got = np.stack([np.asarray(x) for x in logs], 1)
    want = np.asarray(full[:, prefix + split - 1 : prefix + S, :])
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_layer_plan_recurrentgemma_suffix():
    cfg = get_config("recurrentgemma-9b")
    plan = layer_plan(cfg, cfg.layer_kinds())
    assert plan["period"] == 3
    assert plan["groups"] == 12
    assert len(plan["suffix"]) == 2  # 38 = 12*3 + 2
    assert plan["group_kinds"] == ["rglru", "rglru", "local"]


def test_layer_plan_gemma2_pairs():
    cfg = get_config("gemma2-9b")
    plan = layer_plan(cfg, cfg.layer_kinds())
    assert plan["groups"] == 21 and plan["period"] == 2
    assert plan["prefix"] == [] and plan["suffix"] == []


def test_layer_plan_llama4_moe_period():
    cfg = get_config("llama4-maverick-400b-a17b")
    plan = layer_plan(cfg, cfg.layer_kinds())
    assert plan["period"] == 2
    assert plan["group_moe"] == [True, False]


def test_long500k_rules():
    allowed = {n for n in ARCH_NAMES if cell_is_valid(get_config(n), SHAPES["long_500k"])[0]}
    assert allowed == {"recurrentgemma-9b", "rwkv6-7b"}


def test_sliding_window_ring_cache_exceeds_window():
    """Decode far past the window: ring cache must match full forward."""
    cfg = get_config("gemma2-9b", smoke=True)  # window = 32 in smoke
    cfg = cfg.replace(sliding_window=8, num_layers=2)
    m = Model(cfg)
    params = m.init(KEY)
    rng = np.random.default_rng(5)
    B, S = 1, 24  # 3× the window
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    full, _, _ = lm_apply(params, cfg, toks)
    last, states = m.prefill(params, {"tokens": toks[:, : S - 4]}, max_len=S)
    logs = [last]
    for i in range(4):
        lg, states = m.decode(params, toks[:, S - 4 + i][:, None], states, S - 4 + i)
        logs.append(lg)
    got = np.stack([np.asarray(x) for x in logs[:-1]], 1)
    want = np.asarray(full[:, S - 5 : S - 1, :])
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_param_counts_active_vs_total():
    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    m = Model(cfg)
    params = m.init(KEY)
    total, active = m.param_count(params), m.active_param_count(params)
    assert active < total  # top-2 of 8 experts
