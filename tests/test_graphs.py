"""Graph substrate invariants (+ hypothesis properties)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.graphs import (
    CSRGraph,
    barabasi,
    clustered,
    erdos,
    from_edges,
    imbalance_stats,
    rmat,
    road,
)


def _check_invariants(g: CSRGraph):
    assert g.rowptr.shape == (g.n + 1,)
    assert g.rowptr[-1] == g.nnz
    assert np.all(np.diff(g.rowptr) >= 0)
    rows = g.row_of_edge()
    if g.nnz:
        assert rows.min() >= 1
        assert g.colidx.min() >= 1  # ids are 1-based; 0 is the sentinel
        assert np.all(rows < g.colidx)  # strictly upper-triangular
    for v in range(1, g.n + 1):
        r = g.colidx[g.rowptr[v - 1] : g.rowptr[v]]
        assert np.all(np.diff(r) > 0)  # sorted, deduplicated


@pytest.mark.parametrize(
    "g",
    [
        erdos(300, 6.0, seed=3),
        barabasi(400, 3, seed=4),
        rmat(8, 4, seed=5),
        road(16, 0.1, seed=6),
        clustered(4, 16, 0.5, seed=7),
    ],
    ids=["er", "ba", "rmat", "road", "clustered"],
)
def test_generator_invariants(g):
    _check_invariants(g)
    assert g.nnz > 0


@given(
    n=st.integers(2, 40),
    edges=st.lists(
        st.tuples(st.integers(0, 39), st.integers(0, 39)), max_size=200
    ),
)
@settings(max_examples=60, deadline=None)
def test_from_edges_properties(n, edges):
    e = np.array([(u % n, v % n) for u, v in edges], dtype=np.int64).reshape(-1, 2)
    g = from_edges(n, e)
    _check_invariants(g)
    # Round trip: rebuilding from the edge list is idempotent.
    g2 = from_edges(n, g.edge_list() - 1)
    assert np.array_equal(g.rowptr, g2.rowptr)
    assert np.array_equal(g.colidx, g2.colidx)


def test_undirected_doubles_edges():
    g = erdos(200, 6.0, seed=8)
    u = g.undirected_csr()
    assert u.nnz == 2 * g.nnz
    assert np.array_equal(np.sort(u.degrees())[::-1], np.sort(u.degrees())[::-1])


def test_padded_rows_sentinel_row():
    g = erdos(50, 4.0, seed=9)
    pr = g.padded_rows()
    assert pr.shape[0] == g.n + 1
    assert np.all(pr[0] == 0)  # the sentinel vertex has no neighbors


def test_imbalance_orders_families():
    """Power-law graphs must show far worse coarse imbalance than grids —
    the premise of the paper's Fig. 2/3."""
    s_rmat = imbalance_stats(rmat(10, 8, seed=10))
    s_road = imbalance_stats(road(32, 0.05, seed=11))
    assert s_rmat.coarse_imbalance > 5 * s_road.coarse_imbalance
    assert s_rmat.fine_imbalance < s_rmat.coarse_imbalance
    assert s_rmat.fine_tasks > s_rmat.coarse_tasks
