"""Pallas kernels vs ref.py oracles (interpret mode), shape/dtype sweeps."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import KTrussEngine, ktruss_numpy
from repro.graphs import clustered, erdos, rmat
from repro.kernels import ops
from repro.kernels.ref import support_dense_ref, support_tiles_ref
from repro.kernels.support_fine import support_fine_pallas

LARGE = 1 << 20


def _windows(rng, e, w, universe=4096, fill_frac=0.8):
    """CSR-realistic windows: strictly ascending unique valid prefix."""
    vals = np.full((e, w), LARGE, np.int32)
    ok = np.zeros((e, w), bool)
    for i in range(e):
        d = rng.integers(0, w + 1)
        v = np.sort(rng.choice(np.arange(1, universe), size=d, replace=False))
        vals[i, :d] = v
        ok[i, :d] = rng.random(d) < fill_frac
    return vals, ok


@pytest.mark.parametrize("schedule", ["compare", "bsearch"])
@pytest.mark.parametrize(
    "e,w,tile",
    [(128, 128, 64), (256, 256, 128), (512, 128, 256), (128, 512, 128)],
)
def test_support_fine_shapes(schedule, e, w, tile):
    rng = np.random.default_rng(e * w + tile)
    a, a_ok = _windows(rng, e, w)
    b, b_ok = _windows(rng, e, w)
    ref = np.asarray(
        support_tiles_ref(jnp.asarray(a), jnp.asarray(a_ok), jnp.asarray(b), jnp.asarray(b_ok))
    )
    out = np.asarray(
        support_fine_pallas(
            jnp.asarray(a), jnp.asarray(a_ok), jnp.asarray(b), jnp.asarray(b_ok),
            tile=tile, schedule=schedule, interpret=True,
        )
    )
    assert np.array_equal(out, ref)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_support_fine_property(seed):
    rng = np.random.default_rng(seed)
    a, a_ok = _windows(rng, 128, 128, universe=300)
    b, b_ok = _windows(rng, 128, 128, universe=300)
    args = tuple(jnp.asarray(x) for x in (a, a_ok, b, b_ok))
    ref = np.asarray(support_tiles_ref(*args))
    for sched in ("compare", "bsearch"):
        out = np.asarray(
            support_fine_pallas(*args, tile=128, schedule=sched, interpret=True)
        )
        assert np.array_equal(out, ref), sched


@pytest.mark.parametrize("block", [32, 64, 128])
@pytest.mark.parametrize("v", [96, 128, 200])
def test_support_dense_blocks(block, v):
    rng = np.random.default_rng(block + v)
    u = (rng.random((v, v)) < 0.1).astype(np.float32)
    u = np.triu(u, 1)
    u = u + u.T
    ref = np.asarray(support_dense_ref(jnp.asarray(u)))
    out = np.asarray(ops.support_dense(jnp.asarray(u), block=block))
    assert np.allclose(out, ref)


def test_pallas_engine_end_to_end():
    for g in [erdos(120, 9.0, seed=0), rmat(7, 5, seed=1), clustered(3, 16, 0.6, seed=2)]:
        eng = KTrussEngine(g, granularity="fine", backend="pallas", chunk=256)
        res = eng.ktruss(3)
        alive_ref, s_ref = ktruss_numpy(g, 3)
        assert np.array_equal(res.alive, alive_ref), g.name
        assert np.array_equal(res.support, s_ref), g.name


def test_pallas_bsearch_schedule_engine():
    """The O(W log W) schedule drives the same fixed point."""
    import functools
    from repro.kernels import ops as kops

    g = erdos(100, 8.0, seed=4)
    eng = KTrussEngine(g, granularity="fine", backend="pallas", chunk=256)
    eng._support = functools.partial(
        kops.support_fine, eng.problem, window=eng.window, chunk=eng.chunk,
        schedule="bsearch",
    )
    eng._fixed_point = __import__("jax").jit(eng._fixed_point_impl, static_argnums=(1,))
    res = eng.ktruss(3)
    alive_ref, s_ref = ktruss_numpy(g, 3)
    assert np.array_equal(res.alive, alive_ref)
