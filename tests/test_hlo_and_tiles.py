"""Unit tests: trip-scaled HLO accounting + tile-aligned MoE offsets + flash."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_stats import analyze_hlo
from repro.models.moe import tile_aligned_offsets


def test_hlo_stats_scales_scan_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
    ).compile()
    st = analyze_hlo(c.as_text())
    want = 10 * 2 * 64 * 32 * 32  # 10 trips × dot flops
    assert abs(st["flops"] - want) / want < 0.05
    # XLA's own cost analysis counts the body once — the bug we fix.
    assert c.cost_analysis()["flops"] < want / 5


def test_hlo_stats_fusion_boundary_traffic():
    def f(x):
        return jnp.sum(jnp.tanh(x) * 2 + 1)  # one fused elementwise chain

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((1024, 1024), jnp.float32)).compile()
    st = analyze_hlo(c.as_text())
    # Traffic should be O(one read + tiny outputs), not O(#ops × size).
    assert st["traffic"] < 3 * 1024 * 1024 * 4


def test_tile_aligned_offsets_properties():
    rng = np.random.default_rng(0)
    el, tile, cap = 4, 8, 64
    loc_e = np.sort(rng.integers(0, el + 1, size=40)).astype(np.int32)
    slots, tile_expert, keep = jax.tree.map(
        np.asarray, tile_aligned_offsets(jnp.asarray(loc_e), el, tile, cap)
    )
    # slots[r] >= r: kept rows always form a prefix (the combine relies on it)
    idx = np.arange(len(loc_e))
    assert np.all(slots[keep] >= idx[keep])
    # every kept slot's tile belongs to that row's expert
    for r in np.nonzero(keep)[0]:
        assert tile_expert[slots[r] // tile] == loc_e[r]
    # no two rows share a slot
    kept_slots = slots[keep]
    assert len(set(kept_slots.tolist())) == len(kept_slots)
    # invalid rows (loc_e == el) are never kept
    assert not np.any(keep[loc_e == el])


def test_constrain_helpers_noop_without_context():
    from repro.distributed.context import constrain_batch, constrain_cache, constrain_seq

    x = jnp.ones((4, 8, 16))
    assert constrain_batch(x) is x
    assert constrain_seq(x) is x
    c = jnp.ones((2, 8, 4, 16))
    assert constrain_cache(c) is c


@pytest.mark.parametrize("g", [1, 2, 4])
def test_flash_gqa_expand_consistency(g):
    """H-layout flash == dense reference for several GQA group sizes."""
    from repro.models.flash import flash_attention

    rng = np.random.default_rng(g)
    B, Sq, KV, Dh = 2, 12, 2, 8
    H = KV * g
    q = jnp.asarray(rng.normal(0, 1, (B, Sq, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, Sq, KV, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, Sq, KV, Dh)), jnp.float32)
    pos = jnp.arange(Sq)
    out = flash_attention(
        q, k, v, scale=0.3, causal=True, q_positions=pos, kv_positions=pos,
        window=None, softcap=None, chunk=4,
    )
    # dense reference
    q5 = q.reshape(B, Sq, KV, g, Dh)
    s = jnp.einsum("bqhgd,bchd->bqhgc", q5 * 0.3, k)
    mask = pos[None, :] <= pos[:, None]
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    ref = jnp.einsum("bqhgc,bchd->bqhgd", jax.nn.softmax(s, -1), v).reshape(
        B, Sq, H, Dh
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
