"""Exec layer: the on-device peel behind every multi-level workload.

Covers the PR-level contracts: one device dispatch per decompose/kmax (no
per-level host round-trips, asserted via the executor dispatch counter),
batched trussness bit-identical to the per-graph engine across generator
families, slot-aligned packing, the Pallas backend through the serving
path, targeted ``result()`` resolution, and the sharded executor on 8
simulated host devices matching unsharded results exactly.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import KTrussEngine, support_fine_eager, support_numpy
from repro.exec import PeelExecutor
from repro.graphs import barabasi, clustered, erdos, pack_problems, rmat, road
from repro.service import TrussService, bucket_for


def _families():
    return [
        erdos(90, 6.0, seed=0),
        barabasi(110, 3, seed=1),
        clustered(3, 14, 0.6, seed=2),
        road(9, 0.1, seed=3),
        rmat(6, 4, seed=4),
    ]


def _same_bucket(factory, count, *, chunk=64, tries=64):
    """First ``count`` generated graphs sharing one shape bucket (different
    seeds can shift the power-of-two window/nnz bucket)."""
    groups = {}
    for s in range(tries):
        g = factory(s)
        groups.setdefault(bucket_for(g, chunk=chunk), []).append(g)
        if len(groups[bucket_for(g, chunk=chunk)]) == count:
            return groups[bucket_for(g, chunk=chunk)]
    raise AssertionError(f"no bucket reached {count} graphs in {tries} tries")


# ------------------------------------------------------------------ #
# One dispatch per multi-level workload + bit-identical results
# ------------------------------------------------------------------ #
def test_engine_decompose_is_one_dispatch():
    for g in _families():
        eng = KTrussEngine(g, chunk=64)
        dec = eng.decompose()
        assert eng.peel_executor.dispatches == 1, g.name
        km = eng.kmax()
        assert eng.peel_executor.dispatches == 2, g.name
        # decompose kmax floors at 2 (every edge is in the 2-truss);
        # kmax() reports 0 when even the 3-truss is empty.
        assert km == (dec.kmax if dec.kmax >= 3 else 0)
        # levels == peeled thresholds: one per k in [3, kmax] + final empty.
        assert dec.levels == (max(dec.kmax - 2, 0) + 1 if g.nnz else 0)


def test_batched_decompose_one_dispatch_matches_engine():
    graphs = _same_bucket(lambda s: erdos(80, 6.0, seed=s), 4)
    svc = TrussService(max_batch=4, chunk=64)
    futs = [svc.submit_decompose(g) for g in graphs]
    svc.flush()
    st = svc.stats()
    assert st["device_dispatches"] == 1, st  # whole batch, every level: once
    assert st["batches_run"] == 1
    for g, fut in zip(graphs, futs):
        dec = fut.result()
        edec = KTrussEngine(g, chunk=64).decompose()
        assert np.array_equal(dec.trussness, edec.trussness), g.name
        assert dec.kmax == edec.kmax and dec.levels == edec.levels


def test_mixed_workload_batch_resolves_in_one_dispatch():
    graphs = _same_bucket(lambda s: erdos(80, 6.0, seed=s), 4)
    svc = TrussService(max_batch=4, chunk=64)
    f_kt = svc.submit_ktruss(graphs[0], 4)
    f_km = svc.submit_kmax(graphs[1])
    f_dc = svc.submit_decompose(graphs[2])
    f_k3 = svc.submit_ktruss(graphs[3], 3)
    svc.flush()
    assert svc.stats()["device_dispatches"] == 1
    eng0 = KTrussEngine(graphs[0], chunk=64)
    ref = eng0.ktruss(4)
    res = f_kt.result()
    assert np.array_equal(res.alive, ref.alive)
    assert np.array_equal(res.support, ref.support)
    assert f_km.result() == KTrussEngine(graphs[1], chunk=64).kmax()
    edec = KTrussEngine(graphs[2], chunk=64).decompose()
    assert np.array_equal(f_dc.result().trussness, edec.trussness)
    ref3 = KTrussEngine(graphs[3], chunk=64).ktruss(3)
    assert np.array_equal(f_k3.result().alive, ref3.alive)
    # per-member stats: the single-level ktruss member peeled one level,
    # the decompose member peeled through its kmax.
    assert f_kt.stats.rounds == 1
    assert f_dc.stats.rounds == edec.levels


def test_peel_levels_consistent_with_executor():
    g = clustered(3, 12, 0.8, seed=0)
    eng = KTrussEngine(g, chunk=64)
    km, levels = eng.peel_levels()
    assert km == eng.kmax()
    dec = eng.decompose()
    # level k's alive mask is exactly the trussness >= k edge set.
    for res in levels:
        assert np.array_equal(res.alive, dec.trussness >= res.k), res.k


def test_executor_direct_single_level_matches_ktruss():
    g = erdos(70, 7.0, seed=1)
    eng = KTrussEngine(g, chunk=64)
    exe = PeelExecutor(
        mode="eager", backend="xla", window=eng.window, chunk=64
    )
    st = exe.peel(
        eng.problem,
        slot_ids=np.zeros(eng.problem.nnz_pad, np.int32),
        k0=[4],
        single_level=[True],
    )
    ref = eng.ktruss(4)
    assert np.array_equal(np.asarray(st.alive)[: g.nnz], ref.alive)
    assert np.array_equal(np.asarray(st.support)[: g.nnz], ref.support)
    assert int(st.iters[0]) == ref.iterations


# ------------------------------------------------------------------ #
# Slot-aligned packing
# ------------------------------------------------------------------ #
def test_aligned_pack_supports_match_members():
    gs = [erdos(50, 6.0, seed=0), clustered(2, 14, 0.7, seed=1), road(6, 0.2, seed=2)]
    w = max(8, -(-max(int(g.undirected_csr().max_degree()) for g in gs) // 8) * 8)
    pp = pack_problems(gs, slot_n=64, slot_nnz=256, slots=4, chunk=64, layout="aligned")
    assert pp.layout == "aligned"
    assert pp.problem.nnz_pad == 4 * 256
    # Member i's real lanes start exactly at its slot block.
    for i, (g, (a, b)) in enumerate(zip(gs, pp.edge_ranges)):
        assert a == i * 256 and b == a + g.nnz
    alive = jnp.asarray(pp.problem.colidx != 0)
    s = np.asarray(support_fine_eager(pp.problem, alive, window=w, chunk=64))
    for g, (a, b) in zip(gs, pp.edge_ranges):
        assert np.array_equal(s[a:b], support_numpy(g)), g.name
    # The empty 4th slot contributes nothing.
    assert not np.any(s[3 * 256 :])


def test_aligned_pack_validates_capacity():
    g = erdos(50, 6.0, seed=0)
    with pytest.raises(ValueError):
        pack_problems([g], slot_n=16, slot_nnz=256, chunk=64, layout="aligned")
    with pytest.raises(ValueError):
        pack_problems([g], slot_n=64, slot_nnz=64, chunk=64, layout="aligned")


# ------------------------------------------------------------------ #
# Targeted result(): resolving one future leaves other buckets queued
# ------------------------------------------------------------------ #
def test_result_does_not_drain_other_buckets():
    g1, g2 = erdos(80, 5.0, seed=0), road(8, 0.1, seed=1)
    assert bucket_for(g1, chunk=64) != bucket_for(g2, chunk=64)
    svc = TrussService(max_batch=2, chunk=64)
    f_other = svc.submit_ktruss(g1, 3)  # older, different bucket
    f_mine = svc.submit_ktruss(g2, 3)
    res = f_mine.result()
    assert f_mine.done() and res.k == 3
    assert not f_other.done()
    assert svc.stats()["pending"] == 1  # g1 still queued, untouched
    f_other.result()
    assert svc.stats()["pending"] == 0


# ------------------------------------------------------------------ #
# Pallas backend through the serving path (interpret mode on CPU)
# ------------------------------------------------------------------ #
def test_pallas_service_matches_xla_service():
    graphs = [erdos(40, 5.0, seed=0), clustered(2, 10, 0.7, seed=1)]
    results = {}
    for backend in ("xla", "pallas"):
        svc = TrussService(backend=backend, max_batch=2, chunk=64)
        f_dec = svc.submit_decompose(graphs[0])
        f_kt = svc.submit_ktruss(graphs[1], 3)
        svc.flush()
        st = svc.stats()
        assert st["device_dispatches"] == st["batches_run"]  # 1 per batch
        results[backend] = (f_dec.result(), f_kt.result())
    dec_x, kt_x = results["xla"]
    dec_p, kt_p = results["pallas"]
    assert np.array_equal(dec_p.trussness, dec_x.trussness)
    assert dec_p.kmax == dec_x.kmax and dec_p.levels == dec_x.levels
    assert np.array_equal(kt_p.alive, kt_x.alive)
    assert np.array_equal(kt_p.support, kt_x.support)


# ------------------------------------------------------------------ #
# Sharded executor on 8 simulated host devices == unsharded
# ------------------------------------------------------------------ #
_SHARDED_SCRIPT = """
import numpy as np, jax
assert len(jax.devices()) == 8, jax.devices()
from repro.graphs import erdos
from repro.distributed import slot_mesh
from repro.service import TrussService, bucket_for

groups = {}
for s in range(64):
    g = erdos(40, 5.0, seed=s)
    groups.setdefault(bucket_for(g, chunk=64), []).append(g)
    if len(groups[bucket_for(g, chunk=64)]) == 8:
        graphs = groups[bucket_for(g, chunk=64)]
        break
svc_sharded = TrussService(max_batch=8, chunk=64, mesh=slot_mesh(8))
svc_plain = TrussService(max_batch=8, chunk=64)
fs = [svc_sharded.submit_decompose(g) for g in graphs]
fp = [svc_plain.submit_decompose(g) for g in graphs]
svc_sharded.flush(); svc_plain.flush()
assert svc_sharded.stats()["device_dispatches"] == 1
for g, a, b in zip(graphs, fs, fp):
    da, db = a.result(), b.result()
    assert np.array_equal(da.trussness, db.trussness), g.name
    assert da.kmax == db.kmax and da.levels == db.levels
print("SHARDED_OK")
"""


def test_sharded_peel_matches_unsharded_subprocess():
    """8 simulated host devices (fresh process: XLA_FLAGS must precede jax
    init); sharded batched decompose must equal unsharded bit-for-bit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + env.get("XLA_FLAGS", "")
    ).strip()
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_OK" in proc.stdout
