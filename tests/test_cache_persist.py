"""Compile-cache persistence: a restarted server warm-starts from disk.

``TrussService(cache_dir=...)`` wires the in-process shape-bucket cache to
JAX's persistent compilation cache.  The contract: process A populates the
cache directory; a FRESH process B running the same bucket reports a
persistent-cache **hit on its first compile** (counted via JAX's own
``/jax/compilation_cache/cache_hits`` monitoring event — no timing
heuristics).  Subprocesses are required because the persistent cache is
keyed per process lifetime and must observe the config before first use.
"""

import os
import subprocess
import sys

_SCRIPT = """
import sys
import jax.monitoring

hits = []
jax.monitoring.register_event_listener(
    lambda event, **kw: hits.append(event)
    if event == "/jax/compilation_cache/cache_hits"
    else None
)

from repro.graphs import erdos
from repro.service import TrussService

svc = TrussService(max_batch=1, chunk=64, cache_dir=sys.argv[1])
fut = svc.submit_decompose(erdos(40, 5.0, seed=0))
svc.flush()
assert fut.result().kmax >= 2
print(f"PERSIST_HITS={len(hits)}")
print(f"PERSIST_COMPILES={svc.stats()['cache_compiles']}")
"""


def _run(cache_dir: str) -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, cache_dir],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return dict(
        line.split("=", 1)
        for line in proc.stdout.splitlines()
        if line.startswith("PERSIST_")
    )


def test_fresh_process_reports_warm_first_compile(tmp_path):
    cache_dir = str(tmp_path / "xla-cache")
    cold = _run(cache_dir)
    # Process A: compiled once, nothing to hit in an empty cache dir...
    assert cold["PERSIST_COMPILES"] == "1"
    assert cold["PERSIST_HITS"] == "0"
    # ...but its executable persisted to disk.
    assert os.listdir(cache_dir), "persistent cache wrote no entries"

    warm = _run(cache_dir)
    # Process B: same in-process compile count (fresh process), but the
    # XLA compile underneath was served from the persistent cache.
    assert warm["PERSIST_COMPILES"] == "1"
    assert int(warm["PERSIST_HITS"]) >= 1, warm
