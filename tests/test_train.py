"""Training substrate: optimizer math, fused loss, grad accum, e2e loss drop."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.train import (
    AdamWConfig,
    TrainStepConfig,
    adamw_init,
    adamw_update,
    batch_for,
    global_norm,
    init_train_state,
    make_train_step,
    softmax_xent,
    warmup_cosine,
)
from repro.train.fused_loss import fused_unembed_xent
from repro.train.optimizer import _q8_decode, _q8_encode

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ #
# AdamW vs a straight-line numpy reference
# ------------------------------------------------------------------ #
def _np_adamw(p, g, m, v, t, lr, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1**t)
    vh = v / (1 - cfg.b2**t)
    step = mh / (np.sqrt(vh) + cfg.eps)
    wd = cfg.weight_decay * p if p.ndim >= 2 else 0.0
    return p - lr * (step + wd), m, v


def test_adamw_matches_reference():
    cfg = AdamWConfig()
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32),
              "b": jnp.asarray(rng.normal(0, 1, (4,)), jnp.float32)}
    opt = adamw_init(params, cfg)
    pn = {k: np.asarray(v) for k, v in params.items()}
    mn = {k: np.zeros_like(v) for k, v in pn.items()}
    vn = {k: np.zeros_like(v) for k, v in pn.items()}
    for t in range(1, 4):
        grads = {k: jnp.asarray(rng.normal(0, 1, v.shape), jnp.float32) for k, v in params.items()}
        params, opt = adamw_update(grads, opt, params, jnp.float32(1e-2), cfg)
        for k in pn:
            pn[k], mn[k], vn[k] = _np_adamw(pn[k], np.asarray(grads[k]), mn[k], vn[k], t, 1e-2, cfg)
    for k in pn:
        np.testing.assert_allclose(np.asarray(params[k]), pn[k], rtol=1e-5, atol=1e-6)


def test_q8_roundtrip_error_bounded():
    rng = np.random.default_rng(1)
    for shape in [(7,), (5, 130), (3, 4, 257)]:
        x = jnp.asarray(rng.normal(0, 3, shape), jnp.float32)
        dec = np.asarray(_q8_decode(_q8_encode(x), shape))
        err = np.abs(dec - np.asarray(x))
        # symmetric int8: error ≤ scale/2 = max|block|/254
        assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 + 1e-6
        assert dec.shape == shape


def test_int8_moments_training_converges():
    cfg = AdamWConfig(moment_dtype="int8")
    w = jnp.asarray(np.random.default_rng(2).normal(0, 1, (16, 16)), jnp.float32)
    params = {"w": w}
    opt = adamw_init(params, cfg)
    target = jnp.eye(16)
    for _ in range(120):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt = adamw_update(grads, opt, params, jnp.float32(0.05), cfg)
    assert float(jnp.sum((params["w"] - target) ** 2)) < 0.1


# ------------------------------------------------------------------ #
# Fused CE == naive CE (values AND gradients)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("transposed", [True, False])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_fused_loss_matches_naive(transposed, softcap):
    rng = np.random.default_rng(3)
    B, S, D, V = 2, 17, 8, 37
    feats = jnp.asarray(rng.normal(0, 1, (B, S, D)), jnp.float32)
    labels = jnp.asarray(rng.integers(-1, V, (B, S)), jnp.int32)
    un = jnp.asarray(rng.normal(0, 1, (V, D) if transposed else (D, V)), jnp.float32)

    def naive(f, u):
        logits = jnp.einsum("bsd,vd->bsv" if transposed else "bsd,dv->bsv", f, u)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        return softmax_xent(logits, labels, z_loss=1e-4)[0]

    def fused(f, u):
        return fused_unembed_xent(
            f, labels, u, transposed=transposed, softcap=softcap, z_loss=1e-4, chunk=5
        )[0]

    np.testing.assert_allclose(float(naive(feats, un)), float(fused(feats, un)), rtol=1e-5)
    g1 = jax.grad(naive, argnums=(0, 1))(feats, un)
    g2 = jax.grad(fused, argnums=(0, 1))(feats, un)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_grad_accum_equals_full_batch():
    cfg = get_config("smollm-360m", smoke=True)
    model = Model(cfg)
    t1 = TrainStepConfig(grad_accum=1, fused_loss=True)
    t4 = TrainStepConfig(grad_accum=4, fused_loss=True)
    s1 = init_train_state(model, KEY, t1)
    s4 = jax.tree.map(lambda x: x, s1)
    batch = jax.tree.map(jnp.asarray, batch_for(cfg, 8, 16, 0))
    step1 = jax.jit(make_train_step(model, t1))
    step4 = jax.jit(make_train_step(model, t4))
    n1, m1 = step1(s1, batch)
    n4, m4 = step4(s4, batch)
    # Same total gradient (mean over tokens is linear across microbatches).
    np.testing.assert_allclose(
        float(m1["grad_norm"]), float(m4["grad_norm"]), rtol=1e-4
    )
    # Post-Adam params: step-1 Adam is sign(g)·lr, so near-zero gradient
    # lanes may flip sign under fp noise — bound by 2·lr, not rtol.
    for a, b in zip(jax.tree.leaves(n1["params"]), jax.tree.leaves(n4["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=2.5e-3)


def test_training_loss_decreases():
    from repro.launch.train import run_training

    out = run_training(
        arch="smollm-360m", smoke=True, steps=80, batch=16, seq=32,
        base_lr=1e-2, log_every=1000,
    )
    assert out["final_loss"] < out["first_loss"] - 1.0, out["losses"][::10]


def test_schedule_shape():
    lrs = [float(warmup_cosine(s, base_lr=1.0, warmup_steps=10, total_steps=100)) for s in range(100)]
    assert lrs[0] == 0.0 and abs(lrs[10] - 1.0) < 0.11
    assert lrs[99] < 0.2 and all(l >= 0 for l in lrs)


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-6
