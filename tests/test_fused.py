"""Fused peel megakernel: parity, tiling validation, and autotuning.

The fused backend (`fine/fused/aligned`) must be bit-identical to the
XLA peel on every PeelState field — including the per-slot iteration
trajectory — because its per-level kernel replays `build_peel`'s
bookkeeping exactly (slots are block-diagonal and independent).  The
autotune store must round-trip winning configs across processes so a
warm server replays them instead of re-sweeping.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import Session, TrussQuery, solve
from repro.api.cache import bucket_for
from repro.api.registry import BackendKey, choose_backend
from repro.core import trussness_numpy
from repro.errors import InvalidGraphError
from repro.exec.peel import PeelExecutor
from repro.graphs import barabasi, erdos, rmat
from repro.graphs.pack import pack_problems, validate_fused_tiling
from repro.graphs.stats import ImbalanceStats
from repro.kernels.autotune import (
    AutotuneStore,
    FusedConfig,
    autotune_fused,
    candidate_configs,
    lookup,
)

CHUNK = 64


def _packed_batch(graphs):
    buckets = [bucket_for(g, chunk=CHUNK) for g in graphs]
    n_pad = max(b.n_pad for b in buckets)
    nnz_pad = max(b.nnz_pad for b in buckets)
    window = max(b.window for b in buckets)
    slots = len(graphs)
    packed = pack_problems(
        graphs,
        slot_n=n_pad,
        slot_nnz=nnz_pad,
        slots=slots,
        chunk=CHUNK,
        layout="aligned",
    )
    slot_ids = np.repeat(np.arange(slots, dtype=np.int32), nnz_pad)
    return packed, slot_ids, window


_STATE_FIELDS = (
    "alive", "support", "trussness", "cur_k", "kmax",
    "levels", "iters", "done", "edges_alive",
)


def _assert_states_equal(st_a, st_b):
    for field in _STATE_FIELDS:
        a = np.asarray(getattr(st_a, field))
        b = np.asarray(getattr(st_b, field))
        assert np.array_equal(a, b), f"{field}: {a} != {b}"


# --------------------------------------------------------------------- #
# (a) Executor-level bit-identity, both schedules
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("schedule", ["compare", "bsearch"])
def test_fused_executor_bit_identical_to_xla(schedule):
    graphs = [rmat(5, 6, seed=1), barabasi(30, 3, seed=0)]
    packed, slot_ids, window = _packed_batch(graphs)
    k0 = np.full(packed.slots, 3, np.int32)

    xla = PeelExecutor(
        granularity="fine", mode="owner", backend="xla",
        window=window, chunk=CHUNK,
    )
    st_x = xla.peel(packed.problem, slot_ids=slot_ids, k0=k0)

    fused = PeelExecutor(
        backend="fused", window=window, chunk=CHUNK,
        fused_config=FusedConfig(block=32, schedule=schedule),
    )
    st_f = fused.peel(packed.problem, slot_ids=slot_ids, k0=k0)
    _assert_states_equal(st_x, st_f)
    assert fused.dispatches == 1  # the whole peel is still ONE dispatch


def test_fused_frozen_lanes_bit_identical_to_xla():
    """The streaming form: half the lanes frozen at their known
    trussness, the rest re-peeled — fused must match the unfused peel
    bit-for-bit and both must land on the oracle."""
    g = rmat(5, 6, seed=3)
    oracle = trussness_numpy(g)
    packed, slot_ids, window = _packed_batch([g])
    p = packed.problem
    colidx = np.asarray(p.colidx)
    real = colidx != 0
    lanes = np.arange(colidx.shape[0])
    frozen = real & (lanes % 2 == 0)
    alive0 = real & ~frozen
    frozen_truss = np.zeros(colidx.shape[0], np.int32)
    frozen_truss[: oracle.shape[0]] = np.where(
        frozen[: oracle.shape[0]], oracle, 0
    )
    kwargs = dict(
        slot_ids=slot_ids,
        k0=np.array([3], np.int32),
        alive0=alive0,
        frozen=frozen,
        frozen_truss=frozen_truss,
    )
    st_x = PeelExecutor(
        granularity="fine", mode="owner", backend="xla",
        window=window, chunk=CHUNK,
    ).peel(p, **kwargs)
    st_f = PeelExecutor(backend="fused", window=window, chunk=CHUNK).peel(
        p, **kwargs
    )
    _assert_states_equal(st_x, st_f)
    assert np.array_equal(
        np.asarray(st_f.trussness)[: oracle.shape[0]], oracle
    )


def test_fused_solve_matches_xla_across_workloads():
    g = barabasi(60, 4, seed=1)
    queries = lambda: [  # noqa: E731
        TrussQuery.ktruss(g, k=3),
        TrussQuery.kmax(g),
        TrussQuery.decompose(g),
    ]
    ref = solve(queries(), backend="fine/xla/aligned", chunk=CHUNK, max_batch=4)
    got = solve(queries(), backend="fine/fused/aligned", chunk=CHUNK, max_batch=4)
    assert np.array_equal(ref[0].alive, got[0].alive)
    assert np.array_equal(ref[0].support, got[0].support)
    assert ref[1] == got[1]
    assert np.array_equal(ref[2].trussness, got[2].trussness)


# --------------------------------------------------------------------- #
# (b) Aligned-layout tiling validation
# --------------------------------------------------------------------- #
def test_validate_fused_tiling_accepts_aligned_pack():
    packed, _, _ = _packed_batch([rmat(5, 6, seed=1), erdos(25, 4.0, seed=0)])
    block = FusedConfig().clamp(packed.slot_nnz).block
    validate_fused_tiling(packed.problem, slots=packed.slots, block=block)


def test_validate_fused_tiling_rejects_straddling_block():
    packed, _, _ = _packed_batch([rmat(5, 6, seed=1), erdos(25, 4.0, seed=0)])
    with pytest.raises(InvalidGraphError) as ei:
        validate_fused_tiling(
            packed.problem, slots=packed.slots, block=2 * packed.slot_nnz
        )
    assert ei.value.kind == "fused_tiling"
    assert ei.value.slot == 1  # the first straddled band boundary


def test_validate_fused_tiling_names_spilling_slot():
    packed, _, _ = _packed_batch([rmat(5, 6, seed=1), erdos(25, 4.0, seed=0)])
    p = packed.problem
    rowptr = np.asarray(p.rowptr).copy()
    # Shift slot 0's first non-empty row so its lanes spill into slot 1.
    deg = np.asarray(p.deg)
    v = int(np.argmax(deg[1:] > 0)) + 1
    rowptr[v - 1] = packed.slot_nnz - 1
    bad = p._replace(rowptr=rowptr)
    with pytest.raises(InvalidGraphError) as ei:
        validate_fused_tiling(bad, slots=packed.slots, block=32)
    assert ei.value.kind == "fused_tiling"
    assert ei.value.slot == 0
    assert f"row {v}" in str(ei.value)


def test_fused_executor_validates_before_dispatch():
    packed, slot_ids, window = _packed_batch(
        [rmat(5, 6, seed=1), erdos(25, 4.0, seed=0)]
    )
    exe = PeelExecutor(
        backend="fused", window=window, chunk=CHUNK,
        fused_config=FusedConfig(block=32),
    )
    rowptr = np.asarray(packed.problem.rowptr).copy()
    deg = np.asarray(packed.problem.deg)
    v = int(np.argmax(deg[1:] > 0)) + 1
    rowptr[v - 1] = packed.slot_nnz - 1
    with pytest.raises(InvalidGraphError):
        exe.peel(
            packed.problem._replace(rowptr=rowptr),
            slot_ids=slot_ids,
            k0=np.full(packed.slots, 3, np.int32),
        )


def test_fused_rejects_mesh():
    with pytest.raises(ValueError, match="mesh|shard"):
        PeelExecutor(backend="fused", window=32, mesh=object())


# --------------------------------------------------------------------- #
# (c) Auto rule: heavy imbalance upgrades the hand-kernel path to fused
# --------------------------------------------------------------------- #
def _stats(coarse_imbalance, lane_eff):
    return ImbalanceStats(
        name="synthetic", n=100, nnz=1000, max_degree=50, mean_degree=10.0,
        coarse_imbalance=coarse_imbalance, fine_imbalance=1.5,
        coarse_lane_efficiency=lane_eff, fine_lane_efficiency=0.9,
        coarse_tasks=100, fine_tasks=1000,
    )


def test_choose_backend_upgrades_heavy_imbalance_to_fused():
    heavy = _stats(coarse_imbalance=20.0, lane_eff=0.05)
    assert choose_backend(heavy, kernel="pallas", layout="aligned") == (
        BackendKey("fine", "fused", "aligned")
    )
    # moderate imbalance stays on the unfused Pallas kernel
    mild = _stats(coarse_imbalance=4.0, lane_eff=0.3)
    assert choose_backend(mild, kernel="pallas", layout="aligned") == (
        BackendKey("fine", "pallas", "aligned")
    )
    # the XLA path never upgrades (fused is the hand-kernel family)
    assert choose_backend(heavy, kernel="xla", layout="aligned") == (
        BackendKey("fine", "xla", "aligned")
    )
    # no fused/contig variant exists: layout="contig" never upgrades
    assert choose_backend(heavy, kernel="pallas", layout="contig") == (
        BackendKey("fine", "pallas", "contig")
    )


# --------------------------------------------------------------------- #
# (d) Autotune configs and store
# --------------------------------------------------------------------- #
def test_fused_config_validation_and_clamp():
    with pytest.raises(ValueError):
        FusedConfig(block=100)  # not a power of two
    with pytest.raises(ValueError):
        FusedConfig(schedule="magic")
    cfg = FusedConfig(block=256, schedule="bsearch", xla_flags=["--x"])
    assert cfg.clamp(64) == FusedConfig(block=64, schedule="bsearch",
                                        xla_flags=("--x",))
    assert cfg.clamp(512) is cfg
    assert FusedConfig.from_signature(cfg.signature()) == cfg


def test_candidate_configs_clamped_and_deduped():
    cands = candidate_configs(64)
    assert all(c.block <= 64 for c in cands)
    sigs = [c.signature() for c in cands]
    assert len(sigs) == len(set(sigs))


def test_autotune_store_roundtrip(tmp_path):
    path = tmp_path / "autotune.json"
    bucket, slots = (32, 128, 32), 2
    store = AutotuneStore(path)
    assert store.get(bucket, slots) is None
    winner = FusedConfig(block=32, schedule="bsearch")
    store.put(bucket, slots, winner, stats={"best_s": 0.01})
    assert store.get(bucket, slots) == winner
    # a FRESH store (new process stand-in) replays the same config
    assert AutotuneStore(path).get(bucket, slots) == winner
    # unknown (bucket, slots) falls back to the stock default
    assert AutotuneStore(path).get(bucket, 4) is None
    assert lookup(bucket, slots, default=FusedConfig()) == FusedConfig()


def test_autotune_fused_sweeps_and_persists(tmp_path):
    g = erdos(40, 4.0, seed=0)
    bucket = bucket_for(g, chunk=CHUNK)
    store = AutotuneStore(tmp_path / "autotune.json")
    candidates = (
        FusedConfig(block=32, schedule="compare"),
        FusedConfig(block=32, schedule="bsearch"),
    )
    winner, rows = autotune_fused(
        bucket, 1, graphs=[g], chunk=CHUNK, candidates=candidates,
        repeats=1, store=store,
    )
    assert winner in candidates
    assert len(rows) == 2 and all(r["best_s"] > 0 for r in rows)
    assert AutotuneStore(store.path).get(bucket, 1) == winner


_PERSIST_SCRIPT = """
import sys

import numpy as np

from repro.api.cache import bucket_for, enable_persistent_cache
from repro.graphs import erdos
from repro.kernels import autotune
from repro.kernels.autotune import FusedConfig

cache_dir, phase = sys.argv[1], sys.argv[2]
enable_persistent_cache(cache_dir)
g = erdos(40, 4.0, seed=0)
bucket = bucket_for(g, chunk=64)
if phase == "tune":
    # Candidates exclude the stock default so a replay is distinguishable
    # from a store miss.
    winner, _ = autotune.autotune_fused(
        bucket, 1, graphs=[g], chunk=64,
        candidates=(FusedConfig(block=32, schedule="bsearch"),
                    FusedConfig(block=16, schedule="bsearch")),
        repeats=1,
    )
    print(f"PERSIST_WINNER={winner.signature()}")
else:
    replayed = autotune.lookup(bucket, 1)
    print(f"PERSIST_WINNER={replayed.signature()}")
    from repro.api import Session, TrussQuery

    s = Session(backend="fine/fused/aligned", chunk=64, max_batch=1,
                cache_dir=cache_dir)
    variant = s.planner.cache_variant(s.planner.backend, bucket, 1)
    print(f"PERSIST_VARIANT_SIG={variant[-1]}")
    from repro.core import trussness_numpy

    dec = s.solve([TrussQuery.decompose(g)])[0]
    assert np.array_equal(dec.trussness, trussness_numpy(g))
    print("PERSIST_PARITY=ok")
"""


def _run_persist(cache_dir: str, phase: str) -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = (
        os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _PERSIST_SCRIPT, cache_dir, phase],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return dict(
        line.split("=", 1)
        for line in proc.stdout.splitlines()
        if line.startswith("PERSIST_")
    )


def test_autotuned_config_replays_across_processes(tmp_path):
    """Acceptance: a fresh process replays the tuned config from the
    store next to the persistent compile cache, folds it into its
    compile-cache variant key, and still matches the oracle."""
    cache_dir = str(tmp_path / "cache")
    tuned = _run_persist(cache_dir, "tune")
    assert os.path.exists(os.path.join(cache_dir, "autotune.json"))
    replay = _run_persist(cache_dir, "replay")
    assert replay["PERSIST_WINNER"] == tuned["PERSIST_WINNER"]
    # a non-default winner proves the value came from disk, not the stock
    # fallback, and the planner folds it into the executable's cache key
    assert replay["PERSIST_WINNER"] != str(FusedConfig().signature())
    assert replay["PERSIST_VARIANT_SIG"] == tuned["PERSIST_WINNER"]
    assert replay["PERSIST_PARITY"] == "ok"
