"""Fault-domain isolation for the batched peel path (repro.resilience).

What's covered:

* the typed failure taxonomy (``repro.errors``) and its context fields;
* CSR invariant validation at Graph construction — every ``kind`` of
  violation raises :class:`InvalidGraphError` naming the first bad row,
  driven by the deterministic ``poison_csr_arrays`` corpus;
* the fault-injection harness: spec gating (times/skip/p/where), seeded
  determinism, the ``REPRO_FAULTS`` mini-language, context-plan scoping;
* retry/backoff (on the fake clock — no sleeping), registry fallback
  chains, quarantine of poisoned batch members with bit-identical
  survivors, and batch bisection when a fault has no attribution;
* streaming checkpoint/restore: atomic write, checksum/version/shape
  verification, restore-equivalence (a restored session continues
  bit-identically), and auto-checkpoint retention.
"""

import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import (
    CheckpointError,
    CompileError,
    DeviceError,
    InvalidGraphError,
    QueryFailedError,
    Session,
    TrussError,
    TrussQuery,
    fallback_backends,
)
from repro.api.cache import CompileCache
from repro.api.registry import BackendKey
from repro.core import trussness_numpy
from repro.graphs import CSRGraph, erdos, validate_csr
from repro.obs.clock import FakeClock, use_clock
from repro.resilience import (
    CHECKPOINT_VERSION,
    FaultPlan,
    FaultSpec,
    RetryPolicy,
    latest_checkpoint,
    load_checkpoint,
    parse_faults,
    restore_session,
    save_checkpoint,
    use_plan,
)
from repro.resilience.faults import poison_csr_arrays
from repro.stream.delta import EdgeBatch
from repro.stream.session import StreamingTrussSession

FAST_RETRY = RetryPolicy(backoff_base_s=0.0)


def tiny(seed=0):
    return erdos(50, 4.0, seed=seed)


# --------------------------------------------------------------------- #
# (a) Taxonomy
# --------------------------------------------------------------------- #
def test_taxonomy_hierarchy_and_context():
    e = DeviceError("boom", oom=True, bucket="b", backend="k", slot=2, site="x")
    assert isinstance(e, TrussError) and isinstance(e, RuntimeError)
    assert e.oom and e.slot == 2
    ctx = e.context()
    assert ctx["slot"] == 2 and ctx["site"] == "x"
    assert isinstance(InvalidGraphError("bad"), ValueError)
    assert isinstance(CompileError("bad"), RuntimeError)
    assert isinstance(QueryFailedError("bad", attempts=3), RuntimeError)
    assert isinstance(CheckpointError("bad", path="/p"), RuntimeError)
    # legacy except-clauses keep working through the taxonomy
    with pytest.raises(ValueError):
        raise InvalidGraphError("still a ValueError")


# --------------------------------------------------------------------- #
# (b) CSR invariant validation at construction
# --------------------------------------------------------------------- #
def test_validate_csr_names_first_violating_row():
    # row 2 (1-based) holds a self-loop
    with pytest.raises(InvalidGraphError) as ei:
        CSRGraph(3, np.array([0, 1, 2, 2]), np.array([2, 2], np.int32))
    assert ei.value.kind == "self_loop"
    assert ei.value.row == 2
    # duplicate column within row 1
    with pytest.raises(InvalidGraphError) as ei:
        CSRGraph(3, np.array([0, 2, 2, 2]), np.array([2, 2], np.int32))
    assert ei.value.kind == "duplicate"
    assert ei.value.row == 1
    # column out of range
    with pytest.raises(InvalidGraphError) as ei:
        CSRGraph(2, np.array([0, 1, 1]), np.array([7], np.int32))
    assert ei.value.kind == "col_range"
    # rowptr not monotone
    with pytest.raises(InvalidGraphError) as ei:
        CSRGraph(3, np.array([0, 2, 1, 2]), np.array([2, 3], np.int32))
    assert ei.value.kind == "rowptr_unsorted"
    # validate=False is the test/tool escape hatch
    g = CSRGraph(2, np.array([0, 1, 1]), np.array([7], np.int32), validate=False)
    assert g.nnz == 1


def test_poison_corpus_always_caught():
    """Every deterministic corruption of a real graph is caught with the
    kind the corruptor promised."""
    g = erdos(40, 5.0, seed=1)
    for seed in range(24):
        n, rowptr, colidx, kind = poison_csr_arrays(
            g.n, g.rowptr, g.colidx, seed=seed
        )
        with pytest.raises(InvalidGraphError) as ei:
            validate_csr(n, rowptr, colidx, name=f"poison{seed}")
        assert ei.value.kind == kind, f"seed {seed}: {ei.value.kind} != {kind}"
        assert ei.value.row is not None and 1 <= ei.value.row <= n


def test_valid_graphs_pass_validation(small_graphs):
    for g in small_graphs:
        validate_csr(g.n, g.rowptr, g.colidx)  # no raise
        g.undirected_csr()  # symmetrized construction re-validates


# --------------------------------------------------------------------- #
# (c) Fault plan mechanics
# --------------------------------------------------------------------- #
def test_fault_spec_gating_times_skip_where():
    plan = FaultPlan(
        [
            FaultSpec("dispatch", times=2, skip=1),
            FaultSpec("poison", times=None, where=(("query", 7),)),
        ]
    )
    # skip=1: first hit passes, next two fire, then exhausted
    assert plan.should_fire("dispatch", {}) is None
    assert plan.should_fire("dispatch", {}) is not None
    assert plan.should_fire("dispatch", {}) is not None
    assert plan.should_fire("dispatch", {}) is None
    # where: equality and tuple-membership
    assert plan.should_fire("poison", {"query": 3}) is None
    assert plan.should_fire("poison", {"query": 7}) is not None
    assert plan.should_fire("poison", {"queries": (1, 7, 9), "query": 7}) is not None
    plan.reset()
    assert plan.fired() == 0
    assert plan.should_fire("dispatch", {}) is None  # skip applies again


def test_fault_probability_is_seed_deterministic():
    def draw(seed):
        plan = FaultPlan([FaultSpec("dispatch", times=None, p=0.5)], seed=seed)
        return [plan.should_fire("dispatch", {}) is not None for _ in range(32)]

    a, b, c = draw(1), draw(1), draw(2)
    assert a == b  # same seed -> same firing pattern
    assert a != c  # different seed -> different pattern (w.h.p.)
    assert any(a) and not all(a)  # p=0.5 actually gates


def test_parse_faults_mini_language():
    plan = parse_faults(
        "dispatch:times=1;device_oom:skip=2:times=*:p=0.25;"
        "poison:where.query=7:msg=bad member;clock_skew:skew=9.5;seed=11"
    )
    assert plan.seed == 11
    d, o, p, c = plan.specs
    assert (d.site, d.times) == ("dispatch", 1)
    assert (o.skip, o.times, o.p) == (2, None, 0.25)
    assert p.where == (("query", 7),) and p.message == "bad member"
    assert c.skew_s == 9.5
    with pytest.raises(ValueError):
        parse_faults("warp_core_breach")
    with pytest.raises(ValueError):
        parse_faults("dispatch:frequency=11")


def test_faults_env_var(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert FaultPlan.from_env() is None
    monkeypatch.setenv("REPRO_FAULTS", "dispatch:times=1;seed=5")
    plan = FaultPlan.from_env()
    assert plan.seed == 5 and plan.specs[0].site == "dispatch"
    # Session picks the env plan up by default
    s = Session(backend="fine/xla/aligned", max_batch=2, chunk=64, retry=FAST_RETRY)
    assert s.faults is not None and s.faults.specs[0].site == "dispatch"


# --------------------------------------------------------------------- #
# (d) Retry policy + fallback chain
# --------------------------------------------------------------------- #
def test_retry_policy_backoff_schedule():
    p = RetryPolicy(backoff_base_s=0.01, backoff_mult=2.0, backoff_max_s=0.05)
    assert [p.delay(i) for i in (1, 2, 3, 4, 5)] == [
        0.01,
        0.02,
        0.04,
        0.05,
        0.05,  # capped
    ]
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


def test_fallback_chain_shapes():
    assert fallback_backends("fine/xla/aligned") == (
        BackendKey("coarse", "xla", "aligned"),
    )
    assert fallback_backends("fine/pallas/contig") == (
        BackendKey("fine", "xla", "contig"),
        BackendKey("coarse", "xla", "contig"),
    )
    # the fused megakernel steps down to its unfused Pallas twin first,
    # then XLA, then coarse — layout preserved at every step
    assert fallback_backends("fine/fused/aligned") == (
        BackendKey("fine", "pallas", "aligned"),
        BackendKey("fine", "xla", "aligned"),
        BackendKey("coarse", "xla", "aligned"),
    )
    # layout is preserved down the whole chain (mesh safety)
    assert all(k.layout == "aligned" for k in fallback_backends("fine/pallas/aligned"))
    assert all(k.layout == "aligned" for k in fallback_backends("fine/fused/aligned"))
    # the last resort has nowhere to fall
    assert fallback_backends("coarse/xla/contig") == ()


def test_compile_cache_wraps_builder_failures():
    cache = CompileCache(lambda key: (_ for _ in ()).throw(RuntimeError("no exe")))
    with pytest.raises(CompileError) as ei:
        cache.get(("bucket",), 1, "variant")
    assert "no exe" in str(ei.value)
    assert cache.stats.compiles == 0  # failed builds are not compiles


# --------------------------------------------------------------------- #
# (e) Batch fault isolation end to end
# --------------------------------------------------------------------- #
def _oracle(g):
    return trussness_numpy(g)


def test_transient_dispatch_fault_is_retried_under_fake_time():
    g = tiny()
    clk = FakeClock()
    with use_clock(clk):
        s = Session(
            backend="fine/xla/aligned",
            max_batch=2,
            chunk=64,
            faults=FaultPlan([FaultSpec("dispatch", times=1)]),
            retry=RetryPolicy(backoff_base_s=0.5),
        )
        dec = s.solve([TrussQuery.decompose(g)])[0]
    assert np.array_equal(dec.trussness, _oracle(g))
    assert s.retries == 1 and s.queries_failed == 0
    assert s.stats()["faults_injected"] == 1
    # backoff waited on the fake clock, not the wall
    assert clk.now() >= 0.5


def test_oom_fault_exhausts_retries_then_falls_back():
    g = tiny()
    s = Session(
        backend="fine/xla/aligned",
        max_batch=2,
        chunk=64,
        faults=FaultPlan([FaultSpec("device_oom", times=None)]),  # never heals
        retry=RetryPolicy(max_attempts=2, backoff_base_s=0.0),
    )
    fut = s.submit(TrussQuery.decompose(g))
    s.flush()
    with pytest.raises(QueryFailedError) as ei:
        fut.result()
    err = ei.value
    assert isinstance(err.cause, DeviceError) and err.cause.oom
    assert err.attempts >= 2  # retried on the primary before falling back
    assert tuple(str(b) for b in err.backends_tried) == (
        "fine/xla/aligned",
        "coarse/xla/aligned",
    )
    assert s.backend_fallbacks == 1 and s.queries_failed == 1


def test_compile_fault_falls_back_bit_identically():
    g = tiny()
    s = Session(
        backend="fine/xla/aligned",
        max_batch=2,
        chunk=64,
        faults=FaultPlan([FaultSpec("compile", times=1)]),
        retry=FAST_RETRY,
    )
    dec = s.solve([TrussQuery.decompose(g)])[0]
    assert np.array_equal(dec.trussness, _oracle(g))  # coarse parity
    assert s.backend_fallbacks == 1 and s.retries == 0


def test_poisoned_fused_compile_lands_on_xla_bit_identically():
    """A fused megakernel whose compile is poisoned walks its chain
    (fused -> pallas -> xla); poisoning the first two steps lands the
    batch on fine/xla with oracle-identical results."""
    g = tiny()
    s = Session(
        backend="fine/fused/aligned",
        max_batch=2,
        chunk=64,
        faults=FaultPlan(
            [
                FaultSpec(
                    "compile", times=1, where=(("backend", "fine/fused/aligned"),)
                ),
                FaultSpec(
                    "compile", times=1, where=(("backend", "fine/pallas/aligned"),)
                ),
            ]
        ),
        retry=FAST_RETRY,
    )
    dec = s.solve([TrussQuery.decompose(g)])[0]
    assert np.array_equal(dec.trussness, _oracle(g))
    assert s.backend_fallbacks == 2 and s.retries == 0


def test_poison_member_quarantined_survivors_bit_identical():
    gs = [tiny(seed=i) for i in range(3)]
    s = Session(
        backend="fine/xla/aligned", max_batch=4, chunk=64, retry=FAST_RETRY
    )
    futs = [s.submit(TrussQuery.decompose(g)) for g in gs]
    target = futs[1].request.id
    s.faults = FaultPlan(
        [FaultSpec("poison", times=None, where=(("query", target),))]
    )
    s.flush()
    with pytest.raises(QueryFailedError) as ei:
        futs[1].result()
    assert ei.value.query_id == target
    assert isinstance(ei.value.cause, InvalidGraphError)
    assert ei.value.cause.injected
    for i in (0, 2):  # batch-mates resolved bit-identically
        assert np.array_equal(futs[i].result().trussness, _oracle(gs[i]))
    assert s.queries_quarantined == 1
    assert s.queries_failed == 1


def test_unattributed_fault_bisects_to_isolate():
    gs = [tiny(seed=i) for i in range(4)]
    s = Session(
        backend="fine/xla/aligned",
        max_batch=4,
        chunk=64,
        faults=FaultPlan([FaultSpec("dispatch", times=None)]),  # hits everyone
        retry=RetryPolicy(max_attempts=1, backoff_base_s=0.0),
    )
    futs = [s.submit(TrussQuery.decompose(g)) for g in gs]
    s.flush()
    for f in futs:
        with pytest.raises(QueryFailedError):
            f.result()
    assert s.batch_bisects >= 1  # the batch was split to isolate
    assert s.queries_failed == 4


def test_clock_skew_fault_advances_fake_clock_only():
    g = tiny()
    clk = FakeClock()
    with use_clock(clk):
        s = Session(
            backend="fine/xla/aligned",
            max_batch=2,
            chunk=64,
            faults=FaultPlan([FaultSpec("clock_skew", times=1, skew_s=123.0)]),
            retry=FAST_RETRY,
        )
        dec = s.solve([TrussQuery.decompose(g)])[0]
        assert clk.now() >= 123.0  # time jumped mid-dispatch
    assert np.array_equal(dec.trussness, _oracle(g))  # results unaffected
    assert s.stats()["faults_injected"] == 1


def test_peel_iteration_cap_is_a_typed_device_error():
    g = erdos(60, 6.0, seed=2)
    s = Session(
        backend="fine/xla/aligned",
        max_batch=1,
        chunk=64,
        max_iters=1,  # provably too few trips to finish
        retry=RetryPolicy(max_attempts=1, backoff_base_s=0.0, fallback=False),
    )
    fut = s.submit(TrussQuery.decompose(g))
    s.flush()
    with pytest.raises(QueryFailedError) as ei:
        fut.result()
    assert isinstance(ei.value.cause, DeviceError)
    assert "iteration cap" in str(ei.value.cause)


def test_use_plan_scoping():
    plan = FaultPlan([FaultSpec("dispatch", times=None)])
    with use_plan(plan):
        with use_plan(None):  # inner fault-free scope masks the outer plan
            from repro.resilience.faults import current_plan

            assert current_plan() is None
        from repro.resilience.faults import current_plan

        assert current_plan() is plan


# --------------------------------------------------------------------- #
# (f) Streaming checkpoint / restore
# --------------------------------------------------------------------- #
def _stream_graph(seed=0):
    return erdos(40, 5.0, seed=seed)


def _batches(rng, g, count):
    """Deterministic mixed insert/delete batches against evolving state."""
    out = []
    for _ in range(count):
        ins = [
            (int(rng.integers(g.n)), int(rng.integers(g.n))) for _ in range(3)
        ]
        out.append(EdgeBatch.of(inserts=[(u, v) for u, v in ins if u != v]))
    return out


def test_checkpoint_roundtrip(tmp_path):
    g = _stream_graph()
    t = trussness_numpy(g)
    path = str(tmp_path / "state.npz")
    save_checkpoint(path, graph=g, trussness=t, tri_keys=None, updates_applied=3)
    ck = load_checkpoint(path)
    assert ck.graph.n == g.n and ck.graph.nnz == g.nnz
    assert np.array_equal(ck.graph.colidx, g.colidx)
    assert np.array_equal(ck.trussness, t)
    assert ck.tri_keys is None
    assert ck.meta["version"] == CHECKPOINT_VERSION
    assert ck.meta["updates_applied"] == 3
    assert ck.kmax == int(t.max(initial=0))


def test_checkpoint_refuses_inconsistent_state(tmp_path):
    g = _stream_graph()
    with pytest.raises(CheckpointError):
        save_checkpoint(
            str(tmp_path / "bad.npz"),
            graph=g,
            trussness=np.zeros(g.nnz + 1, np.int32),
        )


def test_checkpoint_detects_corruption(tmp_path):
    g = _stream_graph()
    path = str(tmp_path / "state.npz")
    save_checkpoint(path, graph=g, trussness=trussness_numpy(g))
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF  # flip one byte mid-file
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CheckpointError) as ei:
        load_checkpoint(path)
    assert ei.value.path == path


def test_checkpoint_missing_file_and_version(tmp_path):
    with pytest.raises(CheckpointError):
        load_checkpoint(str(tmp_path / "nope.npz"))
    assert latest_checkpoint(str(tmp_path / "empty-dir")) is None


def test_restored_session_continues_bit_identically(tmp_path):
    """The acceptance property: crash after checkpoint, restore, apply the
    same updates — state equals the session that never crashed."""
    rng = np.random.default_rng(7)
    g = _stream_graph()
    live = StreamingTrussSession.for_graph(g, backend="fine/xla/aligned", chunk=64)
    warm = _batches(rng, g, 2)
    tail = _batches(rng, g, 2)
    for b in warm:
        live.update(b, strict=False)
    path = live.checkpoint(str(tmp_path / "mid.npz"))

    # "crash": rebuild from disk only
    restored = restore_session(path, backend="fine/xla/aligned", chunk=64)
    assert np.array_equal(restored.trussness, live.trussness)
    assert restored._tri_cache is not None  # no re-enumeration needed
    assert restored._tri_cache.num_triangles == live._tri_cache.num_triangles

    for b in tail:
        ra = live.update(b, strict=False)
        rb = restored.update(b, strict=False)
        assert np.array_equal(ra.trussness, rb.trussness)
        assert ra.kmax == rb.kmax
    # full-state agreement with the from-scratch oracle
    assert np.array_equal(restored.trussness, trussness_numpy(restored.graph))


def test_auto_checkpoint_retention(tmp_path):
    rng = np.random.default_rng(3)
    g = _stream_graph(seed=1)
    ckdir = str(tmp_path / "ck")
    st = StreamingTrussSession.for_graph(
        g,
        backend="fine/xla/aligned",
        chunk=64,
        checkpoint_dir=ckdir,
        checkpoint_every=1,
    )
    for b in _batches(rng, g, 3):
        st.update(b, strict=False)
    files = sorted(os.listdir(ckdir))
    assert len(files) == 2  # keep-last-two retention
    assert st.checkpoints_written == 3
    assert st.stats()["checkpoints_written"] == 3
    # the latest checkpoint restores to the current committed state
    restored = StreamingTrussSession.restore(
        latest_checkpoint(ckdir), backend="fine/xla/aligned", chunk=64
    )
    assert np.array_equal(restored.trussness, st.trussness)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_checkpoint_restore_property(seed):
    """Property form: for random update streams and a random split point,
    restore-then-continue equals never-crashed."""
    import tempfile

    rng = np.random.default_rng(seed)
    g = _stream_graph(seed=seed % 5)
    batches = _batches(rng, g, 3)
    cut = int(rng.integers(1, len(batches) + 1))

    live = StreamingTrussSession.for_graph(g, backend="fine/xla/aligned", chunk=64)
    for b in batches[:cut]:
        live.update(b, strict=False)
    with tempfile.TemporaryDirectory() as tmp:
        path = live.checkpoint(os.path.join(tmp, "cut.npz"))
        restored = restore_session(path, backend="fine/xla/aligned", chunk=64)
    for b in batches[cut:]:
        ra = live.update(b, strict=False)
        rb = restored.update(b, strict=False)
        assert np.array_equal(ra.trussness, rb.trussness)
    assert np.array_equal(restored.trussness, live.trussness)
