"""Fault tolerance + distributed planning (sharding rules, elastic mesh)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import mesh_shape_for, param_specs, state_specs, batch_specs
from repro.models import Model
from repro.train import StepWatchdog, StragglerStats, run_with_retries

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ #
# Straggler watchdog + retry policy
# ------------------------------------------------------------------ #
def test_watchdog_flags_injected_straggler():
    wd = StepWatchdog(threshold=3.0)
    for _ in range(10):
        wd.observe(0.1)
    assert not wd.observe(0.11)
    assert wd.observe(1.0)  # 10× the EMA: straggler
    assert wd.stats.stragglers == 1
    # EMA not poisoned by the straggler
    assert wd.ema < 0.2


def test_run_with_retries_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("node lost")
        return "ok"

    stats = StragglerStats()
    assert run_with_retries(flaky, retries=3, stats=stats) == "ok"
    assert stats.retries == 2

    def hopeless():
        raise RuntimeError("dead")

    with pytest.raises(RuntimeError):
        run_with_retries(hopeless, retries=1, stats=stats)
    assert stats.failures == 1


# ------------------------------------------------------------------ #
# Elastic mesh planning
# ------------------------------------------------------------------ #
@pytest.mark.parametrize(
    "n,expect_shape,expect_axes",
    [
        (512, (2, 16, 16), ("pod", "data", "model")),
        (256, (16, 16), ("data", "model")),
        (480, (30, 16), ("data", "model")),  # 2 pods minus a rack: downscale
        (1024, (4, 16, 16), ("pod", "data", "model")),
        (100, (25, 4), ("data", "model")),  # width shrinks 16->4
        (7, (7, 1), ("data", "model")),
    ],
)
def test_mesh_shape_for(n, expect_shape, expect_axes):
    shape, axes = mesh_shape_for(n, model_width=16, pod_size=256)
    assert shape == expect_shape and axes == expect_axes
    assert int(np.prod(shape)) <= n


# ------------------------------------------------------------------ #
# Sharding rules: divisibility fallbacks on the production mesh shapes
# ------------------------------------------------------------------ #
def _fake_mesh(shape, names):
    """AbstractMesh is enough for spec planning (no devices needed)."""
    from jax.sharding import AbstractMesh

    return AbstractMesh(shape, names)


def test_param_specs_fallbacks_qwen2_heads():
    """14 heads don't split 16-way -> replicate heads (NEVER shard head_dim:
    a dh-sharded K turns flash score chunks into partial-sum all-reduces —
    EXPERIMENTS §Perf iteration 8)."""
    cfg = get_config("qwen2-0.5b")
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, KEY)
    mesh = _fake_mesh((16, 16), ("data", "model"))
    specs = param_specs(shapes, mesh)
    q = specs["stack"]["scan"][0]["mixer"]["q"]["kernel"]
    assert q == P(None, ("data",), None, None)  # scan dim + fsdp D only
    # divisible heads DO shard: gemma2 has 16 q heads
    cfg2 = get_config("gemma2-9b")
    shapes2 = jax.eval_shape(Model(cfg2).init, KEY)
    q2 = param_specs(shapes2, mesh)["stack"]["scan"][0]["mixer"]["q"]["kernel"]
    assert q2 == P(None, ("data",), "model", None)


def test_param_specs_seamless_vocab_fallback():
    """256206 vocab is indivisible by 16 and 32 -> embedding replicated."""
    cfg = get_config("seamless-m4t-medium")
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, KEY)
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    specs = param_specs(shapes, mesh)
    assert specs["embed"]["embedding"] == P(None, None)


def test_param_specs_moe_experts_sharded():
    cfg = get_config("kimi-k2-1t-a32b")
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, KEY)
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    specs = param_specs(shapes, mesh)
    gate = specs["stack"]["scan"][0]["moe"]["gate"]
    assert gate == P(None, "model", ("pod", "data"), None)


def test_state_specs_long_context_sequence_parallel():
    """batch=1 long-context cache falls back to sharding the window dim."""
    cfg = get_config("recurrentgemma-9b")
    model = Model(cfg)
    states = jax.eval_shape(lambda: model.init_states(1, 2048))
    mesh = _fake_mesh((16, 16), ("data", "model"))
    specs = state_specs(states, mesh)
    k_spec = specs["scan"][2]["cache"]["k"]
    assert k_spec == P(None, None, "model", None, None)  # seq dim sharded


def test_batch_specs_dp_or_replicated():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    b = {
        "tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32),
        "odd": jax.ShapeDtypeStruct((3, 5), jnp.float32),
    }
    specs = batch_specs(b, mesh)
    assert specs["tokens"] == P(("data",), None)
    assert specs["odd"] == P()
