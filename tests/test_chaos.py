"""Chaos suite: randomized fault storms against the batched peel path.

Run directly via ``make test-chaos`` (3 fixed seeds) or as part of the
full suite.  The contract under every storm is the same:

* queries the faults do not touch resolve **bit-identically** to the
  pure-numpy oracle — retries, backend fallback, and survivor
  re-dispatch are invisible in the results;
* queries a fault does hit raise exactly one typed error
  (:class:`QueryFailedError` carrying the right ``query_id`` and cause);
* the session survives and keeps serving afterwards.

When ``CHAOS_METRICS_OUT`` is set (the Makefile/CI do this), the shared
session's metrics snapshot — retries, fallbacks, quarantines, bisects,
faults injected — is written there as JSON so CI can archive what the
storm actually exercised.
"""

import json
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import QueryFailedError, Session, TrussQuery
from repro.core import trussness_numpy
from repro.graphs import erdos, rmat
from repro.resilience import RetryPolicy, parse_faults

SEEDS = (101, 202, 303)

# site -> REPRO_FAULTS clause (seed appended per test).  All transient
# (times=1) except poison, which is targeted separately below.
STORMS = {
    "none": None,
    "dispatch": "dispatch:times=1",
    "device_oom": "device_oom:times=1",
    "compile": "compile:times=1",
    "clock_skew": "clock_skew:times=1:skew=5.0",
}


@pytest.fixture(scope="module")
def chaos_session():
    s = Session(
        backend="fine/xla/aligned",
        max_batch=4,
        chunk=64,
        retry=RetryPolicy(backoff_base_s=0.0),
    )
    yield s
    out = os.environ.get("CHAOS_METRICS_OUT")
    if out:
        with open(out, "w") as fh:
            json.dump(s.stats(), fh, indent=2, sort_keys=True, default=str)


def _graphs(seed, count=3):
    return [erdos(50, 4.0, seed=seed + i) for i in range(count)]


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("storm", sorted(STORMS))
def test_transient_storms_resolve_bit_identical(chaos_session, storm, seed):
    """One transient fault per batch: every query still matches the oracle."""
    s = chaos_session
    gs = _graphs(seed)
    futs = [s.submit(TrussQuery.decompose(g)) for g in gs]
    clause = STORMS[storm]
    s.faults = parse_faults(f"{clause};seed={seed}") if clause else None
    try:
        s.flush()
        for g, f in zip(gs, futs):
            assert np.array_equal(f.result().trussness, trussness_numpy(g)), (
                storm,
                seed,
                g.name,
            )
    finally:
        s.faults = None


@pytest.mark.parametrize("seed", SEEDS)
def test_poison_storm_isolates_exactly_the_target(chaos_session, seed):
    """A poisoned member fails alone; its batch-mates are untouched."""
    s = chaos_session
    gs = _graphs(seed)
    futs = [s.submit(TrussQuery.decompose(g)) for g in gs]
    target = futs[1].request.id
    s.faults = parse_faults(f"poison:times=*:where.query={target};seed={seed}")
    try:
        s.flush()
        with pytest.raises(QueryFailedError) as ei:
            futs[1].result()
        assert ei.value.query_id == target
        assert ei.value.cause is not None and ei.value.cause.injected
        for i in (0, 2):
            assert np.array_equal(
                futs[i].result().trussness, trussness_numpy(gs[i])
            ), (seed, i)
    finally:
        s.faults = None


@pytest.mark.parametrize("seed", SEEDS)
def test_unlimited_oom_storm_fails_typed_then_session_recovers(
    chaos_session, seed
):
    """A storm that never heals exhausts the whole chain: the query gets
    one typed error (not a hang, not a bare RuntimeError), and the very
    next fault-free batch serves normally."""
    s = chaos_session
    g = erdos(50, 4.0, seed=seed)
    fut = s.submit(TrussQuery.decompose(g))
    s.faults = parse_faults(f"device_oom:times=*;seed={seed}")
    try:
        s.flush()
        with pytest.raises(QueryFailedError) as ei:
            fut.result()
        assert len(ei.value.backends_tried) >= 2  # the chain was walked
    finally:
        s.faults = None
    dec = s.solve([TrussQuery.decompose(g)])[0]
    assert np.array_equal(dec.trussness, trussness_numpy(g))


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    site=st.sampled_from(["dispatch", "device_oom", "compile", "clock_skew"]),
    times=st.integers(min_value=1, max_value=2),
)
def test_random_storm_property(seed, site, times):
    """Property form: for random (site, intensity, seed) storms on a fresh
    session, results are either bit-identical to the oracle or a typed
    QueryFailedError — never silent corruption."""
    skew = ":skew=2.5" if site == "clock_skew" else ""
    s = Session(
        backend="fine/xla/aligned",
        max_batch=2,
        chunk=64,
        retry=RetryPolicy(max_attempts=2, backoff_base_s=0.0),
        faults=parse_faults(f"{site}:times={times}{skew};seed={seed}"),
    )
    g = rmat(5, 4, seed=seed % 7)
    fut = s.submit(TrussQuery.decompose(g))
    s.flush()
    try:
        dec = fut.result()
    except QueryFailedError as e:
        assert e.query_id == fut.request.id
        assert e.cause is not None
    else:
        assert np.array_equal(dec.trussness, trussness_numpy(g))
