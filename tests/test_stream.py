"""Streaming K-truss subsystem: incremental == from-scratch, exactly.

The maintenance invariant is absolute — after any batch of edge
insertions/deletions, the session's trussness must be bit-identical to a
from-scratch ``decompose()`` of the mutated graph.  Covered here:

* delta application (edge-id maps, strict/lenient conflict handling);
* frontier soundness (edges outside the closure provably keep their
  trussness) against the independent numpy oracle;
* fixed-seed multi-step sessions across generator families, checked
  against both ``KTrussEngine.decompose()`` and ``trussness_numpy``;
* the hypothesis property test over random graphs and random batches;
* coalescing: many sessions' updates + a plain decompose share ONE
  dispatch, and empty-frontier updates cost zero dispatches;
* the slot-capacity ``ValueError`` satellite in ``graphs.pack``.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import KTrussEngine, trussness_numpy
from repro.graphs import clustered, erdos, from_edges, pack_problems, road
from repro.service import TrussService
from repro.stream import (
    EdgeBatch,
    StreamingTrussSession,
    apply_batch,
    compute_frontier,
    edge_triangles,
)


def _random_batch(rng, g, n_ins, n_del):
    """A batch of up-to-n_ins fresh inserts + n_del existing deletes."""
    existing = set(map(tuple, (g.edge_list() - 1)))
    ins = []
    for _ in range(8 * n_ins):
        if len(ins) == n_ins:
            break
        a, b = int(rng.integers(0, g.n)), int(rng.integers(0, g.n))
        if a != b and (min(a, b), max(a, b)) not in existing:
            ins.append((a, b))
            existing.add((min(a, b), max(a, b)))
    dels = [
        tuple(e - 1)
        for e in g.edge_list()[rng.permutation(g.nnz)[: min(n_del, g.nnz)]]
    ]
    return EdgeBatch.of(ins, dels)


# ------------------------------------------------------------------ #
# Delta application
# ------------------------------------------------------------------ #
def test_apply_batch_maps_and_strictness():
    g = erdos(40, 5.0, seed=0)
    rng = np.random.default_rng(1)
    batch = _random_batch(rng, g, 3, 2)
    d = apply_batch(g, batch)
    assert d.num_inserts == batch.inserts.shape[0]
    assert d.num_deletes == batch.deletes.shape[0]
    assert d.new_graph.nnz == g.nnz + d.num_inserts - d.num_deletes
    # Round trip: surviving old edges land where old2new says.
    el_old, el_new = g.edge_list(), d.new_graph.edge_list()
    surv = ~d.deleted_old
    assert np.array_equal(el_new[d.old2new[surv]], el_old[surv])
    # new2old inverts old2new on shared edges; inserted rows are -1.
    shared = d.new2old >= 0
    assert np.array_equal(d.old2new[d.new2old[shared]], np.nonzero(shared)[0])
    assert np.array_equal(~shared, d.inserted_new)

    # Strict mode rejects conflicting updates...
    dup_ins = tuple(el_old[0] - 1)
    existing = set(map(tuple, el_old - 1))
    missing = next(
        (a, b)
        for a in range(g.n)
        for b in range(a + 1, g.n)
        if (a, b) not in existing
    )
    with pytest.raises(ValueError, match="already exist"):
        apply_batch(g, EdgeBatch.of([dup_ins], []))
    with pytest.raises(ValueError, match="do not exist"):
        apply_batch(g, EdgeBatch.of([], [missing]))
    with pytest.raises(ValueError, match="both inserts and deletes"):
        apply_batch(g, EdgeBatch.of([missing], [missing]))
    # ...lenient mode drops them and no-ops.
    d2 = apply_batch(
        g, EdgeBatch.of([dup_ins, missing], [missing]), strict=False
    )
    assert d2.num_inserts == 0 and d2.num_deletes == 0
    assert d2.new_graph.nnz == g.nnz


def test_empty_batch_is_noop():
    g = clustered(2, 10, 0.7, seed=0)
    d = apply_batch(g, EdgeBatch.of())
    assert d.new_graph.nnz == g.nnz
    fr = compute_frontier(trussness_numpy(g), d)
    assert fr.size == 0


# ------------------------------------------------------------------ #
# Frontier soundness against the numpy oracle
# ------------------------------------------------------------------ #
def test_frontier_excluded_edges_keep_trussness():
    rng = np.random.default_rng(3)
    for seed in range(4):
        g = erdos(40, 6.0, seed=seed)
        t_old = trussness_numpy(g)
        d = apply_batch(g, _random_batch(rng, g, 2, 2))
        fr = compute_frontier(t_old, d)
        t_new = trussness_numpy(d.new_graph)
        keep = (d.new2old >= 0) & ~fr.frontier
        assert np.array_equal(
            t_new[keep], t_old[d.new2old[keep]]
        ), f"seed {seed}: frontier missed a changed edge"
        # Inserted edges are always in the frontier.
        assert fr.frontier[d.inserted_new].all()


def test_triangle_enumeration_matches_support():
    from repro.core import support_numpy

    for g in [erdos(50, 6.0, seed=0), clustered(3, 12, 0.7, seed=1), road(6, 0.2, seed=2)]:
        tri = edge_triangles(g)
        # Every triangle contributes one support unit to each of its edges.
        s = np.bincount(tri.ravel(), minlength=g.nnz)
        assert np.array_equal(s, support_numpy(g)), g.name


# ------------------------------------------------------------------ #
# Fixed-seed multi-step sessions: bit-identical to from-scratch
# ------------------------------------------------------------------ #
def test_session_multi_step_identical_to_decompose():
    rng = np.random.default_rng(11)
    for g0 in [erdos(50, 6.0, seed=0), clustered(3, 12, 0.7, seed=1)]:
        sess = StreamingTrussSession.for_graph(g0, chunk=64)
        for step in range(4):
            res = sess.update(_random_batch(rng, sess.graph, 2, 1))
            eng = KTrussEngine(sess.graph, chunk=64)
            assert np.array_equal(
                res.trussness, eng.decompose().trussness
            ), f"{g0.name} step {step}"
            assert res.kmax == sess.kmax
            assert res.dispatches <= 1


def test_session_delete_only_and_grow_only():
    rng = np.random.default_rng(13)
    g = clustered(2, 12, 0.8, seed=5)
    sess = StreamingTrussSession.for_graph(g, chunk=64)
    res = sess.update(_random_batch(rng, sess.graph, 0, 5))
    assert np.array_equal(
        res.trussness, trussness_numpy(sess.graph).astype(res.trussness.dtype)
    )
    res = sess.update(_random_batch(rng, sess.graph, 6, 0))
    assert np.array_equal(
        res.trussness, trussness_numpy(sess.graph).astype(res.trussness.dtype)
    )


def test_empty_frontier_update_costs_zero_dispatches():
    # A 2x2 grid has no triangles: deleting an edge can change nothing.
    g = road(3, 0.0, seed=0)
    sess = StreamingTrussSession.for_graph(g, chunk=64)
    base = sess.service.stats()["device_dispatches"]
    e0 = tuple(sess.graph.edge_list()[0] - 1)
    res = sess.update(EdgeBatch.of([], [e0]))
    assert res.dispatches == 0 and res.frontier_size == 0
    assert sess.service.stats()["device_dispatches"] == base
    assert np.array_equal(res.trussness, trussness_numpy(sess.graph))


# ------------------------------------------------------------------ #
# Hypothesis property: random graphs x random batches
# ------------------------------------------------------------------ #
@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(min_value=8, max_value=28),
    m=st.integers(min_value=6, max_value=70),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n_ins=st.integers(min_value=0, max_value=4),
    n_del=st.integers(min_value=0, max_value=3),
)
def test_incremental_equals_scratch_property(n, m, seed, n_ins, n_del):
    rng = np.random.default_rng(seed)
    g = from_edges(n, rng.integers(0, n, size=(m, 2)))
    if g.nnz == 0:
        return
    sess = StreamingTrussSession.for_graph(g, chunk=64)
    res = sess.update(_random_batch(rng, g, n_ins, n_del))
    assert np.array_equal(
        res.trussness,
        trussness_numpy(sess.graph).astype(res.trussness.dtype),
    ), f"n={n} m={m} seed={seed} ins={n_ins} del={n_del}"


# ------------------------------------------------------------------ #
# Coalescing through the shared service
# ------------------------------------------------------------------ #
def test_concurrent_session_updates_coalesce_into_one_dispatch():
    from repro.service import bucket_for

    rng = np.random.default_rng(17)
    svc = TrussService(max_batch=4, chunk=64)
    # Collect 4 same-bucket graphs (different seeds can shift the
    # power-of-two window/nnz bucket): 3 streams + 1 plain member.
    groups: dict = {}
    for s in range(64):
        g = erdos(60, 6.0, seed=s)
        groups.setdefault(bucket_for(g, chunk=64), []).append(g)
        if len(groups[bucket_for(g, chunk=64)]) == 4:
            graphs = groups[bucket_for(g, chunk=64)]
            break
    sessions = [svc.open_stream(g) for g in graphs[:3]]
    before = svc.stats()["device_dispatches"]

    pend = []
    for sess in sessions:
        # Deletes only: the mutated graphs stay inside the shared bucket.
        pend.append(sess.submit_update(_random_batch(rng, sess.graph, 0, 2)))
    extra = svc.submit_decompose(graphs[3])  # plain member, same bucket
    assert svc.stats()["pending"] == 4
    svc.flush()
    # All three streams + the plain decompose completed in ONE dispatch.
    assert svc.stats()["device_dispatches"] == before + 1
    for sess, p in zip(sessions, pend):
        res = p.result()
        eng = KTrussEngine(sess.graph, chunk=64)
        assert np.array_equal(res.trussness, eng.decompose().trussness)
    assert np.array_equal(
        extra.result().trussness,
        KTrussEngine(graphs[3], chunk=64).decompose().trussness,
    )


def test_session_rejects_overlapping_updates():
    g = erdos(40, 5.0, seed=0)
    svc = TrussService(max_batch=2, chunk=64)
    sess = svc.open_stream(g)
    rng = np.random.default_rng(0)
    sess.submit_update(_random_batch(rng, g, 1, 0))
    with pytest.raises(RuntimeError):
        sess.submit_update(_random_batch(rng, g, 1, 0))


# ------------------------------------------------------------------ #
# Satellite: aligned-slot capacity errors name the member and capacity
# ------------------------------------------------------------------ #
def test_pack_capacity_errors_are_specific():
    big = erdos(50, 6.0, seed=0)
    with pytest.raises(ValueError, match=r"slot_nnz=64"):
        pack_problems([big], slot_n=64, slot_nnz=64, chunk=64, layout="aligned")
    with pytest.raises(ValueError, match=r"slot_n=16"):
        pack_problems([big], slot_n=16, slot_nnz=256, chunk=64, layout="aligned")
    # Contiguous layout: an oversized member must fail even when the batch
    # TOTAL fits (it would silently spill into the next slot's region).
    small = erdos(20, 3.0, seed=1)
    assert big.nnz > 128 and big.nnz + small.nnz < 2 * 128
    with pytest.raises(ValueError, match=r"member 0.*slot_nnz=128"):
        pack_problems(
            [big, small], slot_n=64, slot_nnz=128, slots=2, chunk=64, layout="contig"
        )


# ------------------------------------------------------------------ #
# Satellite: incremental triangle cache — one full enumeration per
# session, and the cached list always equals a from-scratch enumeration
# ------------------------------------------------------------------ #
def test_triangle_cache_incremental_matches_full():
    from repro.stream import ENUM_COUNTS, edge_keys

    g = erdos(40, 5.0, seed=2)
    sess = StreamingTrussSession.for_graph(g, chunk=64)
    rng = np.random.default_rng(3)
    base_full = ENUM_COUNTS["full"]
    snapshots = []
    for _ in range(4):
        sess.update(_random_batch(rng, sess.graph, 3, 2))
        snapshots.append((sess.graph, sess._tri_cache.tri_keys.copy()))
    # Four updates cost exactly ONE full enumeration (the cache seed);
    # everything after is wedge-incremental.
    assert ENUM_COUNTS["full"] == base_full + 1
    assert ENUM_COUNTS["incident"] >= 1
    for graph, cached in snapshots:
        tri = edge_triangles(graph)  # oracle (counts as "full", after the assert)
        want = (
            edge_keys(graph)[tri] if tri.size else np.zeros((0, 3), np.int64)
        )
        assert np.array_equal(
            np.unique(cached, axis=0), np.unique(want, axis=0)
        )


def test_triangle_cache_off_still_exact():
    from repro.api import Session

    g = clustered(3, 12, 0.7, seed=1)
    sess = StreamingTrussSession(
        Session(max_batch=1, chunk=64), g, cache_triangles=False
    )
    rng = np.random.default_rng(5)
    res = sess.update(_random_batch(rng, g, 2, 2))
    assert sess._tri_cache is None
    assert np.array_equal(res.trussness, trussness_numpy(sess.graph))
