"""Shared fixtures. Deliberately does NOT set XLA_FLAGS — tests must see
the single real CPU device (the 512-device override is dry-run-only)."""

import numpy as np
import pytest

from repro.graphs import clustered, erdos, rmat


@pytest.fixture(scope="session")
def small_graphs():
    return [
        erdos(120, 8.0, seed=0),
        clustered(3, 18, 0.7, seed=1),
        rmat(7, 5, seed=2),
    ]


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
