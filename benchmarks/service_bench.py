"""Serving-layer benchmark: throughput + compile-cache hit rate.

A mixed stream of graphs drawn from the generator suite families is
submitted to :class:`repro.service.TrussService` and flushed; per batch
width B ∈ {1, 4, 8} we report graphs/s end-to-end (submit → all futures
resolved), the compile-cache hit rate, and the queue/pack/device time
split.  The stream repeats each family with distinct seeds, so hits come
from shape-bucket canonicalization (different graphs, same bucket), not
from literal input reuse.

Modes:
  * small (default) — laptop-scale members of each suite family; the smoke
    target for ``benchmarks/run.py service`` and ``make bench-smoke``.
  * ``--full``      — the actual ``graphs.generators.suite()`` graphs
    (rmat-16/er-mid scale; minutes on CPU).
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.graphs import CSRGraph, barabasi, clustered, erdos, rmat, road, suite
from repro.service import TrussService

__all__ = ["build_stream", "run_service_bench", "report"]

# Small-scale members of the suite's five families (distinct seeds per
# repeat so the stream is genuinely mixed).
_SMALL_FAMILIES = (
    ("er", lambda s: erdos(400, 7.0, seed=s)),
    ("ba", lambda s: barabasi(500, 4, seed=s)),
    ("clustered", lambda s: clustered(6, 24, 0.5, seed=s)),
    ("road", lambda s: road(24, 0.08, seed=s)),
    ("rmat", lambda s: rmat(8, 5, seed=s)),
)


def build_stream(num_graphs: int = 20, *, full: bool = False) -> list[CSRGraph]:
    """A mixed stream of ``num_graphs`` suite-family graphs."""
    if full:
        base = suite()
        return [base[i % len(base)] for i in range(num_graphs)]
    out = []
    for i in range(num_graphs):
        name, fac = _SMALL_FAMILIES[i % len(_SMALL_FAMILIES)]
        g = fac(100 + i)
        out.append(CSRGraph(g.n, g.rowptr, g.colidx, name=f"{name}-{i}"))
    return out


def _submit_wave(svc: TrussService, stream, k: int, kmax_every: int):
    futs = []
    for i, g in enumerate(stream):
        if kmax_every and i % kmax_every == kmax_every - 1:
            futs.append(svc.submit_kmax(g))
        else:
            futs.append(svc.submit_ktruss(g, k))
    svc.flush()
    assert all(f.done() for f in futs)
    return futs


def run_service_bench(
    num_graphs: int = 20,
    batch_sizes: tuple[int, ...] = (1, 4, 8),
    *,
    full: bool = False,
    k: int = 3,
    kmax_every: int = 5,
    chunk: int = 256,
) -> list[dict]:
    """One row per batch width: cold + warm throughput, hit rate, time split.

    The cold wave pays every bucket's compile; the warm wave (a second burst
    of the same traffic mix against the now-populated cache) is the
    steady-state number a long-running server sees.
    """
    stream = build_stream(num_graphs, full=full)
    rows = []
    for b in batch_sizes:
        svc = TrussService(max_batch=b, chunk=chunk)
        t0 = time.perf_counter()
        cold = _submit_wave(svc, stream, k, kmax_every)
        cold_wall = time.perf_counter() - t0
        t1 = time.perf_counter()
        warm = _submit_wave(svc, stream, k, kmax_every)
        warm_wall = time.perf_counter() - t1
        st = svc.stats()
        futs = cold + warm
        queue = [f.stats.queue_time_s for f in futs]
        pack = [f.stats.pack_time_s for f in futs]
        rows.append(
            {
                "batch": b,
                "graphs": len(stream),
                "cold_graphs_per_s": round(len(stream) / cold_wall, 3),
                "warm_graphs_per_s": round(len(stream) / warm_wall, 3),
                "batches": st["batches_run"],
                "compiles": st["cache_compiles"],
                "cache_hits": st["cache_hits"],
                "hit_rate": st["cache_hit_rate"],
                # Fraction of requests that never paid a compile — the
                # amortization batching buys on top of caching.
                "req_hit_rate": round(
                    float(np.mean([f.stats.compile_hit for f in futs])), 4
                ),
                "device_s": st["device_time_s"],
                "mean_queue_ms": round(1e3 * float(np.mean(queue)), 3),
                "mean_pack_ms": round(1e3 * float(np.mean(pack)), 3),
            }
        )
    return rows


def report(rows: list[dict]) -> None:
    """CSV table + one ``bench,...`` summary line per batch width."""
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    for r in rows:
        print(
            f"bench,service_b{r['batch']},{r['warm_graphs_per_s']},"
            f"hit_rate={r['hit_rate']}"
        )


def main() -> None:
    full = "--full" in sys.argv
    smoke = "--smoke" in sys.argv
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    num = int(args[0]) if args else (6 if smoke else 20)
    if smoke:
        rows = run_service_bench(num, batch_sizes=(1, 2), chunk=64)
    else:
        rows = run_service_bench(num, full=full)
    report(rows)


if __name__ == "__main__":
    main()
