"""Streaming update benchmark: updates/s and frontier-vs-full ratio.

A :class:`repro.stream.StreamingTrussSession` is opened on an R-MAT graph
(heavy-tailed, triangle-dense — the regime the paper targets) and fed
balanced insert/delete batches of widths {1, 16, 256}.  Each update costs
at most ONE device dispatch over the affected-edge frontier; the benchmark
reports updates/s, the mean frontier size as a fraction of the full edge
set, and the initial full-decompose time as the from-scratch baseline.

Batches are balanced (half inserts, half deletes; width-1 batches
alternate) so the edge count never leaves the session's shape bucket —
otherwise a bucket jump would recompile mid-run and distort the numbers.

Writes ``BENCH_stream.json`` (``--out PATH``) and prints CSV +
``bench,...`` summary lines.  ``--smoke`` shrinks the update counts but
keeps the >= 10k-edge graph, and **asserts** the PR's frontier claim: a
single-edge update re-peels a frontier measurably smaller than the full
edge set.
"""

from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.graphs import CSRGraph, rmat
from repro.service import TrussService
from repro.stream import ENUM_COUNTS, EdgeBatch

__all__ = ["run_stream_bench", "report"]


def _bench_graph() -> CSRGraph:
    # Flatter-than-Graph500 quadrants keep the window (and hence the CPU
    # support cost) sane while staying R-MAT/power-law; ~16k edges.
    g = rmat(12, 4, a=0.45, b=0.22, c=0.22, seed=42)
    return CSRGraph(g.n, g.rowptr, g.colidx, name="rmat12-stream")


def _make_batches(
    rng: np.random.Generator, g: CSRGraph, width: int, count: int
) -> list[EdgeBatch]:
    """``count`` balanced batches of ``width`` updates over ``g``'s edges.

    Inserts are sampled fresh (not currently present, not pending), and
    deletes are sampled from the original edge list minus pending deletes,
    so applying the batches in order is always conflict-free.
    """
    existing = set(map(tuple, (g.edge_list() - 1)))
    deletable = list(existing)
    batches = []
    flip = False
    for _ in range(count):
        n_del = width // 2 if width > 1 else (1 if flip else 0)
        n_ins = width - n_del
        flip = not flip
        ins = []
        while len(ins) < n_ins:
            a, b = int(rng.integers(0, g.n)), int(rng.integers(0, g.n))
            key = (min(a, b), max(a, b))
            if a != b and key not in existing:
                ins.append(key)
                existing.add(key)
        dels = []
        for i in rng.permutation(len(deletable))[:n_del]:
            dels.append(deletable[i])
        for d in dels:
            deletable.remove(d)
            existing.discard(d)
        batches.append(EdgeBatch.of(ins, dels))
    return batches


def run_stream_bench(
    widths: tuple[int, ...] = (1, 16, 256),
    updates_per_width: int = 6,
    *,
    chunk: int = 256,
) -> list[dict]:
    """One row per batch width; session re-opened per width (same graph)."""
    g = _bench_graph()
    rows = []
    svc = TrussService(max_batch=1, chunk=chunk)  # shared: one compile
    # Warm the bucket's executable once so every row's from-scratch
    # baseline (and the updates) time warm execution, not the XLA compile.
    svc.submit_decompose(g).result()
    for width in widths:
        rng = np.random.default_rng(7)
        enum0 = dict(ENUM_COUNTS)
        t0 = time.perf_counter()
        sess = svc.open_stream(g)
        full_s = time.perf_counter() - t0
        batches = _make_batches(rng, sess.graph, width, updates_per_width)
        fronts, update_s = [], []
        t_all = time.perf_counter()
        for b in batches:
            t1 = time.perf_counter()
            res = sess.update(b)
            update_s.append(time.perf_counter() - t1)
            fronts.append(res.frontier_size)
        wall = time.perf_counter() - t_all
        st = sess.stats()
        rows.append(
            {
                "graph": g.name,
                "edges": g.nnz,
                "batch_width": width,
                "updates": len(batches),
                "updates_per_s": round(len(batches) / wall, 4),
                "mean_update_s": round(float(np.mean(update_s)), 4),
                "mean_frontier_edges": round(float(np.mean(fronts)), 1),
                "mean_frontier_frac": round(float(np.mean(fronts)) / g.nnz, 4),
                "dispatches": st["update_dispatches"],
                # Incremental triangle state: full enumerations this
                # session paid (1 = the cache seed) vs. the cheap
                # insert-wedge ones; without the cache every update would
                # be a full enumeration.
                "tri_full_enums": ENUM_COUNTS["full"] - enum0["full"],
                "tri_incident_enums": ENUM_COUNTS["incident"] - enum0["incident"],
                "cached_triangles": st["cached_triangles"],
                "full_decompose_s": round(full_s, 3),
                "speedup_vs_full": round(
                    full_s / max(float(np.mean(update_s)), 1e-9), 2
                ),
            }
        )
    return rows


def report(rows: list[dict]) -> None:
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    for r in rows:
        print(
            f"bench,stream_update_b{r['batch_width']},"
            f"{r['updates_per_s']},frontier_frac={r['mean_frontier_frac']}"
        )


def main() -> None:
    out = None
    args = list(sys.argv[1:])
    if "--out" in args:
        out = args[args.index("--out") + 1]
        del args[args.index("--out") : args.index("--out") + 2]
    smoke = "--smoke" in args
    rows = run_stream_bench(
        widths=(1, 16, 256),
        updates_per_width=2 if smoke else 6,
    )
    report(rows)
    if smoke:
        # The PR's frontier-bound claim, pinned: a single-edge update on a
        # >= 10k-edge R-MAT graph re-peels far fewer edges than exist.
        r1 = next(r for r in rows if r["batch_width"] == 1)
        assert r1["edges"] >= 10_000, r1
        assert r1["mean_frontier_edges"] < 0.5 * r1["edges"], (
            "single-edge frontier not measurably smaller than the graph: "
            f"{r1}"
        )
        assert r1["dispatches"] <= r1["updates"], r1
        # Incremental frontier state, pinned: a session enumerates the
        # graph's triangles ONCE (the cache seed), not once per update.
        for r in rows:
            assert r["tri_full_enums"] == 1, r
            assert r["tri_full_enums"] < r["updates"] + 1, r
        print(
            f"# smoke OK: frontier {r1['mean_frontier_edges']:.0f} edges "
            f"vs {r1['edges']} total ({100 * r1['mean_frontier_frac']:.2f}%); "
            f"{r1['tri_full_enums']} full triangle enumeration for "
            f"{r1['updates']} updates"
        )
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
