"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per section, plus the section
tables.  Sections:

  table1    — paper Table I analog (coarse/fine runtimes + ME/s)
  fig23     — paper Fig 2/3 analog (fine-over-coarse speedups + geomean)
  imbalance — load-imbalance statistics (the paper's §III-A mechanism)
  kernels   — Pallas kernel structural models + interpret-mode checks
  service   — TrussService throughput + compile-cache hit rate (batch sweep)
  peel      — on-device peel: decompose graphs/s, sharded vs unsharded
  stream    — incremental truss maintenance: updates/s + frontier ratio
  api       — repro.api planner overhead + backend auto-choice per bucket
  obs       — tracing overhead on/off + observed per-bucket imbalance
  serve     — multi-replica fleet: queries/s, p50/p99, affinity hit rate
"""

from __future__ import annotations

import sys
import time


def _section(title: str):
    print(f"\n##### {title} " + "#" * max(1, 60 - len(title)), flush=True)


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    t_start = time.time()

    if only in (None, "imbalance"):
        _section("imbalance")
        from repro.configs.ktruss import BENCH_GRAPHS
        from repro.graphs import imbalance_stats

        cols = None
        for spec in BENCH_GRAPHS:
            st = imbalance_stats(spec.build()).row()
            if cols is None:
                cols = list(st.keys())
                print(",".join(cols))
            print(",".join(f"{st[c]:.3g}" if isinstance(st[c], float) else str(st[c]) for c in cols))

    if only in (None, "table1"):
        _section("table1 (paper Table I analog, K=3)")
        from . import ktruss_table

        rows = ktruss_table.run_table()
        cols = sorted({c for r in rows for c in r})
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in cols))
        for r in rows:
            if r.get("support_ms_fe"):
                print(
                    f"bench,ktruss_fine_support_{r['graph']},"
                    f"{r['support_ms_fe']*1e3:.0f},ME/s={r.get('me_s_fe')}"
                )

    if only in (None, "fig23"):
        _section("fig23 (speedup fine/coarse + geomean)")
        from . import ktruss_speedup

        rows, geo = ktruss_speedup.run_speedup()
        cols = list(rows[0].keys())
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r[c]) for c in cols))
        print(f"geomean_speedup,{geo:.2f}")
        print("paper_reference,CPU 1.48x / GPU 16.93x (K=3)")

    if only in (None, "kernels"):
        _section("kernels (structural + interpret + fused megakernel)")
        from . import kernels_bench

        for r in kernels_bench.kernel_structure_rows():
            print(r)
        for r in kernels_bench.run_kernel_bench():
            print(r)
        kernels_bench.report(kernels_bench.run_fused_bench(smoke=True))

    if only in (None, "service"):
        _section("service (batched serving: graphs/s + cache hit rate)")
        from . import service_bench

        service_bench.report(service_bench.run_service_bench())

    if only in (None, "peel"):
        _section("peel (one-dispatch decompose: graphs/s)")
        from . import peel_bench

        peel_bench.report(peel_bench.run_peel_bench())

    if only in (None, "stream"):
        _section("stream (incremental updates: updates/s + frontier frac)")
        from . import stream_bench

        stream_bench.report(
            stream_bench.run_stream_bench(widths=(1, 16), updates_per_width=2)
        )

    if only in (None, "api"):
        _section("api (planner overhead + backend auto-choice)")
        from . import api_bench

        api_bench.report(api_bench.run_api_bench())

    if only in (None, "obs"):
        _section("obs (tracing overhead + observed imbalance)")
        from . import obs_bench

        obs_bench.report(obs_bench.run_obs_bench(repeats=2))

    if only in (None, "serve"):
        _section("serve (fleet: qps + p50/p99 + affinity hit rate)")
        from . import serve_bench

        serve_bench.report(serve_bench.run_serve_bench(queries_per_fleet=24))

    print(f"\n# total bench wall time: {time.time()-t_start:.1f}s")


if __name__ == "__main__":
    main()
