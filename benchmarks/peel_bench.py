"""On-device peel benchmark: decompose graphs/s, sharded vs unsharded.

Measures the PR's tentpole path: a stream of same-family graphs is
submitted as ``decompose`` requests to :class:`repro.service.TrussService`
at batch widths {1, 8}; each batch's entire level peel runs as one device
dispatch.  When more than one JAX device is visible (e.g. under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the widths that
divide the device count are additionally run with the packed slot blocks
sharded across a ``slots`` mesh, so the artifact tracks the sharding
overhead/benefit over time.

Writes ``BENCH_peel.json`` (``--out PATH``) — one row per
(batch width × sharding) cell with cold/warm graphs/s and dispatch counts
— and prints the same rows as CSV plus ``bench,...`` summary lines.
"""

from __future__ import annotations

import json
import sys
import time

import jax

from repro.graphs import CSRGraph, erdos
from repro.service import TrussService

__all__ = ["run_peel_bench", "report"]


def _stream(num_graphs: int) -> list[CSRGraph]:
    out = []
    for i in range(num_graphs):
        g = erdos(300, 7.0, seed=100 + i)
        out.append(CSRGraph(g.n, g.rowptr, g.colidx, name=f"er-{i}"))
    return out


def _wave(svc: TrussService, stream) -> float:
    t0 = time.perf_counter()
    futs = [svc.submit_decompose(g) for g in stream]
    svc.flush()
    assert all(f.done() for f in futs)
    return time.perf_counter() - t0


def run_peel_bench(
    num_graphs: int = 8,
    batch_sizes: tuple[int, ...] = (1, 8),
    *,
    chunk: int = 256,
) -> list[dict]:
    """One row per (batch width × sharded?) cell: cold + warm graphs/s."""
    stream = _stream(num_graphs)
    n_dev = len(jax.devices())
    rows = []
    for b in batch_sizes:
        variants = [None]
        if n_dev > 1 and b % n_dev == 0:
            from repro.distributed import slot_mesh

            variants.append(slot_mesh(n_dev))
        for mesh in variants:
            svc = TrussService(max_batch=b, chunk=chunk, mesh=mesh)
            cold = _wave(svc, stream)
            warm = _wave(svc, stream)
            st = svc.stats()
            rows.append(
                {
                    "workload": "decompose",
                    "batch": b,
                    "sharded": mesh is not None,
                    "devices": n_dev if mesh is not None else 1,
                    "graphs": len(stream),
                    "cold_graphs_per_s": round(len(stream) / cold, 3),
                    "warm_graphs_per_s": round(len(stream) / warm, 3),
                    "device_dispatches": st["device_dispatches"],
                    "device_s": st["device_time_s"],
                }
            )
    return rows


def report(rows: list[dict]) -> None:
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    for r in rows:
        tag = "sharded" if r["sharded"] else "unsharded"
        print(f"bench,peel_decompose_b{r['batch']}_{tag},{r['warm_graphs_per_s']}")


def main() -> None:
    out = None
    args = list(sys.argv[1:])
    if "--out" in args:
        out = args[args.index("--out") + 1]
        del args[args.index("--out") : args.index("--out") + 2]
    smoke = "--smoke" in args
    num = int(args[0]) if args and not args[0].startswith("--") else (4 if smoke else 8)
    rows = run_peel_bench(num, batch_sizes=(1, 2) if smoke else (1, 8),
                          chunk=64 if smoke else 256)
    report(rows)
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
