"""repro.serve benchmark: fleet throughput/latency + affinity accounting.

Hammers a real fleet (replica subprocesses, real sockets) with
mixed-bucket traffic from concurrent closed-loop clients and reports,
for 1 replica vs 3 replicas:

* **queries/s** — end-to-end through router + wire + replica Session;
* **p50 / p99 latency** — per-query submit→result wall time;
* **affinity hit rate** — fraction of routed queries that landed on
  their bucket's home replica (the router's whole point: executables
  compile once per bucket per fleet, not once per replica).

Writes ``BENCH_serve.json`` (``--out PATH``); ``--smoke`` shrinks the
load and **asserts** the affinity hit rate exceeds 0.8 on the 3-replica
fleet and that fleet results stay bit-identical to a local ``solve()``.
"""

from __future__ import annotations

import json
import sys
import tempfile
import threading
import time

import numpy as np

from repro.api import TrussQuery, solve
from repro.graphs import erdos, rmat
from repro.serve import Fleet, FleetClient

__all__ = ["run_serve_bench", "report"]

_WARMUP = (
    {"kind": "erdos", "n": 48, "avg_degree": 6.0, "seed": 0},
    {"kind": "erdos", "n": 150, "avg_degree": 5.0, "seed": 1},
    {"kind": "rmat", "scale": 7, "edge_factor": 5, "seed": 2},
)


def _graphs():
    return [
        erdos(48, 6.0, seed=0),
        erdos(150, 5.0, seed=1),
        rmat(7, 5, seed=2),
    ]


def _query_stream(n: int) -> list[TrussQuery]:
    """Mixed workloads cycling through three distinct shape buckets."""
    gs = _graphs()
    makers = (
        lambda g: TrussQuery.decompose(g),
        lambda g: TrussQuery.kmax(g),
        lambda g: TrussQuery.ktruss(g, k=3),
    )
    # Decorrelate workload from bucket so every (workload, bucket) pair
    # shows up in the stream.
    return [makers[i % 3](gs[(i // 3) % len(gs)]) for i in range(n)]


def _hammer(client: FleetClient, queries: list[TrussQuery], workers: int):
    """Closed-loop concurrent load; returns (results, latencies_s, wall_s)."""
    results: list = [None] * len(queries)
    lat = [0.0] * len(queries)
    errors: list[BaseException] = []
    it = iter(range(len(queries)))
    lock = threading.Lock()

    def loop():
        while True:
            with lock:
                i = next(it, None)
            if i is None:
                return
            t0 = time.perf_counter()
            try:
                results[i] = client.submit(queries[i]).result()
            except BaseException as e:  # shed/quarantine under overload
                errors.append(e)
            lat[i] = time.perf_counter() - t0

    t0 = time.perf_counter()
    threads = [threading.Thread(target=loop) for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return results, lat, wall


def run_serve_bench(
    *, queries_per_fleet: int = 60, workers: int = 4, sizes=(1, 3)
) -> dict:
    queries = _query_stream(queries_per_fleet)
    expect = solve(list(queries), max_batch=2)
    out: dict = {"queries_per_fleet": queries_per_fleet, "workers": workers}
    for size in sizes:
        with tempfile.TemporaryDirectory(prefix="serve_bench_") as td:
            with Fleet(
                size, workdir=td, max_batch=2, warmup=_WARMUP
            ) as fleet:
                client = FleetClient(fleet)
                results, lat, wall = _hammer(client, list(queries), workers)
                st = client.stats()
        matched = sum(
            1
            for exp, got in zip(expect, results)
            if (
                got == exp
                if isinstance(exp, int)
                else np.array_equal(
                    getattr(got, "trussness", getattr(got, "alive", None)),
                    getattr(exp, "trussness", getattr(exp, "alive", None)),
                )
            )
        )
        out[f"replicas_{size}"] = {
            "queries": len(queries),
            "bit_identical": matched,
            "queries_per_s": round(len(queries) / wall, 3),
            "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "affinity_hit_rate": st["affinity_hit_rate"],
            "affinity_hits": st["affinity_hits"],
            "spillovers": st["spillovers"],
            "cold_assignments": st["cold_assignments"],
            "queries_shed": st["queries_shed"],
        }
    return out


def report(row: dict) -> None:
    for size_key in sorted(k for k in row if k.startswith("replicas_")):
        r = row[size_key]
        print(
            f"{size_key},qps={r['queries_per_s']},p50_ms={r['p50_ms']},"
            f"p99_ms={r['p99_ms']},affinity={r['affinity_hit_rate']},"
            f"spill={r['spillovers']},shed={r['queries_shed']}"
        )
        print(
            f"bench,serve_{size_key},{r['p50_ms']},"
            f"qps={r['queries_per_s']}"
        )


def main() -> None:
    out = None
    args = list(sys.argv[1:])
    if "--out" in args:
        out = args[args.index("--out") + 1]
        del args[args.index("--out") : args.index("--out") + 2]
    smoke = "--smoke" in args
    row = run_serve_bench(queries_per_fleet=30 if smoke else 60)
    report(row)
    if smoke:
        for size_key in ("replicas_1", "replicas_3"):
            r = row[size_key]
            # Routing changes *where* a query runs, never what it computes.
            assert r["bit_identical"] == r["queries"], row
        # Warmup seeds each bucket's home; after the one cold assignment
        # per bucket, mixed traffic must keep landing home.
        assert row["replicas_3"]["affinity_hit_rate"] > 0.8, row
        print("# smoke OK: bit-identical under the fleet + affinity > 0.8")
    if out:
        with open(out, "w") as f:
            json.dump(row, f, indent=2)
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
