"""Table I analog: runtimes + ME/s for coarse vs fine on the graph suite.

Mirrors the paper's Table I (Kokkos, 48-thread Skylake + V100) at
laptop scale on XLA:CPU: per graph, time the full K-truss to convergence
and a single support computation for each decomposition, and report ME/s
(millions of edges per second, the paper's metric).  The paper's CPU
columns correspond to our XLA path; the Pallas interpret path checks the
kernel route end-to-end (its wall-clock is NOT TPU-representative and is
flagged as such).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.ktruss import BENCH_GRAPHS, LARGE_GRAPHS
from repro.core import KTrussEngine
from repro.graphs import imbalance_stats

__all__ = ["run_table", "time_support", "VARIANTS"]

VARIANTS = (
    ("coarse", "eager", "xla", {}),  # Algorithm 2 (baseline)
    ("fine", "eager", "xla", {}),  # Algorithm 3 (paper's contribution)
    ("fine", "owner", "xla", {}),  # TPU-kernel-form reformulation
    ("fine", "eager", "xla", {"bucketed": True}),  # beyond-paper (§Perf-ktruss)
)


def time_support(engine: KTrussEngine, repeats: int = 3) -> float:
    alive = engine.initial_alive()
    fn = jax.jit(engine.support)
    fn(alive).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(alive).block_until_ready()
    return (time.perf_counter() - t0) / repeats


def time_truss(engine: KTrussEngine, k: int) -> tuple[float, int]:
    engine.ktruss(k)  # compile
    t0 = time.perf_counter()
    res = engine.ktruss(k)
    return time.perf_counter() - t0, res.edges_remaining


def run_table(
    k: int = 3, include_large: bool = False, skip_coarse_above: int = 24_000
):
    rows = []
    graphs = list(BENCH_GRAPHS) + (list(LARGE_GRAPHS) if include_large else [])
    for spec in graphs:
        g = spec.build()
        st = imbalance_stats(g)
        row = {
            "graph": g.name,
            "regime": spec.regime,
            "vertices": g.n,
            "edges": g.nnz,
            "max_deg": g.max_degree(),
            "coarse_imbalance": round(st.coarse_imbalance, 1),
        }
        for gran, mode, backend, extra in VARIANTS:
            tag = f"{gran[0]}{mode[0]}" + ("b" if extra.get("bucketed") else "")
            if gran == "coarse" and g.nnz > skip_coarse_above:
                row[f"support_ms_{tag}"] = None  # prohibitive by design
                continue
            eng = KTrussEngine(
                g, granularity=gran, mode=mode, backend=backend, **extra
            )
            dt = time_support(eng)
            row[f"support_ms_{tag}"] = round(dt * 1e3, 2)
            row[f"me_s_{tag}"] = round(g.nnz / dt / 1e6, 3)
            # Full-convergence truss timing only on the fine paths (the
            # coarse fixed point at padded O(n·W²) per iteration is
            # prohibitive by design — that asymmetry IS the result).
            if gran == "fine":
                t_truss, remaining = time_truss(eng, k)
                row[f"truss_ms_{tag}"] = round(t_truss * 1e3, 2)
                row["edges_in_truss"] = remaining
        if row.get("support_ms_ce") and row.get("support_ms_fe"):
            row["speedup_fine"] = round(
                row["support_ms_ce"] / row["support_ms_fe"], 2
            )
        rows.append(row)
    return rows


def main() -> None:
    rows = run_table()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))


if __name__ == "__main__":
    main()
