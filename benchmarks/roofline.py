"""Roofline analysis (§Roofline): three terms per (arch × shape), from the
dry-run's compiled artifacts.

Reads results/dryrun_all.jsonl (written by ``python -m repro.launch.dryrun
--all``), computes per single-pod cell:

  compute_s    = HLO_FLOPs / peak_FLOPs            (197 TF/s bf16, v5e)
  memory_s     = HLO_traffic_bytes / HBM_bw        (819 GB/s)
  collective_s = Σ_k ring_factor·bytes_k / link_bw (~50 GB/s/link ICI)

HLO_FLOPs / traffic / collective bytes are the **trip-count-scaled
per-device** totals from launch/hlo_stats.py (XLA's cost_analysis counts
while bodies once; see that module).  MODEL_FLOPS = 6·N_active·tokens for
train, 2·N_active·tokens for prefill/decode, per device.  The dominant
term is the bottleneck the §Perf loop iterates on; roofline_frac =
compute_s / max(all terms) is the fraction-of-peak upper bound reported as
the §Perf score.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.models import Model

__all__ = ["roofline_rows", "render_markdown", "HW"]

HW = {
    "peak_flops": 197e12,  # bf16 per chip
    "hbm_bw": 819e9,  # bytes/s
    "link_bw": 50e9,  # bytes/s/link ICI
}

# Per-device time multipliers for ring algorithms (N→∞ limit).
_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_ACTIVE_CACHE: dict[str, int] = {}


def _active_params(arch: str) -> int:
    if arch not in _ACTIVE_CACHE:
        model = Model(get_config(arch))
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        _ACTIVE_CACHE[arch] = model.active_param_count(shapes)
    return _ACTIVE_CACHE[arch]


def model_flops_per_device(arch: str, shape_name: str, n_chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = _active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_chips
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens / n_chips


def roofline_rows(jsonl_path: str, mesh: str = "16x16") -> list[dict]:
    n_chips = 256 if mesh == "16x16" else 512
    rows = []
    for line in open(jsonl_path):
        r = json.loads(line)
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(
                {"arch": r["arch"], "shape": r["shape"], "status": "skipped",
                 "reason": r["reason"]}
            )
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"], "status": "error"})
            continue
        compute_s = r["hlo_flops"] / HW["peak_flops"]
        memory_s = r["hlo_traffic_bytes"] / HW["hbm_bw"]
        coll_s = sum(
            _COLL_FACTOR.get(k, 1.0) * v["bytes"] / HW["link_bw"]
            for k, v in r["collectives_scaled"].items()
        )
        terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
        dominant = max(terms, key=terms.get)
        mf = model_flops_per_device(r["arch"], r["shape"], n_chips)
        bound = max(terms.values())
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "status": "ok",
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": coll_s,
                "dominant": dominant,
                "model_flops": mf,
                "hlo_flops": r["hlo_flops"],
                "useful_ratio": mf / max(r["hlo_flops"], 1.0),
                "roofline_frac": compute_s / bound if bound else 0.0,
                "mfu_bound": (mf / HW["peak_flops"]) / bound if bound else 0.0,
                "peak_gb": (
                    r["memory"]["argument_bytes"]
                    + r["memory"]["temp_bytes"]
                    + r["memory"]["output_bytes"]
                    - r["memory"]["alias_bytes"]
                )
                / 1e9,
            }
        )
    return rows


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def render_markdown(rows: list[dict]) -> str:
    out = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL/HLO flops | MFU bound | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | "
                f"({r['reason'][:40]}) |"
            )
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        fits = "yes" if r["peak_gb"] <= 16 else f"NO {r['peak_gb']:.1f}GB"
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']*100:.1f}% | {fits} |"
        )
    return "\n".join(out)


def main() -> None:
    path = os.environ.get("DRYRUN_JSONL", "results/dryrun_all.jsonl")
    if not os.path.exists(path):
        print(f"# roofline: {path} not found — run the dry-run first")
        return
    rows = roofline_rows(path)
    print("arch,shape,compute_s,memory_s,collective_s,dominant,useful_ratio,mfu_bound,peak_gb")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']},{r['shape']},{r['status']},,,,,,")
            continue
        print(
            f"{r['arch']},{r['shape']},{r['compute_s']:.4g},{r['memory_s']:.4g},"
            f"{r['collective_s']:.4g},{r['dominant']},{r['useful_ratio']:.3f},"
            f"{r['mfu_bound']:.3f},{r['peak_gb']:.2f}"
        )


if __name__ == "__main__":
    main()
