"""Kernel benchmarks: structural models + the fused megakernel sweep.

This container has no TPU, so the Pallas kernels are profiled
*structurally* (the §Perf methodology for kernels): per tile configuration
we report VMEM working set, arithmetic intensity, and the analytic MXU/VPU
cycle model — plus interpret-mode correctness timing (NOT TPU wall-clock;
flagged).  The table shows why the fine-grained edge-tile kernel is the
right TPU decomposition: its tiles are dense and uniform (lane efficiency
1.0 by construction), while the coarse row decomposition's efficiency is
the graph's lane-efficiency statistic.

``run_fused_bench`` is the fused-vs-xla-vs-pallas speedup table per shape
bucket (warm full decompose, one autotuned fused config per bucket): the
fused megakernel's dead-tile skipping should beat the unfused Pallas
backend wherever a batch is *skewed* — light members retire early and
leave most edge tiles dead while the heavy member keeps peeling.  Smoke
mode asserts exactly that claim on at least one skewed bucket, plus
fused/XLA bit-parity and that the autotuned winner persisted and replays
from a fresh store (the warm-start path).  `BENCH_kernels.json` carries
all tables (CI uploads it like the peel/stream/api/obs artifacts).
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.configs.ktruss import BENCH_GRAPHS
from repro.core import KTrussEngine
from repro.graphs import imbalance_stats

__all__ = [
    "kernel_structure_rows",
    "run_kernel_bench",
    "run_fused_bench",
    "report",
]

_VPU_LANES = 8 * 128  # v5e VPU: 8 sublanes × 128 lanes
_CLOCK = 0.94e9  # ~v5e clock


def kernel_structure_rows(tiles=((256, 128), (256, 256), (128, 512), (512, 256))):
    rows = []
    for t, w in tiles:
        vmem_bytes = 4 * t * w * 4  # four int32 operand tiles
        # compare schedule: W²/128 slabs of (T, W, 128) compares
        cmp_ops = t * w * w
        cmp_cycles = cmp_ops / _VPU_LANES
        # bsearch schedule: log2(W)+1 rounds of gather+compare over (T, W)
        bs_rounds = int(np.ceil(np.log2(w + 1)))
        bs_cycles = bs_rounds * t * w * 3 / _VPU_LANES  # gather≈3 ops/lane
        rows.append(
            {
                "tile": f"{t}x{w}",
                "vmem_kb": vmem_bytes // 1024,
                "vmem_ok": vmem_bytes < 16 * 2**20,
                "compare_cycles": int(cmp_cycles),
                "bsearch_cycles": int(bs_cycles),
                "bsearch_speedup": round(cmp_cycles / bs_cycles, 1),
                "edges_per_s_model_compare": int(t / (cmp_cycles / _CLOCK)),
                "edges_per_s_model_bsearch": int(t / (bs_cycles / _CLOCK)),
            }
        )
    return rows


def run_kernel_bench():
    """Interpret-mode end-to-end timing for the pallas-backed engine."""
    rows = []
    for spec in BENCH_GRAPHS[:2]:
        g = spec.build()
        for schedule in ("compare", "bsearch"):
            import functools

            from repro.kernels import ops as kops

            eng = KTrussEngine(g, granularity="fine", backend="pallas")
            eng._support = functools.partial(
                kops.support_fine,
                eng.problem,
                window=eng.window,
                chunk=eng.chunk,
                schedule=schedule,
            )
            import jax

            fn = jax.jit(eng._support)
            alive = eng.initial_alive()
            fn(alive).block_until_ready()
            t0 = time.perf_counter()
            fn(alive).block_until_ready()
            dt = time.perf_counter() - t0
            rows.append(
                {
                    "graph": g.name,
                    "schedule": schedule,
                    "interpret_ms": round(dt * 1e3, 1),
                    "note": "interpret-mode (CPU emulation, not TPU wall-clock)",
                }
            )
    return rows


# --------------------------------------------------------------------- #
# Fused megakernel: per-bucket autotune + speedup table
# --------------------------------------------------------------------- #
def _pack_batch(graphs, *, chunk):
    from repro.api.cache import bucket_for
    from repro.graphs.pack import pack_problems

    buckets = [bucket_for(g, chunk=chunk) for g in graphs]
    n_pad = max(b.n_pad for b in buckets)
    nnz_pad = max(b.nnz_pad for b in buckets)
    window = max(b.window for b in buckets)
    from repro.api.cache import Bucket

    bucket = Bucket(n_pad=n_pad, nnz_pad=nnz_pad, window=window)
    packed = pack_problems(
        graphs,
        slot_n=n_pad,
        slot_nnz=nnz_pad,
        slots=len(graphs),
        chunk=chunk,
        layout="aligned",
    )
    slot_ids = np.repeat(np.arange(len(graphs), dtype=np.int32), nnz_pad)
    return bucket, packed, slot_ids


def _time_peel(exe, problem, slot_ids, k0, repeats):
    exe.peel(problem, slot_ids=slot_ids, k0=k0)  # warm (compile)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        st = exe.peel(problem, slot_ids=slot_ids, k0=k0)
        np.asarray(st.done)
        times.append(time.perf_counter() - t0)
    return min(times), st


def _fused_workloads(smoke: bool):
    """(name, graphs, skewed) batches.

    The skewed batches are the fused kernel's home turf: one heavy
    R-MAT member next to light members that retire within a couple of
    levels, leaving most edge tiles dead for most of the peel.
    """
    from repro.graphs import erdos, rmat

    skew = [rmat(6, 8, seed=1)] + [erdos(20, 3.0, seed=s) for s in range(3)]
    loads = [("rmat+light_skew", skew, True)]
    if not smoke:
        loads += [
            ("rmat_pair_skew", [rmat(6, 8, seed=2), rmat(6, 2, seed=3)], True),
            ("erdos_balanced", [erdos(64, 5.0, seed=s) for s in range(4)], False),
        ]
    return loads


def run_fused_bench(smoke: bool = False, *, chunk: int = 64, repeats: int = 3):
    """Fused-vs-xla-vs-pallas warm decompose per bucket, autotuned."""
    from repro.exec.peel import PeelExecutor
    from repro.kernels import autotune
    from repro.kernels.autotune import AutotuneStore, FusedConfig

    store_path = os.path.join(
        tempfile.mkdtemp(prefix="bench-autotune-"), "autotune.json"
    )
    store = AutotuneStore(store_path)
    candidates = autotune.candidate_configs(
        2**30,
        blocks=(32, 64) if smoke else (32, 64, 128),
        schedules=("compare", "bsearch"),
    )
    rows = []
    for name, graphs, skewed in _fused_workloads(smoke):
        bucket, packed, slot_ids = _pack_batch(graphs, chunk=chunk)
        slots = packed.slots
        k0 = np.full(slots, 3, np.int32)
        cfg, sweep = autotune.autotune_fused(
            bucket,
            slots,
            graphs=graphs,
            chunk=chunk,
            candidates=[c.clamp(bucket.nnz_pad) for c in candidates],
            repeats=max(1, repeats - 1),
            store=store,
        )
        # The replay path a warm process takes: a FRESH store instance
        # must hand back the persisted winner.
        replayed = AutotuneStore(store_path).get(bucket, slots)
        assert replayed == cfg, f"autotune replay mismatch: {replayed} != {cfg}"

        xla = PeelExecutor(
            granularity="fine", mode="owner", backend="xla",
            window=bucket.window, chunk=chunk,
        )
        pallas = PeelExecutor(
            granularity="fine", mode="owner", backend="pallas",
            window=bucket.window, chunk=chunk,
        )
        fused = PeelExecutor(
            backend="fused", window=bucket.window, chunk=chunk, fused_config=cfg
        )
        xla_s, st_x = _time_peel(xla, packed.problem, slot_ids, k0, repeats)
        pallas_s, _ = _time_peel(pallas, packed.problem, slot_ids, k0, repeats)
        fused_s, st_f = _time_peel(fused, packed.problem, slot_ids, k0, repeats)
        assert np.array_equal(
            np.asarray(st_x.trussness), np.asarray(st_f.trussness)
        ), f"fused/xla parity broke on {name}"
        rows.append(
            {
                "batch": name,
                "bucket": f"n{bucket.n_pad}-nnz{bucket.nnz_pad}-w{bucket.window}",
                "slots": slots,
                "skewed": skewed,
                "xla_ms": round(xla_s * 1e3, 2),
                "pallas_ms": round(pallas_s * 1e3, 2),
                "fused_ms": round(fused_s * 1e3, 2),
                "fused_vs_pallas": round(pallas_s / fused_s, 2),
                "fused_vs_xla": round(xla_s / fused_s, 2),
                "config": cfg.to_json(),
                "sweep": sweep,
            }
        )
    result = {
        "rows": rows,
        "autotune_store": json.load(open(store_path)),
        "note": "interpret-mode (CPU emulation, not TPU wall-clock)",
    }
    if smoke:
        assert any(
            r["skewed"] and r["fused_vs_pallas"] > 1.0 for r in rows
        ), f"fused showed no warm-path win on any skewed bucket: {rows}"
        # replay must also round-trip the default-config distinction
        assert result["autotune_store"]["configs"], "autotune store is empty"
        _ = FusedConfig  # keep the import local to this path
    return result


def report(result: dict) -> None:
    cols = (
        "batch", "bucket", "slots", "skewed",
        "xla_ms", "pallas_ms", "fused_ms", "fused_vs_pallas", "fused_vs_xla",
    )
    print(",".join(cols))
    for r in result["rows"]:
        print(",".join(str(r[c]) for c in cols))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small sweep + asserts")
    ap.add_argument("--out", default=None, help="write BENCH_kernels.json here")
    args = ap.parse_args()

    print("# structural model (v5e)")
    structural = kernel_structure_rows()
    cols = list(structural[0].keys())
    print(",".join(cols))
    for r in structural:
        print(",".join(str(r[c]) for c in cols))

    interpret = []
    if not args.smoke:
        print("# interpret-mode end-to-end")
        interpret = run_kernel_bench()
        cols = list(interpret[0].keys())
        print(",".join(cols))
        for r in interpret:
            print(",".join(str(r[c]) for c in cols))

    print("# fused megakernel vs unfused (warm decompose, autotuned)")
    fused = run_fused_bench(smoke=args.smoke)
    report(fused)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(
                {
                    "structural": structural,
                    "interpret": interpret,
                    "fused": fused,
                },
                f,
                indent=2,
            )
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
