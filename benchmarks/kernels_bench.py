"""Kernel-level structural benchmarks (Fig. 4 analog for the TPU target).

This container has no TPU, so the Pallas kernels are profiled
*structurally* (the §Perf methodology for kernels): per tile configuration
we report VMEM working set, arithmetic intensity, and the analytic MXU/VPU
cycle model — plus interpret-mode correctness timing (NOT TPU wall-clock;
flagged).  The table shows why the fine-grained edge-tile kernel is the
right TPU decomposition: its tiles are dense and uniform (lane efficiency
1.0 by construction), while the coarse row decomposition's efficiency is
the graph's lane-efficiency statistic.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.ktruss import BENCH_GRAPHS
from repro.core import KTrussEngine
from repro.graphs import imbalance_stats

__all__ = ["kernel_structure_rows", "run_kernel_bench"]

_VPU_LANES = 8 * 128  # v5e VPU: 8 sublanes × 128 lanes
_CLOCK = 0.94e9  # ~v5e clock


def kernel_structure_rows(tiles=((256, 128), (256, 256), (128, 512), (512, 256))):
    rows = []
    for t, w in tiles:
        vmem_bytes = 4 * t * w * 4  # four int32 operand tiles
        # compare schedule: W²/128 slabs of (T, W, 128) compares
        cmp_ops = t * w * w
        cmp_cycles = cmp_ops / _VPU_LANES
        # bsearch schedule: log2(W)+1 rounds of gather+compare over (T, W)
        bs_rounds = int(np.ceil(np.log2(w + 1)))
        bs_cycles = bs_rounds * t * w * 3 / _VPU_LANES  # gather≈3 ops/lane
        rows.append(
            {
                "tile": f"{t}x{w}",
                "vmem_kb": vmem_bytes // 1024,
                "vmem_ok": vmem_bytes < 16 * 2**20,
                "compare_cycles": int(cmp_cycles),
                "bsearch_cycles": int(bs_cycles),
                "bsearch_speedup": round(cmp_cycles / bs_cycles, 1),
                "edges_per_s_model_compare": int(t / (cmp_cycles / _CLOCK)),
                "edges_per_s_model_bsearch": int(t / (bs_cycles / _CLOCK)),
            }
        )
    return rows


def run_kernel_bench():
    """Interpret-mode end-to-end timing for the pallas-backed engine."""
    rows = []
    for spec in BENCH_GRAPHS[:2]:
        g = spec.build()
        for schedule in ("compare", "bsearch"):
            import functools

            from repro.kernels import ops as kops

            eng = KTrussEngine(g, granularity="fine", backend="pallas")
            eng._support = functools.partial(
                kops.support_fine,
                eng.problem,
                window=eng.window,
                chunk=eng.chunk,
                schedule=schedule,
            )
            import jax

            fn = jax.jit(eng._support)
            alive = eng.initial_alive()
            fn(alive).block_until_ready()
            t0 = time.perf_counter()
            fn(alive).block_until_ready()
            dt = time.perf_counter() - t0
            rows.append(
                {
                    "graph": g.name,
                    "schedule": schedule,
                    "interpret_ms": round(dt * 1e3, 1),
                    "note": "interpret-mode (CPU emulation, not TPU wall-clock)",
                }
            )
    return rows


def main() -> None:
    print("# structural model (v5e)")
    rows = kernel_structure_rows()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    print("# interpret-mode end-to-end")
    rows = run_kernel_bench()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
