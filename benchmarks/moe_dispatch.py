"""Beyond-paper benchmark: coarse vs fine MoE dispatch (the paper's
decomposition applied to expert routing).

Measures, under increasing router skew (the MoE analog of a power-law
degree distribution):
  * wall-clock per MoE layer call (XLA:CPU),
  * dropped-token fraction at equal buffer budget,
  * padded-FLOPs fraction (coarse pays per-expert bucket padding; fine pays
    none — same trade as Alg.2 row padding vs Alg.3 flat tasks).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import MoEConfig
from repro.models.moe import moe_apply, moe_init

__all__ = ["run_moe_dispatch"]


def _cfg(dispatch: str, e=32, k=2, dff=128, d_model=256, cap=1.25):
    base = get_config("kimi-k2-1t-a32b", smoke=True)
    return base.replace(
        d_model=d_model,
        moe=MoEConfig(
            num_experts=e,
            top_k=k,
            d_ff_expert=dff,
            dispatch=dispatch,
            capacity_factor=cap,
        ),
    )


def run_moe_dispatch(tokens: int = 4096, skews=(0.0, 1.0, 2.0, 4.0)):
    rows = []
    rng = np.random.default_rng(0)
    for skew in skews:
        for dispatch in ("coarse", "fine"):
            cfg = _cfg(dispatch)
            p = moe_init(jax.random.PRNGKey(0), cfg)
            # Skew the router: exponentially decaying expert preference.
            bias = -skew * np.arange(cfg.moe.num_experts)
            rk = np.asarray(p["router"]["kernel"], np.float32).copy()
            p["router"]["kernel"] = jnp.asarray(rk * 0.1)
            x = rng.normal(0, 1, (tokens, cfg.d_model)).astype(np.float32)
            x[:, 0] = 1.0  # give the bias a stable channel
            rk2 = np.asarray(p["router"]["kernel"], np.float32).copy()
            rk2[0, :] = bias
            p["router"]["kernel"] = jnp.asarray(rk2)
            xj = jnp.asarray(x)

            fn = jax.jit(lambda pp, xx: moe_apply(pp, xx, cfg))
            y, aux = fn(p, xj)
            jax.block_until_ready(y)
            t0 = time.perf_counter()
            for _ in range(5):
                y, aux = fn(p, xj)
                jax.block_until_ready(y)
            dt = (time.perf_counter() - t0) / 5
            load = np.asarray(aux["expert_load"])
            rows.append(
                {
                    "skew": skew,
                    "dispatch": dispatch,
                    "ms_per_call": round(dt * 1e3, 2),
                    "drop_frac": round(float(aux["moe_drop_frac"]), 4),
                    "pad_frac": round(float(aux.get("moe_pad_frac", 0.0)), 4),
                    "load_imbalance": round(float(load.max() / max(load.mean(), 1e-9)), 2),
                }
            )
    return rows


def main() -> None:
    rows = run_moe_dispatch()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()
