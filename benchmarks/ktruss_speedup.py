"""Fig. 2/3 analog: fine-over-coarse speedup per graph + geomean, K ∈ {3, kmax}.

The paper reports geomean speedups of 1.48×/1.26× (CPU, K=3/K_max) and
16.93×/9.97× (GPU).  On a vector machine the coarse decomposition pays its
imbalance as padding (O(n·W²) vs O(nnz·W)), so our speedups track the
*GPU* regime; the table prints the measured speedup next to the imbalance
statistics that predict it (speedup ≈ coarse_lane_waste / fine_lane_waste),
which is the paper's mechanism made explicit.
"""

from __future__ import annotations

import numpy as np

from repro.configs.ktruss import BENCH_GRAPHS
from repro.core import KTrussEngine
from repro.graphs import imbalance_stats

from .ktruss_table import time_support

__all__ = ["run_speedup"]


def run_speedup(k_setting: str = "k3", max_coarse_edges: int = 40_000):
    rows = []
    speedups = []
    for spec in BENCH_GRAPHS:
        g = spec.build()
        if g.nnz > max_coarse_edges:
            continue
        st = imbalance_stats(g)
        coarse = KTrussEngine(g, granularity="coarse", mode="eager")
        fine = KTrussEngine(g, granularity="fine", mode="eager")
        if k_setting == "kmax":
            # Time the support on the k_max-pruned graph (paper's K=K_max);
            # peel_levels is the per-level-results API (kmax() itself is a
            # single on-device dispatch with no level masks).
            km, results = fine.peel_levels()
            alive = results[-1].alive if results else None
        dt_c = time_support(coarse)
        dt_f = time_support(fine)
        sp = dt_c / dt_f
        speedups.append(sp)
        # Napkin model: work_coarse / work_fine = n·W² / nnz·W = W / avg_deg.
        predicted = st.max_degree / max(g.nnz / g.n, 1e-9)
        rows.append(
            {
                "graph": g.name,
                "speedup_fine_over_coarse": round(sp, 2),
                "predicted_from_imbalance": round(predicted, 2),
                "coarse_ms": round(dt_c * 1e3, 2),
                "fine_ms": round(dt_f * 1e3, 2),
                "coarse_imbalance": round(st.coarse_imbalance, 1),
                "fine_imbalance": round(st.fine_imbalance, 1),
            }
        )
    geo = float(np.exp(np.mean(np.log(speedups)))) if speedups else float("nan")
    return rows, geo


def main() -> None:
    rows, geo = run_speedup()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    print(f"# geomean_speedup,{geo:.2f}")
    print("# paper reference: CPU 1.48x (K=3); GPU 16.93x (K=3)")


if __name__ == "__main__":
    main()
