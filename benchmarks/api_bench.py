"""repro.api benchmark: planner overhead + backend auto-choice per bucket.

Feeds a mixed ktruss/kmax/decompose query stream spanning the generator
families (balanced grids through heavy-tail R-MAT) into one
:class:`repro.api.Session` with the auto backend rule, and reports:

* **planner overhead** — µs/query spent on bucket assignment + the
  imbalance-statistic backend choice (the cost of declarativeness, which
  must stay negligible next to packing and the dispatch);
* **backend per bucket** — which (formulation, kernel, layout) the auto
  rule picked for every shape bucket (the paper's coarse-vs-fine choice,
  made per input);
* throughput + one-dispatch-per-batch accounting (cold, then warm from
  the compile cache).

Writes ``BENCH_api.json`` (``--out PATH``); ``--smoke`` additionally
**asserts** the planner-overhead bound, the one-dispatch contract, and
that the auto rule actually splits the suite across both formulations.
"""

from __future__ import annotations

import json
import sys
import time

from repro.api import Session, TrussQuery
from repro.graphs import barabasi, clustered, erdos, rmat, road

__all__ = ["run_api_bench", "report"]


def _query_stream() -> list[TrussQuery]:
    """Mixed workloads over every generator family (2 seeds each)."""
    queries: list[TrussQuery] = []
    for s in range(2):
        queries += [
            TrussQuery.decompose(erdos(100, 6.0, seed=s)),
            TrussQuery.ktruss(barabasi(120, 3, seed=s), k=3 + s),
            TrussQuery.kmax(clustered(3, 16, 0.6, seed=s)),
            TrussQuery.decompose(road(8, 0.1, seed=s)),
            TrussQuery.kmax(rmat(6, 4, seed=s)),
        ]
    return queries


def run_api_bench(*, chunk: int = 64, max_batch: int = 4) -> dict:
    session = Session(kernel="xla", max_batch=max_batch, chunk=chunk)
    queries = _query_stream()

    t0 = time.perf_counter()
    session.solve(queries)
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    session.solve(queries)
    warm_s = time.perf_counter() - t0

    st = session.stats()
    return {
        "queries": 2 * len(queries),
        "cold_queries_per_s": round(len(queries) / cold_s, 3),
        "warm_queries_per_s": round(len(queries) / warm_s, 3),
        "plan_us_per_query": st["planner_plan_us_per_query"],
        "device_dispatches": st["device_dispatches"],
        "batches_run": st["batches_run"],
        "cache_compiles": st["cache_compiles"],
        "cache_hit_rate": st["cache_hit_rate"],
        # one row per (bucket, backend) the auto rule chose, with counts
        "backends": st["planner_backends"],
    }


def report(row: dict) -> None:
    for k, v in row.items():
        if k != "backends":
            print(f"{k},{v}")
    for choice in row["backends"]:
        print(f"backend,{choice['bucket']},{choice['backend']},{choice['queries']}")
    print(
        f"bench,api_planner_overhead,{row['plan_us_per_query']},"
        f"warm_q_s={row['warm_queries_per_s']}"
    )


def main() -> None:
    out = None
    args = list(sys.argv[1:])
    if "--out" in args:
        out = args[args.index("--out") + 1]
        del args[args.index("--out") : args.index("--out") + 2]
    smoke = "--smoke" in args
    row = run_api_bench()
    report(row)
    if smoke:
        # Declarativeness must stay cheap: the assignment (bucket +
        # imbalance stats + registry lookup) is host numpy over the
        # degree arrays — O(nnz) with tiny constants.
        assert row["plan_us_per_query"] < 50_000, row
        # One dispatch per formed batch, through the new front door.
        assert row["device_dispatches"] == row["batches_run"], row
        # The auto rule must actually exercise BOTH formulations on this
        # suite (road grids -> coarse, heavy tails -> fine).
        chosen = {c["backend"] for c in row["backends"]}
        assert any(b.startswith("fine/") for b in chosen), row
        assert any(b.startswith("coarse/") for b in chosen), row
        print("# smoke OK: planner overhead + one-dispatch + both formulations")
    if out:
        with open(out, "w") as f:
            json.dump(row, f, indent=2)
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
