"""repro.obs benchmark: instrument overhead + observed load imbalance.

Two questions this answers, feeding ``BENCH_obs.json``:

* **what does observability cost?** — the same warm mixed-workload solve
  timed with tracing off (the shared no-op tracer) and on (recording
  spans into the ring).  Both sessions run from warm compile caches and
  the delta is best-of-N to denoise; it must stay a small fraction of
  the dispatch-dominated solve.
* **what imbalance do real dispatches see?** — the per-(bucket, backend)
  roll-up of ``peel_batch_imbalance`` (max/mean per-slot iterations, the
  runtime analog of the paper's max/mean work statistic) over a suite
  mixing heavy-tail graphs (R-MAT, Barabási — where the paper's
  fine-grained win lives) with balanced road grids.

Writes ``BENCH_obs.json`` (``--out PATH``) and a sample Chrome trace
(``--trace-out PATH``); ``--smoke`` additionally **asserts** the
overhead bound, that the traced run produced well-formed span events,
and that imbalance telemetry was recorded per (bucket, backend).
"""

from __future__ import annotations

import json
import sys
import time

from repro.api import Session, TrussQuery
from repro.graphs import barabasi, rmat, road
from repro.obs import imbalance_summary

__all__ = ["run_obs_bench", "report"]


def _query_stream() -> list[TrussQuery]:
    """Heavy-tail (R-MAT, Barabási) + balanced (road) decomposes."""
    queries: list[TrussQuery] = []
    for s in range(2):
        queries += [
            TrussQuery.decompose(rmat(6, 6, seed=s)),
            TrussQuery.decompose(barabasi(120, 4, seed=s)),
            TrussQuery.decompose(road(8, 0.1, seed=s)),
        ]
    return queries


def _best_warm_solve_s(session: Session, queries, repeats: int) -> float:
    session.solve(queries)  # warm-up: compiles into the session's cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        session.solve(queries)
        best = min(best, time.perf_counter() - t0)
    return best


def run_obs_bench(
    *,
    chunk: int = 64,
    max_batch: int = 4,
    repeats: int = 5,
    trace_out: str | None = None,
) -> dict:
    queries = _query_stream()
    kw = dict(kernel="xla", max_batch=max_batch, chunk=chunk)

    off = Session(trace=False, **kw)
    off_s = _best_warm_solve_s(off, queries, repeats)

    on = Session(trace=True, **kw)
    on_s = _best_warm_solve_s(on, queries, repeats)

    overhead_frac = (on_s - off_s) / off_s if off_s > 0 else 0.0
    events = on.obs.tracer.events()
    if trace_out:
        on.export_trace(trace_out)

    return {
        "queries_per_solve": len(queries),
        "repeats_best_of": repeats,
        "untraced_solve_s": round(off_s, 6),
        "traced_solve_s": round(on_s, 6),
        "trace_overhead_frac": round(overhead_frac, 4),
        "trace_events_total": len(events),
        "span_names": sorted({e["name"] for e in events}),
        # per-(bucket, backend) observed imbalance — the traced session
        # saw every dispatch, so its registry holds the full roll-up
        "imbalance": imbalance_summary(on.obs.metrics),
        "trace_sample": trace_out,
    }


def report(row: dict) -> None:
    for k, v in row.items():
        if k not in ("imbalance", "span_names"):
            print(f"{k},{v}")
    print("spans," + "|".join(row["span_names"]))
    for r in row["imbalance"]:
        print(
            f"imbalance,{r['bucket']},{r['backend']},"
            f"mean={r['mean_imbalance']},max={r['max_imbalance']},"
            f"slot_iters_max={r['slot_iters_max']}"
        )
    print(
        f"bench,obs_overhead,{row['trace_overhead_frac']},"
        f"traced_s={row['traced_solve_s']}"
    )


def main() -> None:
    out = trace_out = None
    args = list(sys.argv[1:])
    if "--out" in args:
        out = args[args.index("--out") + 1]
        del args[args.index("--out") : args.index("--out") + 2]
    if "--trace-out" in args:
        trace_out = args[args.index("--trace-out") + 1]
        del args[args.index("--trace-out") : args.index("--trace-out") + 2]
    smoke = "--smoke" in args
    row = run_obs_bench(trace_out=trace_out)
    report(row)
    if smoke:
        # Tracing must not meaningfully tax the dispatch-dominated path
        # (the bound is loose: CI timing noise, not the instrument, sets
        # the floor — the recording itself is ~a dict append per span).
        assert row["trace_overhead_frac"] < 0.25, row
        # The traced run recorded every stage of the query path.
        assert {"solve", "plan", "pack", "compile", "dispatch"} <= set(
            row["span_names"]
        ), row
        # Imbalance telemetry landed, labeled, and is a ratio >= 1.
        assert row["imbalance"], row
        assert all(
            r["bucket"] and r["backend"] and r["mean_imbalance"] >= 1.0
            for r in row["imbalance"]
        ), row
        print("# smoke OK: overhead bound + spans + labeled imbalance")
    if out:
        with open(out, "w") as f:
            json.dump(row, f, indent=2)
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
