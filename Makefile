PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-chaos bench-smoke bench-peel bench-stream bench-api bench-obs bench-kernels bench-serve lint lint-analysis

# Tier-1 verify (see ROADMAP.md).
test:
	$(PYTHON) -m pytest -x -q

# Chaos gate: fault storms (dispatch/oom/compile/poison/clock-skew) over
# 3 fixed seeds; writes the storm's metrics snapshot (retries, fallbacks,
# quarantines, faults injected) to CHAOS_metrics.json for CI to archive.
test-chaos:
	CHAOS_METRICS_OUT=CHAOS_metrics.json \
		$(PYTHON) -m pytest tests/test_chaos.py tests/test_resilience.py -q

# Tiny serving benchmark: 6 small graphs, batch widths 1 and 2.
bench-smoke:
	$(PYTHON) -m benchmarks.service_bench --smoke

# On-device peel benchmark -> BENCH_peel.json (decompose graphs/s at batch
# widths {1, 8}, sharded over 8 simulated host devices vs unsharded).
bench-peel:
	XLA_FLAGS="--xla_force_host_platform_device_count=8 $$XLA_FLAGS" \
		$(PYTHON) -m benchmarks.peel_bench --out BENCH_peel.json

# Streaming-update benchmark -> BENCH_stream.json (updates/s + frontier
# ratio at batch widths {1, 16, 256}; smoke asserts the frontier bound and
# the one-full-triangle-enumeration-per-session cache claim).
bench-stream:
	$(PYTHON) -m benchmarks.stream_bench --smoke --out BENCH_stream.json

# Declarative API benchmark -> BENCH_api.json (planner overhead µs/query +
# which backend the auto rule chose per shape bucket; smoke asserts the
# one-dispatch contract and that both formulations are exercised).
bench-api:
	$(PYTHON) -m benchmarks.api_bench --smoke --out BENCH_api.json

# Observability benchmark -> BENCH_obs.json (tracing overhead on/off +
# per-(bucket, backend) observed imbalance) and a sample Chrome trace
# (BENCH_trace_sample.json); smoke asserts the overhead bound and that
# every query-path stage shows up as a span.
bench-obs:
	$(PYTHON) -m benchmarks.obs_bench --smoke --out BENCH_obs.json \
		--trace-out BENCH_trace_sample.json

# Kernel benchmark -> BENCH_kernels.json (structural tile models + the
# fused-vs-xla-vs-pallas speedup table per shape bucket, one autotuned
# fused config each; smoke asserts a warm-path fused win on a skewed
# bucket, fused/XLA bit-parity, and autotune-store replay).
bench-kernels:
	$(PYTHON) -m benchmarks.kernels_bench --smoke --out BENCH_kernels.json

# Fleet benchmark -> BENCH_serve.json (queries/s + p50/p99 latency at
# 1 vs 3 replica processes under mixed-bucket traffic, plus the router's
# affinity hit rate; smoke asserts bit-identical-to-solve() and an
# affinity hit rate above 0.8 on the 3-replica fleet).
bench-serve:
	$(PYTHON) -m benchmarks.serve_bench --smoke --out BENCH_serve.json

# Byte-compile gate (no extra tooling required) + ruff when available
# (CI installs it via requirements-dev.txt; bare containers skip it).
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "ruff not installed; skipped (pip install -r requirements-dev.txt)"; \
	fi

# Repo-native static analysis (rules R1-R6, see src/repro/analysis/).
# Pure-stdlib AST pass: fails on any finding not in analysis/baseline.json
# (which ships empty — the dispatch-path and serve layers are lint-clean)
# and writes ANALYSIS_report.json for CI to archive.
lint-analysis:
	$(PYTHON) -m repro.analysis --report ANALYSIS_report.json
