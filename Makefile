PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench-peel bench-stream lint

# Tier-1 verify (see ROADMAP.md).
test:
	$(PYTHON) -m pytest -x -q

# Tiny serving benchmark: 6 small graphs, batch widths 1 and 2.
bench-smoke:
	$(PYTHON) -m benchmarks.service_bench --smoke

# On-device peel benchmark -> BENCH_peel.json (decompose graphs/s at batch
# widths {1, 8}, sharded over 8 simulated host devices vs unsharded).
bench-peel:
	XLA_FLAGS="--xla_force_host_platform_device_count=8 $$XLA_FLAGS" \
		$(PYTHON) -m benchmarks.peel_bench --out BENCH_peel.json

# Streaming-update benchmark -> BENCH_stream.json (updates/s + frontier
# ratio at batch widths {1, 16, 256}; smoke asserts the frontier bound).
bench-stream:
	$(PYTHON) -m benchmarks.stream_bench --smoke --out BENCH_stream.json

# Byte-compile everything (import/syntax gate; no extra tooling required).
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
