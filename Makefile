PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke lint

# Tier-1 verify (see ROADMAP.md).
test:
	$(PYTHON) -m pytest -x -q

# Tiny serving benchmark: 6 small graphs, batch widths 1 and 2.
bench-smoke:
	$(PYTHON) -m benchmarks.service_bench --smoke

# Byte-compile everything (import/syntax gate; no extra tooling required).
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
