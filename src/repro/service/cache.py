"""Deprecation shim: the shape-bucket compile cache moved to ``repro.api``.

Everything here re-exports from :mod:`repro.api.cache` so existing
imports (``from repro.service.cache import bucket_for``) keep working one
release; new code should import from ``repro.api``.
"""

from __future__ import annotations

from ..api.cache import (  # noqa: F401 — re-exports
    Bucket,
    CacheStats,
    CompileCache,
    bucket_for,
    build_peel,
    enable_persistent_cache,
)

__all__ = [
    "Bucket",
    "bucket_for",
    "build_peel",
    "CompileCache",
    "enable_persistent_cache",
]
