"""Deprecation shim: the shape-bucket compile cache moved to ``repro.api``.

Everything here re-exports from :mod:`repro.api.cache` so existing
imports (``from repro.service.cache import bucket_for``) keep working one
release; new code should import from ``repro.api``.  Importing this
module raises a :class:`DeprecationWarning`.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.service.cache is deprecated; import from repro.api instead "
    "(e.g. `from repro.api import bucket_for, CompileCache`)",
    DeprecationWarning,
    stacklevel=2,
)

from ..api.cache import (  # noqa: E402, F401 — re-exports
    Bucket,
    CacheStats,
    CompileCache,
    bucket_for,
    build_peel,
    enable_persistent_cache,
)

__all__ = [
    "Bucket",
    "bucket_for",
    "build_peel",
    "CompileCache",
    "enable_persistent_cache",
]
