"""Shape-bucket compile cache for the K-truss serving layer.

XLA (and Pallas) executables are specialized to static shapes, so a naive
server recompiles the fixed-point program for every distinct graph — tens
of milliseconds to seconds per request.  Canonicalizing every incoming
graph to power-of-two ``(n_pad, nnz_pad, window)`` buckets collapses the
shape space: one executable per bucket serves every request (and every
micro-batch) that lands in it.  GraphBLAST makes the same bet — reusable
kernels behind a stable API beat per-input specialization.

The compiled artifact is a *problem-polymorphic* fixed point: unlike
``KTrussEngine`` (which closes over one graph's arrays), the executable
takes the :class:`FineProblem` pytree as an argument, so any same-bucket
problem — including a block-diagonal batch of them — reuses the program.
The prune threshold is a per-edge vector, which lets one dispatch run
different k values (and mixed ktruss/kmax/decompose workloads) for
different members of a packed batch.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.eager_fine import FineProblem, support_fine_eager, support_fine_owner
from ..graphs.csr import CSRGraph

__all__ = ["Bucket", "bucket_for", "build_fixed_point", "CompileCache"]


class Bucket(NamedTuple):
    """Canonical power-of-two shape class of one graph slot.

    A graph in this bucket is packed to ``n_pad`` vertices, ``nnz_pad``
    directed nonzeros (twice that undirected) and intersected with windows
    of width ``window``.  Batches of B same-bucket graphs use the scaled
    shapes ``(B * n_pad, B * nnz_pad)``; the executable cache key is
    ``(bucket, slots)``.
    """

    n_pad: int
    nnz_pad: int
    window: int


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def bucket_for(g: CSRGraph, *, chunk: int = 256, min_window: int = 8) -> Bucket:
    """Canonical shape bucket of one graph.

    The window is sized to the max *undirected* degree so one bucket is
    valid for every support mode (eager needs out-degree, owner/pallas need
    the symmetric degree).
    """
    deg = g.degrees()
    indeg = np.bincount(g.colidx, minlength=g.n + 1)
    und_max = int((deg + indeg).max(initial=0))
    return Bucket(
        n_pad=_next_pow2(max(g.n, 1)),
        nnz_pad=_next_pow2(max(g.nnz, chunk)),
        window=_next_pow2(max(min_window, und_max)),
    )


def build_fixed_point(
    *,
    mode: str = "eager",
    backend: str = "xla",
    window: int,
    chunk: int = 256,
    max_iters: int = 1_000,
) -> Callable:
    """Compile-cachable fixed point ``(problem, alive0, thresh) -> (alive, support, iters)``.

    ``thresh`` is a per-edge int32 vector (``k - 2`` on each member's edge
    range in a packed batch), traced rather than static so one executable
    serves every k.  Shapes come from the arguments, so the jit cache holds
    exactly one entry per shape bucket.
    """
    if backend == "pallas":
        from ..kernels import ops as kernel_ops  # lazy: keeps service dep-light

        support = functools.partial(
            kernel_ops.support_fine, window=window, chunk=chunk
        )
    elif backend != "xla":
        raise ValueError(f"unknown backend {backend!r}")
    elif mode == "owner":
        support = functools.partial(support_fine_owner, window=window, chunk=chunk)
    elif mode == "eager":
        support = functools.partial(support_fine_eager, window=window, chunk=chunk)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    def fixed_point(p: FineProblem, alive0: jax.Array, thresh: jax.Array):
        def cond(state):
            _, _, changed, it = state
            return changed & (it < max_iters)

        def body(state):
            alive, _, _, it = state
            s = support(p, alive)
            new_alive = alive & (s >= thresh)
            changed = jnp.any(new_alive != alive)
            return new_alive, s * new_alive.astype(s.dtype), changed, it + 1

        state = (alive0, jnp.zeros_like(alive0, jnp.int32), jnp.asarray(True), 0)
        alive, s, _, it = jax.lax.while_loop(cond, body, state)
        return alive, s, it

    return jax.jit(fixed_point)


@dataclasses.dataclass
class CacheStats:
    compiles: int = 0
    hits: int = 0

    @property
    def requests(self) -> int:
        return self.compiles + self.hits

    @property
    def hit_rate(self) -> float:
        return self.hits / self.requests if self.requests else 0.0

    def row(self) -> dict:
        return {
            "compiles": self.compiles,
            "hits": self.hits,
            "hit_rate": round(self.hit_rate, 4),
        }


class CompileCache:
    """Executable store keyed by ``(bucket, slots)`` with hit/miss counters.

    Each key maps to one jitted fixed point built by ``builder(key)``; a
    key's executable only ever sees one argument-shape signature (the
    bucket-canonical one), so ``compiles`` counts actual XLA compilations,
    not just builder calls.
    """

    def __init__(self, builder: Callable[[tuple[Bucket, int]], Callable]):
        self._builder = builder
        self._exes: dict[tuple[Bucket, int], Callable] = {}
        self._lock = threading.Lock()
        self.stats = CacheStats()

    def get(self, bucket: Bucket, slots: int) -> tuple[Callable, bool]:
        """Return (executable, was_hit) for one bucket/batch-width key."""
        key = (bucket, int(slots))
        with self._lock:
            exe = self._exes.get(key)
            if exe is not None:
                self.stats.hits += 1
                return exe, True
            self.stats.compiles += 1
            exe = self._exes[key] = self._builder(key)
            return exe, False

    def __len__(self) -> int:
        return len(self._exes)
