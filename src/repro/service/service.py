"""TrussService: batched, cache-aware K-truss serving front end.

Workloads (per request):

* ``ktruss(k)``    — membership mask + supports of the k-truss.
* ``kmax()``       — largest non-empty truss, warm-started level by level.
* ``decompose()``  — full truss decomposition (trussness per edge).

Flow: ``submit_*`` canonicalizes the graph to a shape bucket and enqueues;
``flush`` drains the queue in same-bucket micro-batches.  Each batch is
packed block-diagonally, the bucket's cached executable runs the
fixed point with a *per-edge* threshold vector (so mixed workloads and
mixed k share one dispatch), and level peeling advances kmax/decompose
members while ktruss members complete on the first round.  Futures resolve
on flush (or transparently on ``result()``); per-request stats expose
queue/pack/device time and whether the batch hit the compile cache.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..core.truss import KTrussResult, TrussDecomposition
from ..graphs.csr import CSRGraph
from .batcher import MicroBatcher, Request, RequestStats
from .cache import Bucket, CompileCache, bucket_for, build_fixed_point

__all__ = ["TrussFuture", "TrussService"]


class TrussFuture:
    """Handle to a submitted request; resolves when its batch is flushed."""

    def __init__(self, service: "TrussService", request: Request):
        self._service = service
        self.request = request
        self._result: Any = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def result(self) -> Any:
        if not self._done:
            self._service.flush()
        if not self._done:
            raise RuntimeError(f"request {self.request.id} did not resolve")
        return self._result

    @property
    def stats(self) -> RequestStats:
        return self.request.stats

    def _resolve(self, result: Any) -> None:
        self._result = result
        self._done = True


@dataclasses.dataclass
class _Member:
    """Per-request state while its batch peels levels."""

    future: TrussFuture
    sl: slice
    cur_k: int
    active: bool = True
    # kmax / decompose accumulators
    kmax: int = 0
    levels: int = 0
    level_results: list = dataclasses.field(default_factory=list)
    trussness: np.ndarray | None = None
    prev_edges: int = 0

    @property
    def request(self) -> Request:
        return self.future.request


class TrussService:
    """Batched multi-graph K-truss serving over one compile cache."""

    def __init__(
        self,
        *,
        mode: str = "eager",
        backend: str = "xla",
        max_batch: int = 8,
        chunk: int = 256,
        max_iters: int = 1_000,
    ):
        if chunk & (chunk - 1):
            raise ValueError(f"chunk={chunk} must be a power of two")
        self.mode = mode
        self.backend = backend
        self.chunk = int(chunk)
        self.max_iters = int(max_iters)
        self.batcher = MicroBatcher(max_batch=max_batch, chunk=chunk)
        self.cache = CompileCache(self._build_executable)
        self._futures: dict[int, TrussFuture] = {}
        self.requests_served = 0
        self.batches_run = 0
        self.device_time_s = 0.0

    # ------------------------------------------------------------------ #
    # Submission
    # ------------------------------------------------------------------ #
    def submit(self, g: CSRGraph, workload: str = "ktruss", *, k: int = 3) -> TrussFuture:
        if workload not in ("ktruss", "kmax", "decompose"):
            raise ValueError(f"unknown workload {workload!r}")
        if k < 3:
            raise ValueError("k must be >= 3")
        bucket = bucket_for(g, chunk=self.chunk)
        req = Request(graph=g, workload=workload, k=int(k), bucket=bucket)
        fut = TrussFuture(self, req)
        self._futures[req.id] = fut
        self.batcher.enqueue(req)
        return fut

    def submit_ktruss(self, g: CSRGraph, k: int) -> TrussFuture:
        return self.submit(g, "ktruss", k=k)

    def submit_kmax(self, g: CSRGraph, k_start: int = 3) -> TrussFuture:
        return self.submit(g, "kmax", k=k_start)

    def submit_decompose(self, g: CSRGraph, k_start: int = 3) -> TrussFuture:
        return self.submit(g, "decompose", k=k_start)

    # ------------------------------------------------------------------ #
    # Batch execution
    # ------------------------------------------------------------------ #
    def poll(self) -> int:
        """Run at most one micro-batch; returns how many requests resolved."""
        batch = self.batcher.next_batch()
        if not batch:
            return 0
        return self._run_batch(batch)

    def flush(self) -> int:
        """Drain the queue; returns how many requests resolved."""
        n = 0
        while len(self.batcher):
            n += self.poll()
        return n

    def _build_executable(self, key: tuple[Bucket, int]):
        bucket, _slots = key
        return build_fixed_point(
            mode=self.mode,
            backend=self.backend,
            window=bucket.window,
            chunk=self.chunk,
            max_iters=self.max_iters,
        )

    def _run_batch(self, batch: list[Request]) -> int:
        bucket = batch[0].bucket
        packed = self.batcher.pack(batch)
        exe, hit = self.cache.get(bucket, self.batcher.max_batch)
        for req in batch:
            req.stats.compile_hit = hit

        p = packed.problem
        total = p.nnz_pad
        members = [
            _Member(
                future=self._futures.pop(req.id),
                sl=slice(a, b),
                cur_k=req.k,
                trussness=(
                    np.full(b - a, max(2, req.k - 1), dtype=np.int32)
                    if req.workload == "decompose"
                    else None
                ),
                prev_edges=b - a,
            )
            for req, (a, b) in zip(batch, packed.edge_ranges)
        ]
        # Edgeless graphs resolve without touching the device.
        for m in members:
            if m.prev_edges == 0:
                self._finalize_empty(m)

        alive = jnp.asarray(p.colidx != 0)
        rounds = 0
        total_iters = 0
        while any(m.active for m in members):
            # Finished members keep their last threshold: their alive mask is
            # already a fixed point for it, so re-running them is idempotent
            # and adds no prune iterations.
            thresh_np = self.batcher.member_thresh(
                packed, [m.cur_k - 2 for m in members], total
            )
            t0 = time.perf_counter()
            alive, support, it = exe(p, alive, jnp.asarray(thresh_np))
            alive.block_until_ready()
            dt = time.perf_counter() - t0
            self.device_time_s += dt
            rounds += 1
            total_iters += int(it)
            alive_np = np.asarray(alive)
            support_np = np.asarray(support)
            for m in members:
                if m.active:
                    self._advance(m, alive_np[m.sl], support_np[m.sl], int(it))
            for m in members:
                m.request.stats.device_time_s += dt

        for m in members:
            m.request.stats.rounds = rounds
            m.request.stats.iterations = total_iters
        self.batches_run += 1
        self.requests_served += len(batch)
        return len(batch)

    def _advance(self, m: _Member, alive: np.ndarray, support: np.ndarray, iters: int) -> None:
        req = m.request
        edges = int(alive.sum())
        res = KTrussResult(
            k=m.cur_k,
            alive=alive.copy(),
            support=support.copy(),
            iterations=iters,
            edges_remaining=edges,
        )
        if req.workload == "ktruss":
            m.active = False
            m.future._resolve(res)
            return
        m.levels += 1
        if edges:
            m.kmax = m.cur_k
            if req.workload == "kmax":
                m.level_results.append(res)
            else:
                m.trussness[alive] = m.cur_k
            m.cur_k += 1
            return
        m.active = False
        if req.workload == "kmax":
            m.future._resolve((m.kmax, m.level_results))
        else:
            m.future._resolve(
                TrussDecomposition(
                    trussness=m.trussness,
                    kmax=int(m.trussness.max(initial=0)) if m.trussness.size else 0,
                    levels=m.levels,
                )
            )

    def _finalize_empty(self, m: _Member) -> None:
        req = m.request
        m.active = False
        if req.workload == "ktruss":
            empty = np.zeros(0, dtype=bool)
            m.future._resolve(
                KTrussResult(
                    k=req.k,
                    alive=empty,
                    support=np.zeros(0, dtype=np.int32),
                    iterations=0,
                    edges_remaining=0,
                )
            )
        elif req.workload == "kmax":
            m.future._resolve((0, []))
        else:
            m.future._resolve(
                TrussDecomposition(
                    trussness=np.zeros(0, dtype=np.int32), kmax=0, levels=0
                )
            )

    # ------------------------------------------------------------------ #
    # Observability
    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return {
            "requests_served": self.requests_served,
            "batches_run": self.batches_run,
            "pending": len(self.batcher),
            "device_time_s": round(self.device_time_s, 6),
            **{f"cache_{k}": v for k, v in self.cache.stats.row().items()},
        }
