"""TrussService: batched, cache-aware K-truss serving front end.

Workloads (per request):

* ``ktruss(k)``    — membership mask + supports of the k-truss.
* ``kmax()``       — largest non-empty truss (int).
* ``decompose()``  — full truss decomposition (trussness per edge).

Flow: ``submit_*`` canonicalizes the graph to a shape bucket and enqueues;
``flush`` drains the queue in same-bucket micro-batches.  Each batch is
packed block-diagonally with slot-aligned edge lanes and handed to the
bucket's cached :class:`repro.exec.PeelExecutor`, which peels **every**
truss level of **every** member on device in ONE dispatch — per-slot
thresholds advance inside the compiled loop, ktruss members retire at
their first fixed point, kmax/decompose members peel to exhaustion — and
the service reads back one final ``(alive, support, trussness, kmax,
levels)`` state.  With ``mesh=`` the packed slot blocks are sharded across
devices (``repro.distributed.ktruss``).  Futures resolve on flush (or
transparently on ``result()``, which polls only the owning request's
bucket); per-request stats expose queue/pack/device time, per-member
levels/iterations, and whether the batch hit the compile cache.
"""

from __future__ import annotations

import time
from typing import Any

import numpy as np

from ..core.truss import KTrussResult, TrussDecomposition
from ..graphs.csr import CSRGraph
from .batcher import MicroBatcher, Request, RequestStats
from .cache import (
    Bucket,
    CompileCache,
    bucket_for,
    build_peel,
    enable_persistent_cache,
)

__all__ = ["TrussFuture", "TrussService"]


class TrussFuture:
    """Handle to a submitted request; resolves when its batch runs."""

    def __init__(self, service: "TrussService", request: Request):
        self._service = service
        self.request = request
        self._result: Any = None
        self._error: BaseException | None = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def result(self) -> Any:
        if not self._done:
            # Poll only the owning request's bucket — other buckets' queued
            # work stays queued for their own flush/poll.
            self._service.resolve(self.request)
        if not self._done:
            raise RuntimeError(f"request {self.request.id} did not resolve")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def stats(self) -> RequestStats:
        return self.request.stats

    def _resolve(self, result: Any) -> None:
        self._result = result
        self._done = True

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._done = True


class TrussService:
    """Batched multi-graph K-truss serving over one compile cache."""

    def __init__(
        self,
        *,
        mode: str = "eager",
        backend: str = "xla",
        max_batch: int = 8,
        chunk: int = 256,
        max_iters: int | None = None,
        mesh=None,
        cache_dir: str | None = None,
    ):
        if chunk & (chunk - 1):
            raise ValueError(f"chunk={chunk} must be a power of two")
        if cache_dir is not None:
            # Persist compiled executables across processes (ROADMAP
            # "compile-cache persistence"): a restarted server warm-starts
            # its first compile per bucket from disk.
            enable_persistent_cache(cache_dir)
        self.mode = mode
        self.backend = backend
        self.chunk = int(chunk)
        # None = the peel's provable iteration bound; an explicit cap that
        # fires raises instead of returning truncated results.
        self.max_iters = None if max_iters is None else int(max_iters)
        self.mesh = mesh
        if mesh is not None:
            mesh_size = int(np.prod(list(dict(mesh.shape).values())))
            if max_batch % mesh_size:
                raise ValueError(
                    f"max_batch={max_batch} must divide evenly over the "
                    f"mesh's {mesh_size} devices (slots shard whole)"
                )
            mesh_key = (tuple(mesh.axis_names), tuple(dict(mesh.shape).values()))
        else:
            mesh_key = None
        self._layout = ("aligned", mesh_key)
        self.batcher = MicroBatcher(max_batch=max_batch, chunk=chunk)
        self.cache = CompileCache(self._build_executor)
        self._slot_ids: dict[int, Any] = {}  # bucket nnz_pad -> device array
        self._futures: dict[int, TrussFuture] = {}
        self.requests_served = 0
        self.batches_run = 0
        self.device_dispatches = 0
        self.device_time_s = 0.0

    # ------------------------------------------------------------------ #
    # Submission
    # ------------------------------------------------------------------ #
    def submit(self, g: CSRGraph, workload: str = "ktruss", *, k: int = 3) -> TrussFuture:
        if workload not in ("ktruss", "kmax", "decompose"):
            raise ValueError(f"unknown workload {workload!r}")
        if k < 3:
            raise ValueError("k must be >= 3")
        bucket = bucket_for(g, chunk=self.chunk)
        req = Request(graph=g, workload=workload, k=int(k), bucket=bucket)
        fut = TrussFuture(self, req)
        self._futures[req.id] = fut
        self.batcher.enqueue(req)
        return fut

    def submit_ktruss(self, g: CSRGraph, k: int) -> TrussFuture:
        return self.submit(g, "ktruss", k=k)

    def submit_kmax(self, g: CSRGraph, k_start: int = 3) -> TrussFuture:
        return self.submit(g, "kmax", k=k_start)

    def submit_decompose(self, g: CSRGraph, k_start: int = 3) -> TrussFuture:
        return self.submit(g, "decompose", k=k_start)

    def submit_stream(
        self,
        g: CSRGraph,
        *,
        frontier: np.ndarray,
        frozen_truss: np.ndarray,
    ) -> TrussFuture:
        """Submit a frontier-bounded re-peel (the streaming update kernel).

        ``frontier`` marks the member's edges that are free to peel;
        the complement is frozen at ``frozen_truss`` (its maintained
        trussness) and only contributes support while the threshold is
        inside its truss.  The future resolves to the member's full
        (nnz,) trussness — frontier lanes re-peeled, frozen lanes passed
        through.  Rides the same bucket queue / micro-batcher / compile
        cache as ordinary requests, so concurrent streams (and plain
        decomposes) coalesce into shared dispatches.
        """
        frontier = np.asarray(frontier, bool)
        frozen_truss = np.asarray(frozen_truss, np.int32)
        if frontier.shape[0] != g.nnz or frozen_truss.shape[0] != g.nnz:
            raise ValueError(
                f"frontier/frozen_truss must cover all {g.nnz} edges"
            )
        bucket = bucket_for(g, chunk=self.chunk)
        req = Request(
            graph=g,
            workload="stream",
            k=3,
            bucket=bucket,
            alive0=frontier,
            frozen_truss=frozen_truss,
        )
        fut = TrussFuture(self, req)
        self._futures[req.id] = fut
        self.batcher.enqueue(req)
        return fut

    def open_stream(self, g: CSRGraph, trussness: np.ndarray | None = None):
        """Open a :class:`repro.stream.StreamingTrussSession` on this service.

        Runs the initial full decompose through the ordinary batched path
        unless ``trussness`` is supplied; subsequent ``update()`` batches
        are frontier-bounded re-peels submitted via :meth:`submit_stream`.
        """
        from ..stream.session import StreamingTrussSession  # lazy: no cycle

        return StreamingTrussSession(self, g, trussness=trussness)

    # ------------------------------------------------------------------ #
    # Batch execution
    # ------------------------------------------------------------------ #
    def poll(self) -> int:
        """Run at most one micro-batch; returns how many requests resolved."""
        batch = self.batcher.next_batch()
        if not batch:
            return 0
        return self._run_batch(batch)

    def flush(self) -> int:
        """Drain the queue; returns how many requests resolved."""
        n = 0
        while len(self.batcher):
            n += self.poll()
        return n

    def resolve(self, request: Request) -> None:
        """Run batches from ``request``'s bucket until it resolves.

        Unlike :meth:`flush` this never touches other buckets' queued
        requests — a ``result()`` call on one future does not drain the
        whole service.
        """
        while request.id in self._futures:
            batch = self.batcher.next_batch(bucket=request.bucket)
            if not batch:
                raise RuntimeError(
                    f"request {request.id} is unresolved but not queued"
                )
            self._run_batch(batch)

    def _build_executor(self, key: tuple[Bucket, int, Any]):
        bucket, _slots, _layout = key
        return build_peel(
            mode=self.mode,
            backend=self.backend,
            window=bucket.window,
            chunk=self.chunk,
            max_iters=self.max_iters,
            mesh=self.mesh,
        )

    def _run_batch(self, batch: list[Request]) -> int:
        bucket = batch[0].bucket
        packed = self.batcher.pack(batch)
        exe, hit = self.cache.get(bucket, self.batcher.max_batch, self._layout)
        for req in batch:
            req.stats.compile_hit = hit

        slots = self.batcher.max_batch
        slot_ids = self._slot_ids.get(bucket.nnz_pad)
        if slot_ids is None:
            import jax.numpy as jnp

            slot_ids = self._slot_ids[bucket.nnz_pad] = jnp.asarray(
                np.repeat(np.arange(slots, dtype=np.int32), bucket.nnz_pad)
            )
        k0 = np.full(slots, 3, np.int32)
        single_level = np.zeros(slots, bool)
        for i, req in enumerate(batch):
            k0[i] = req.k
            single_level[i] = req.workload == "ktruss"

        # Streaming members peel only their affected frontier; the rest of
        # their lanes are frozen at the session's maintained trussness.
        # Ordinary members stay on the executor's defaults (fully alive,
        # nothing frozen) — zeros here reproduce those defaults exactly.
        alive0 = frozen = frozen_truss = None
        if any(req.alive0 is not None for req in batch):
            import jax.numpy as jnp

            nnzp_total = slots * bucket.nnz_pad
            alive_np = np.asarray(packed.problem.colidx) != 0
            frozen_np = np.zeros(nnzp_total, bool)
            ft_np = np.zeros(nnzp_total, np.int32)
            for req, (a, b) in zip(batch, packed.edge_ranges):
                if req.alive0 is None:
                    continue
                alive_np[a:b] = req.alive0
                frozen_np[a:b] = ~req.alive0
                ft_np[a:b] = req.frozen_truss
            alive0 = jnp.asarray(alive_np)
            frozen = jnp.asarray(frozen_np)
            frozen_truss = jnp.asarray(ft_np)

        t0 = time.perf_counter()
        # peel() synchronizes internally (its iteration-cap check reads back
        # the done flags), so dt covers the whole dispatch.  The batch was
        # already dequeued, so if the dispatch fails its futures must carry
        # the error — otherwise they are stranded unresolvable.
        try:
            st = exe.peel(
                packed.problem,
                slot_ids=slot_ids,
                k0=k0,
                single_level=single_level,
                alive0=alive0,
                frozen=frozen,
                frozen_truss=frozen_truss,
            )
        except Exception as e:
            for req in batch:
                self._futures.pop(req.id)._fail(e)
            raise
        dt = time.perf_counter() - t0
        self.device_time_s += dt
        self.device_dispatches += 1

        alive = np.asarray(st.alive)
        support = np.asarray(st.support)
        trussness = np.asarray(st.trussness)
        kmax = np.asarray(st.kmax)
        levels = np.asarray(st.levels)
        iters = np.asarray(st.iters)

        for i, (req, (a, b)) in enumerate(zip(batch, packed.edge_ranges)):
            fut = self._futures.pop(req.id)
            req.stats.device_time_s = dt  # the batch's single dispatch
            req.stats.rounds = int(levels[i])
            req.stats.iterations = int(iters[i])
            if req.workload == "ktruss":
                member_alive = alive[a:b].copy()
                fut._resolve(
                    KTrussResult(
                        k=req.k,
                        alive=member_alive,
                        support=support[a:b].copy(),
                        iterations=int(iters[i]),
                        edges_remaining=int(member_alive.sum()),
                    )
                )
            elif req.workload == "kmax":
                fut._resolve(int(kmax[i]))
            elif req.workload == "stream":
                # Full member trussness: frontier lanes re-peeled, frozen
                # lanes passed through by the peel (see exec.build_peel).
                fut._resolve(trussness[a:b].copy())
            else:
                t = trussness[a:b].copy()
                fut._resolve(
                    TrussDecomposition(
                        trussness=t,
                        kmax=int(t.max(initial=0)) if t.size else 0,
                        levels=int(levels[i]),
                    )
                )

        self.batches_run += 1
        self.requests_served += len(batch)
        return len(batch)

    # ------------------------------------------------------------------ #
    # Observability
    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return {
            "requests_served": self.requests_served,
            "batches_run": self.batches_run,
            "device_dispatches": self.device_dispatches,
            "pending": len(self.batcher),
            "device_time_s": round(self.device_time_s, 6),
            **{f"cache_{k}": v for k, v in self.cache.stats.row().items()},
        }
