"""TrussService: the legacy batched serving front end (adapter).

.. deprecated::
    ``TrussService`` is a thin adapter over :class:`repro.api.Session` —
    the declarative query API is the one front door now::

        from repro.api import Session, TrussQuery

        s = Session(max_batch=8)
        fut = s.submit(TrussQuery.ktruss(g, k=4))

    The adapter keeps one release of compatibility: every ``submit_*``
    method builds the equivalent :class:`repro.api.TrussQuery` and hands
    it to the session, so queueing, bucketing, packing, compile caching,
    and dispatch all run through the single ``repro.api`` lowering path.
    ``TrussFuture`` *is* :class:`repro.api.TrussFuture` (re-exported).
"""

from __future__ import annotations

import numpy as np

from ..api.registry import BackendKey
from ..api.session import Session, TrussFuture
from ..api.query import TrussQuery
from ..graphs.csr import CSRGraph

__all__ = ["TrussFuture", "TrussService"]


class TrussService:
    """Batched multi-graph K-truss serving over one compile cache.

    Adapter over :class:`repro.api.Session`: ``mode``/``backend`` pin the
    session to one registry backend (``fine`` formulation, the given
    kernel, slot-aligned layout) so legacy behavior — one executable per
    shape bucket — is preserved exactly.  Use ``repro.api`` directly for
    the declarative surface (per-query backends, deadlines, the
    imbalance-keyed auto rule).
    """

    def __init__(
        self,
        *,
        mode: str = "eager",
        backend: str = "xla",
        max_batch: int = 8,
        chunk: int = 256,
        max_iters: int | None = None,
        mesh=None,
        cache_dir: str | None = None,
    ):
        self.mode = mode
        self.backend = backend
        self._session = Session(
            backend=BackendKey("fine", backend, "aligned"),
            mode=mode,
            max_batch=max_batch,
            chunk=chunk,
            max_iters=max_iters,
            mesh=mesh,
            cache_dir=cache_dir,
        )

    # The api session's state, exposed under the legacy names ---------- #
    @property
    def session(self) -> Session:
        """The underlying :class:`repro.api.Session`."""
        return self._session

    @property
    def cache(self):
        return self._session.cache

    @property
    def batcher(self):
        return self._session.queue

    @property
    def chunk(self) -> int:
        return self._session.chunk

    @property
    def mesh(self):
        return self._session.mesh

    @property
    def requests_served(self) -> int:
        return self._session.requests_served

    @property
    def batches_run(self) -> int:
        return self._session.batches_run

    @property
    def device_dispatches(self) -> int:
        return self._session.device_dispatches

    @property
    def device_time_s(self) -> float:
        return self._session.device_time_s

    # ------------------------------------------------------------------ #
    # Submission
    # ------------------------------------------------------------------ #
    def submit(self, g: CSRGraph, workload: str = "ktruss", *, k: int = 3) -> TrussFuture:
        if workload not in ("ktruss", "kmax", "decompose"):
            raise ValueError(f"unknown workload {workload!r}")
        return self._session.submit(TrussQuery(graph=g, workload=workload, k=int(k)))

    def submit_ktruss(self, g: CSRGraph, k: int) -> TrussFuture:
        return self.submit(g, "ktruss", k=k)

    def submit_kmax(self, g: CSRGraph, k_start: int = 3) -> TrussFuture:
        return self.submit(g, "kmax", k=k_start)

    def submit_decompose(self, g: CSRGraph, k_start: int = 3) -> TrussFuture:
        return self.submit(g, "decompose", k=k_start)

    def submit_stream(
        self,
        g: CSRGraph,
        *,
        frontier: np.ndarray,
        frozen_truss: np.ndarray,
    ) -> TrussFuture:
        """Submit a frontier-bounded re-peel (the streaming update kernel).

        Adapter for :meth:`repro.api.TrussQuery.stream_update` — see there
        for semantics.  The future resolves to the member's full (nnz,)
        trussness: frontier lanes re-peeled, frozen lanes passed through.
        """
        return self._session.submit(
            TrussQuery.stream_update(
                g,
                frontier=np.asarray(frontier, bool),
                frozen_truss=np.asarray(frozen_truss, np.int32),
            )
        )

    def open_stream(self, g: CSRGraph, trussness: np.ndarray | None = None):
        """Open a :class:`repro.stream.StreamingTrussSession` on this service."""
        return self._session.open_stream(g, trussness=trussness)

    # ------------------------------------------------------------------ #
    # Batch execution
    # ------------------------------------------------------------------ #
    def poll(self) -> int:
        """Run at most one micro-batch; returns how many requests resolved."""
        return self._session.poll()

    def flush(self) -> int:
        """Drain the queue; returns how many requests resolved."""
        return self._session.flush()

    def resolve(self, request) -> None:
        """Run batches from ``request``'s group until it resolves (legacy
        spelling of ``future.result()`` — which is the API to use)."""
        fut = self._session._futures.get(request.id)
        if fut is not None:
            fut.result()

    # ------------------------------------------------------------------ #
    # Observability
    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return self._session.stats()
