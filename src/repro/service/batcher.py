"""Request queue + micro-batcher: pack same-bucket requests into one dispatch.

Requests accumulate in an arrival-ordered queue; a batch is formed by
taking the oldest pending request's shape bucket and draining up to
``max_batch`` same-bucket requests (FIFO within the bucket, so no request
starves behind an endless stream of other buckets).  The batch is then
packed block-diagonally (``repro.graphs.pack``) so one device dispatch
serves all members.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Optional

import numpy as np

from ..graphs.csr import CSRGraph
from ..graphs.pack import PackedProblem, pack_problems
from .cache import Bucket

__all__ = ["Request", "RequestStats", "MicroBatcher"]

_ids = itertools.count()


@dataclasses.dataclass
class RequestStats:
    """Per-request observability (exposed on the future)."""

    queue_time_s: float = 0.0  # submit -> batch formation
    pack_time_s: float = 0.0  # host-side block-diagonal packing (shared)
    device_time_s: float = 0.0  # the batch's single peel dispatch (shared)
    compile_hit: bool = False  # did the batch reuse a cached executable
    bucket: Optional[Bucket] = None
    batch_size: int = 0  # real members in the packed batch
    rounds: int = 0  # fixed-point levels THIS member peeled
    iterations: int = 0  # prune iterations while THIS member was live


@dataclasses.dataclass
class Request:
    graph: CSRGraph
    workload: str  # "ktruss" | "kmax" | "decompose" | "stream"
    k: int  # target k (ktruss) or starting k (kmax/decompose/stream)
    bucket: Bucket
    # Streaming re-peel members only (workload == "stream"): which of the
    # member's real edges are free to peel (the affected frontier) and the
    # known trussness the complement is frozen at.  None on ordinary
    # requests — the member starts fully alive, nothing frozen.
    alive0: Optional["np.ndarray"] = None  # (nnz,) bool
    frozen_truss: Optional["np.ndarray"] = None  # (nnz,) int32
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    stats: RequestStats = dataclasses.field(default_factory=RequestStats)


class MicroBatcher:
    """Arrival-ordered queue with same-bucket batch formation."""

    def __init__(self, *, max_batch: int = 8, chunk: int = 256):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self.chunk = int(chunk)
        self._pending: deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._pending)

    def enqueue(self, req: Request) -> None:
        self._pending.append(req)

    def next_batch(self, bucket: Bucket | None = None) -> list[Request]:
        """Drain up to ``max_batch`` requests sharing one bucket.

        With no argument the oldest pending request's bucket is taken
        (FIFO, so no bucket starves); passing ``bucket`` forms a batch for
        that bucket only, leaving every other bucket queued — the targeted
        path behind ``TrussFuture.result()``.
        """
        if not self._pending:
            return []
        if bucket is None:
            bucket = self._pending[0].bucket
        batch: list[Request] = []
        keep: deque[Request] = deque()
        while self._pending:
            req = self._pending.popleft()
            if req.bucket == bucket and len(batch) < self.max_batch:
                batch.append(req)
            else:
                keep.append(req)
        self._pending = keep
        now = time.perf_counter()
        for req in batch:
            req.stats.queue_time_s = now - req.submitted_at
            req.stats.bucket = bucket
            req.stats.batch_size = len(batch)
        return batch

    def pack(self, batch: list[Request]) -> PackedProblem:
        """Slot-aligned block-diagonal pack, always padded to ``max_batch``
        slots so the packed shapes — and hence the compiled executable — do
        not depend on how full the batch is.  The aligned layout keeps each
        member's edge lanes inside its own slot block, which is what lets
        the executor shard whole slots across a mesh."""
        t0 = time.perf_counter()
        bucket = batch[0].bucket
        packed = pack_problems(
            [r.graph for r in batch],
            slot_n=bucket.n_pad,
            slot_nnz=bucket.nnz_pad,
            slots=self.max_batch,
            chunk=self.chunk,
            layout="aligned",
        )
        dt = time.perf_counter() - t0
        for req in batch:
            req.stats.pack_time_s = dt
        return packed
