"""Deprecation shim: the queue + micro-batcher moved to ``repro.api``.

``Request`` is now :class:`repro.api.QueryState` (a submitted
``TrussQuery`` with its planner assignment — note the constructor takes
``query=``, not the old ``graph``/``workload`` fields, though the old
read accessors ``.graph``/``.workload``/``.k`` still work),
``MicroBatcher`` keeps its old keyword surface below, and the
block-diagonal packing itself lives in :class:`repro.api.Planner`.
Importable for one release; new code should use ``repro.api``.
Importing this module raises a :class:`DeprecationWarning`.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.service.batcher is deprecated; import from repro.api instead "
    "(QueryState/RequestStats/QueryQueue in repro.api.planner/session)",
    DeprecationWarning,
    stacklevel=2,
)

from ..api.cache import Bucket  # noqa: E402
from ..api.planner import QueryState as Request  # noqa: E402, F401 — re-export
from ..api.planner import RequestStats  # noqa: E402, F401 — re-export
from ..api.session import QueryQueue  # noqa: E402

__all__ = ["Request", "RequestStats", "MicroBatcher"]


class MicroBatcher(QueryQueue):
    """Old-surface adapter over :class:`repro.api.QueryQueue`.

    Accepts the legacy ``chunk=`` constructor knob (now a planner
    concern, ignored here) and the legacy ``next_batch(bucket=...)``
    spelling — a bare :class:`Bucket` selects the oldest pending query in
    that bucket and batches its full ``(bucket, backend)`` group.
    """

    def __init__(self, *, max_batch: int = 8, chunk: int | None = None):
        del chunk  # folded into repro.api.Planner
        super().__init__(max_batch=max_batch)

    def next_batch(self, bucket=None, group=None):
        if group is None and bucket is not None:
            if isinstance(bucket, Bucket):
                st = next((s for s in self._pending if s.bucket == bucket), None)
                if st is None:
                    return []
                group = st.group
            else:  # already a (bucket, backend) group
                group = bucket
        return super().next_batch(group)
