"""Batched multi-graph K-truss serving subsystem.

Layers (bottom-up):

* :mod:`repro.exec` — the device-resident peel every workload lowers onto.
* :mod:`.cache`   — shape-bucket canonicalization + compile cache (one
                    peel executor per ``(bucket, slots, layout)`` key).
* :mod:`.batcher` — request queue + same-bucket micro-batcher over the
                    slot-aligned block-diagonal packing in
                    :mod:`repro.graphs.pack`.
* :mod:`.service` — ``TrussService``: submit/poll futures, per-request
                    stats, ``ktruss(k)`` / ``kmax()`` / ``decompose()``
                    workloads in one dispatch per batch; ``mesh=`` shards
                    packed slots across devices.
"""

from .batcher import MicroBatcher, Request, RequestStats
from .cache import (
    Bucket,
    CompileCache,
    bucket_for,
    build_peel,
    enable_persistent_cache,
)
from .service import TrussFuture, TrussService

__all__ = [
    "MicroBatcher",
    "Request",
    "RequestStats",
    "Bucket",
    "CompileCache",
    "bucket_for",
    "build_peel",
    "enable_persistent_cache",
    "TrussFuture",
    "TrussService",
]
