"""Legacy serving subsystem — adapters over :mod:`repro.api`.

.. deprecated::
    ``repro.api`` is the one front door now: declare work as
    :class:`repro.api.TrussQuery` values and run them through
    ``repro.api.solve()`` or a :class:`repro.api.Session`.  This package
    keeps the previous surface importable for one release:

    * :class:`TrussService` — thin adapter over ``repro.api.Session``
      (pinned to one registry backend, exactly the old behavior);
    * ``TrussFuture`` — re-export of :class:`repro.api.TrussFuture`;
    * ``Bucket`` / ``bucket_for`` / ``CompileCache`` /
      ``enable_persistent_cache`` / ``build_peel`` — re-exports of
      :mod:`repro.api.cache`;
    * ``Request`` / ``RequestStats`` / ``MicroBatcher`` — re-exports of
      the api queue types.
"""

from .batcher import MicroBatcher, Request, RequestStats
from .cache import (
    Bucket,
    CompileCache,
    bucket_for,
    build_peel,
    enable_persistent_cache,
)
from .service import TrussFuture, TrussService

__all__ = [
    "MicroBatcher",
    "Request",
    "RequestStats",
    "Bucket",
    "CompileCache",
    "bucket_for",
    "build_peel",
    "enable_persistent_cache",
    "TrussFuture",
    "TrussService",
]
