"""Legacy serving subsystem — adapters over :mod:`repro.api`.

.. deprecated::
    ``repro.api`` is the one front door now: declare work as
    :class:`repro.api.TrussQuery` values and run them through
    ``repro.api.solve()`` or a :class:`repro.api.Session`.  This package
    keeps the previous surface importable for one release:

    * :class:`TrussService` — thin adapter over ``repro.api.Session``
      (pinned to one registry backend, exactly the old behavior);
    * ``TrussFuture`` — re-export of :class:`repro.api.TrussFuture`.

    The cache and batcher spellings (``Bucket``, ``bucket_for``,
    ``CompileCache``, ``build_peel``, ``enable_persistent_cache``,
    ``Request``, ``RequestStats``, ``MicroBatcher``) still resolve but
    are no longer part of the documented surface; importing the
    ``repro.service.cache`` / ``repro.service.batcher`` shims raises a
    :class:`DeprecationWarning`.  Import from :mod:`repro.api` instead.
"""

# Cache names resolve straight from repro.api so the common legacy
# imports (``from repro.service import bucket_for``) stay warning-free.
from ..api.cache import (  # noqa: F401 — legacy re-exports
    Bucket,
    CompileCache,
    bucket_for,
    build_peel,
    enable_persistent_cache,
)
from .service import TrussFuture, TrussService

__all__ = [
    "TrussFuture",
    "TrussService",
]

_BATCHER_NAMES = ("MicroBatcher", "Request", "RequestStats")


def __getattr__(name: str):
    # Batcher names import lazily through the deprecated shim so merely
    # importing ``repro.service`` doesn't warn, but touching them does.
    if name in _BATCHER_NAMES:
        from . import batcher

        return getattr(batcher, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
