"""Batched multi-graph K-truss serving subsystem.

Layers (bottom-up):

* :mod:`.cache`   — shape-bucket canonicalization + compile cache (one
                    XLA/Pallas executable per power-of-two bucket).
* :mod:`.batcher` — request queue + same-bucket micro-batcher over the
                    block-diagonal packing in :mod:`repro.graphs.pack`.
* :mod:`.service` — ``TrussService``: submit/poll futures, per-request
                    stats, ``ktruss(k)`` / ``kmax()`` / ``decompose()``
                    workloads.
"""

from .batcher import MicroBatcher, Request, RequestStats
from .cache import Bucket, CompileCache, bucket_for, build_fixed_point
from .service import TrussFuture, TrussService

__all__ = [
    "MicroBatcher",
    "Request",
    "RequestStats",
    "Bucket",
    "CompileCache",
    "bucket_for",
    "build_fixed_point",
    "TrussFuture",
    "TrussService",
]
