"""Legacy serving subsystem — adapters over :mod:`repro.api`.

.. deprecated::
    ``repro.api`` is the one front door now: declare work as
    :class:`repro.api.TrussQuery` values and run them through
    ``repro.api.solve()`` or a :class:`repro.api.Session`.  This package
    keeps the previous surface importable for one release:

    * :class:`TrussService` — thin adapter over ``repro.api.Session``
      (pinned to one registry backend, exactly the old behavior);
    * ``TrussFuture`` — re-export of :class:`repro.api.TrussFuture`.

    The cache spellings (``Bucket``, ``bucket_for``, ``CompileCache``,
    ``build_peel``, ``enable_persistent_cache``) still resolve but are
    no longer part of the documented surface.  The deprecated
    ``repro.service.cache`` / ``repro.service.batcher`` shim modules
    (DeprecationWarning since PR 5) are gone — import from
    :mod:`repro.api` instead (``MicroBatcher``'s role is
    ``repro.api.QueryQueue``; ``Request``/``RequestStats`` are
    ``repro.api.QueryState``/``RequestStats``).
"""

# Cache names resolve straight from repro.api so the common legacy
# imports (``from repro.service import bucket_for``) stay warning-free.
from ..api.cache import (  # noqa: F401 — legacy re-exports
    Bucket,
    CompileCache,
    bucket_for,
    build_peel,
    enable_persistent_cache,
)
from .service import TrussFuture, TrussService

__all__ = [
    "TrussFuture",
    "TrussService",
]
