"""Execution layer: the device-resident peel behind every multi-level workload.

``KTrussEngine`` (single graph) and ``TrussService`` (packed batches) both
lower their ``kmax``/``decompose``/``ktruss`` workloads onto one
:class:`PeelExecutor` — a single compiled ``lax.while_loop`` that peels
all truss levels on device and reads back one final state.
"""

from .peel import PeelExecutor, PeelState, build_peel, make_problem_support

__all__ = ["PeelExecutor", "PeelState", "build_peel", "make_problem_support"]
