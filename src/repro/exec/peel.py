"""Device-resident level peeling: the whole truss decomposition in one dispatch.

``KTrussEngine`` and ``TrussService`` used to peel truss levels from the
host: one compiled fixed point per level, a ``np.asarray(alive)`` readback
and threshold re-upload between levels, and two copies of the peel logic
(engine loop, service loop).  PKT frames decomposition as a *single*
peeling computation; this module is that framing on device.

:func:`build_peel` compiles one ``lax.while_loop`` whose body runs a
support computation, prunes against each packed slot's current threshold,
and — for every slot whose alive mask just reached a fixed point — records
the surviving edges' trussness at ``cur_k``, bumps the slot's kmax/level
counters, and advances its threshold to ``cur_k + 1`` (or retires the slot
when its level emptied, or immediately for single-level ``ktruss(k)``
members).  The loop exits only when every slot is done, so a batched
``decompose`` costs **one** dispatch instead of one per level per round.

Slots are the block-diagonal members of ``repro.graphs.pack``; because the
packing is a disjoint union, each slot's fixed point is independent and a
per-slot convergence test (``segment_sum`` of changed lanes) is exact.

:class:`PeelExecutor` wraps the compiled peel with optional mesh placement
(slot blocks sharded across devices — see ``repro.distributed.ktruss``)
and a dispatch counter that tests use to assert the one-dispatch contract.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.eager_coarse import support_coarse_eager
from ..core.eager_fine import FineProblem, support_fine_eager, support_fine_owner
from ..errors import DeviceError
from ..obs import current_registry, current_tracer

__all__ = [
    "PeelState",
    "make_problem_support",
    "init_peel_state",
    "build_peel",
    "build_fused_peel",
    "PeelExecutor",
]


class PeelState(NamedTuple):
    """Carry/result of the on-device peel.

    Per-edge arrays span the packed problem's ``nnz_pad`` lanes; per-slot
    arrays have one entry per packed slot.
    """

    alive: jax.Array  # (nnzp,) bool — final alive mask (fixed point of cur_k)
    support: jax.Array  # (nnzp,) int32 — post-prune supports of that mask
    trussness: jax.Array  # (nnzp,) int32 — last k whose truss held the edge
    cur_k: jax.Array  # (S,) int32 — threshold each slot ended on
    kmax: jax.Array  # (S,) int32 — largest k with non-empty truss (0 if none)
    levels: jax.Array  # (S,) int32 — fixed-point levels peeled
    iters: jax.Array  # (S,) int32 — prune iterations while the slot was live
    done: jax.Array  # (S,) bool
    total_iters: jax.Array  # () int32 — while-loop trips (the cap's subject)
    edges_alive: jax.Array  # (S,) int32 — alive edges at the last converged level


def make_problem_support(
    *,
    granularity: str = "fine",
    mode: str = "eager",
    backend: str = "xla",
    window: int,
    chunk: int = 256,
    row_chunk: int = 32,
) -> Callable[[FineProblem, jax.Array], jax.Array]:
    """Problem-polymorphic ``(problem, alive) -> support`` for one config.

    Unlike ``repro.core.truss.make_support_fn`` this does not close over a
    graph, so one compiled peel serves every same-bucket problem —
    including block-diagonal batches of them.
    """
    if backend == "fused":
        raise ValueError(
            "the fused backend is not a support fn; it is built whole via "
            "build_fused_peel (one megakernel launch per level)"
        )
    if backend == "pallas":
        from ..kernels import ops as kernel_ops  # lazy: keeps exec dep-light

        if granularity != "fine":
            raise ValueError("pallas backend implements the fine granularity")
        return functools.partial(
            kernel_ops.support_fine,
            window=window,
            chunk=chunk,
            tile=min(256, chunk),
        )
    if backend != "xla":
        raise ValueError(f"unknown backend {backend!r}")
    if granularity == "coarse":
        if mode != "eager":
            raise ValueError("coarse granularity implements the eager mode")
        return functools.partial(
            support_coarse_eager, window=window, row_chunk=row_chunk
        )
    if granularity != "fine":
        raise ValueError(f"unknown granularity {granularity!r}")
    if mode == "eager":
        return functools.partial(support_fine_eager, window=window, chunk=chunk)
    if mode == "owner":
        return functools.partial(support_fine_owner, window=window, chunk=chunk)
    raise ValueError(f"unknown mode {mode!r}")


def init_peel_state(
    p: FineProblem,
    slot_ids: jax.Array,
    k0: jax.Array,
    single_level: jax.Array,
    alive0: jax.Array,
    frozen: jax.Array,
    frozen_truss: jax.Array,
) -> PeelState:
    """The peel's starting carry — shared by the unfused while-loop peel
    (traced inside its jit) and the fused per-level path (built eagerly on
    the host side of its level loop).  Frozen lanes carry their known
    trussness straight through to the output; free lanes start at the
    vacuous floor."""
    num_slots = int(k0.shape[0])
    seg = functools.partial(jax.ops.segment_sum, num_segments=num_slots)
    edges0 = seg(alive0.astype(jnp.int32), slot_ids)
    return PeelState(
        alive=alive0,
        support=jnp.zeros_like(alive0, jnp.int32),
        trussness=jnp.where(
            frozen,
            frozen_truss,
            jnp.maximum(jnp.int32(2), k0 - 1)[slot_ids]
            * alive0.astype(jnp.int32),
        ),
        cur_k=k0,
        kmax=jnp.zeros(num_slots, jnp.int32),
        levels=jnp.zeros(num_slots, jnp.int32),
        iters=jnp.zeros(num_slots, jnp.int32),
        done=edges0 == 0,
        total_iters=jnp.int32(0),
        edges_alive=edges0,
    )


def build_peel(
    support: Callable[[FineProblem, jax.Array], jax.Array],
    *,
    max_iters: int | None = None,
) -> Callable:
    """Compile the full level peel into one jitted callable.

    The returned function has signature

        peel(p, slot_ids, k0, single_level, alive0, frozen, frozen_truss) -> PeelState

    where ``slot_ids`` maps every edge lane to its packed slot, ``k0`` is
    each slot's starting k, and ``single_level`` marks slots that stop at
    their first fixed point (the ``ktruss(k)`` workload) instead of peeling
    on.  ``frozen`` marks lanes whose trussness is already known
    (``frozen_truss``): they are never pruned or re-ranked, but count as
    alive for support exactly while the slot's threshold is within their
    truss (``frozen_truss >= cur_k``) — the masked sub-problem form the
    streaming layer (``repro.stream``) peels, where only a frontier of
    affected edges is free and the rest of the graph is frozen at its
    maintained trussness.  ``alive0`` and ``frozen`` must be disjoint.
    ``max_iters`` caps total loop trips across all levels; ``None``
    (the default) uses ``nnz_pad + n + 4``, a provable upper bound (every
    trip each active slot either prunes ≥ 1 edge — at most nnz per slot —
    or converges a level — at most kmax + 2 ≤ n + 3 per slot), so an
    uncapped peel can never be truncated.  An explicit cap that fires
    raises in :meth:`PeelExecutor.peel` rather than returning a truncated
    state as final.

    Semantics per while-loop trip: compute supports, prune each lane
    against its slot's ``cur_k - 2``, and per-slot test convergence (no
    lane of the slot changed).  A converged slot's surviving edges get
    ``trussness = cur_k``; if edges survive the slot advances to
    ``cur_k + 1`` (warm-started from the current mask), otherwise — or when
    ``single_level`` — it retires.  Retired slots keep their threshold, so
    re-running them is idempotent and their alive/support lanes stay
    frozen at the converged values.
    """

    def peel(
        p: FineProblem,
        slot_ids: jax.Array,
        k0: jax.Array,
        single_level: jax.Array,
        alive0: jax.Array,
        frozen: jax.Array,
        frozen_truss: jax.Array,
    ) -> PeelState:
        num_slots = int(k0.shape[0])
        limit = (
            int(alive0.shape[0]) + p.n + 4 if max_iters is None else int(max_iters)
        )
        seg = functools.partial(jax.ops.segment_sum, num_segments=num_slots)
        state = init_peel_state(
            p, slot_ids, k0, single_level, alive0, frozen, frozen_truss
        )

        def cond(st: PeelState):
            return jnp.any(~st.done) & (st.total_iters < limit)

        def body(st: PeelState) -> PeelState:
            # Frozen lanes participate in supports exactly while the slot's
            # threshold is inside their truss: at level k the from-scratch
            # k-truss contains a frozen edge iff its trussness >= k, so the
            # restricted peel over the free lanes sees the same subgraph.
            eff_alive = st.alive | (frozen & (frozen_truss >= st.cur_k[slot_ids]))
            s = support(p, eff_alive)
            thresh = (st.cur_k - 2)[slot_ids]
            new_alive = st.alive & (s >= thresh)
            changed = seg((new_alive ^ st.alive).astype(jnp.int32), slot_ids)
            converged = (changed == 0) & ~st.done
            conv_lane = converged[slot_ids]
            trussness = jnp.where(
                conv_lane & new_alive, st.cur_k[slot_ids], st.trussness
            )
            left = seg(new_alive.astype(jnp.int32), slot_ids)
            nonempty = left > 0
            retired = converged & (~nonempty | single_level)
            cur_k = jnp.where(converged & ~retired, st.cur_k + 1, st.cur_k)
            # Prune-ahead: slots that just advanced re-prune against their
            # new threshold using the support already in hand (the free mask
            # is unchanged, so s IS the next level's first support; with
            # frozen lanes s only over-counts — support is monotone in the
            # alive set — so every ahead-pruned edge would be pruned by the
            # next level's first true support anyway) — saving one full
            # support evaluation per level, the peel's dominant cost.
            # Retired/done slots see their old threshold: idempotent.
            new_alive = new_alive & (s >= (cur_k - 2)[slot_ids])
            return PeelState(
                alive=new_alive,
                support=s * new_alive.astype(s.dtype),
                trussness=trussness,
                cur_k=cur_k,
                kmax=jnp.where(converged & nonempty, st.cur_k, st.kmax),
                levels=st.levels + converged.astype(jnp.int32),
                iters=st.iters + (~st.done).astype(jnp.int32),
                done=st.done | retired,
                total_iters=st.total_iters + 1,
                # Live slots track their current level's alive-edge count;
                # a retired slot freezes at its final level — free per-slot
                # telemetry for the runtime imbalance histograms
                # (repro.obs.peel_stats).
                edges_alive=jnp.where(st.done, st.edges_alive, left),
            )

        return jax.lax.while_loop(cond, body, state)

    return jax.jit(peel)


def build_fused_peel(
    *,
    window: int,
    block: int = 128,
    schedule: str = "compare",
    max_iters: int | None = None,
) -> Callable:
    """Host-driven fused peel: one Pallas megakernel launch per level.

    Same signature and bit-identical results as :func:`build_peel`'s
    callable, but the support→prune fixed point of each level runs
    entirely inside one persistent kernel
    (``repro.kernels.peel_fused.make_fused_level``), and the host loop
    steps levels — emitting one ``"peel-level"`` span and one
    ``peel_fused_levels`` counter tick per launch so traces show one
    kernel per level.  A fired iteration cap returns the un-done state;
    :meth:`PeelExecutor.peel`'s all-done belt raises the typed
    ``DeviceError`` exactly as on the unfused path.
    """
    from ..kernels.peel_fused import make_fused_level  # lazy: dep-light

    level_step = make_fused_level(window=window, block=block, schedule=schedule)

    def peel(
        p: FineProblem,
        slot_ids: jax.Array,
        k0: jax.Array,
        single_level: jax.Array,
        alive0: jax.Array,
        frozen: jax.Array,
        frozen_truss: jax.Array,
    ) -> PeelState:
        num_slots = int(k0.shape[0])
        limit = (
            int(alive0.shape[0]) + p.n + 4 if max_iters is None else int(max_iters)
        )
        state = init_peel_state(
            p, slot_ids, k0, single_level, alive0, frozen, frozen_truss
        )
        tracer = current_tracer()
        registry = current_registry()
        level = 0
        while not bool(np.asarray(state.done).all()):
            if int(state.total_iters) >= limit:
                break  # the executor's all-done belt raises DeviceError
            with tracer.span("peel-level", level=level, slots=num_slots):
                state = level_step(p, state, frozen, frozen_truss, single_level)
                jax.block_until_ready(state.done)
            registry.inc("peel_fused_levels")
            level += 1
        return state

    return peel


class PeelExecutor:
    """Unified executor for every multi-level K-truss workload.

    One instance owns one compiled peel (one support configuration) and
    serves ``ktruss`` / ``kmax`` / ``decompose`` for any problem matching
    its shapes — a single graph (one slot) or a packed batch.  With
    ``mesh=`` the packed slot blocks are sharded across devices before
    dispatch (slot boundaries are natural shard boundaries because the
    block-diagonal packing makes slots independent).

    ``dispatches`` counts calls into the compiled peel; the serving layer
    and tests use it to assert the one-dispatch-per-batch contract.
    """

    def __init__(
        self,
        *,
        granularity: str = "fine",
        mode: str = "eager",
        backend: str = "xla",
        window: int | None = None,
        chunk: int = 256,
        row_chunk: int = 32,
        max_iters: int | None = None,
        mesh=None,
        support: Callable[[FineProblem, jax.Array], jax.Array] | None = None,
        fused_config=None,
    ):
        self.backend = backend
        self.fused_config = None
        if backend == "fused":
            if mesh is not None:
                raise ValueError(
                    "the fused backend keeps peel state kernel-resident and "
                    "does not shard; use fine/pallas/aligned under a mesh"
                )
            if granularity != "fine":
                raise ValueError("fused backend implements the fine granularity")
            if window is None:
                raise ValueError("window is required for the fused backend")
            from ..kernels.autotune import FusedConfig  # lazy: dep-light

            cfg = fused_config if fused_config is not None else FusedConfig()
            self.fused_config = cfg
            self.support = None
            self.mesh = None
            self._peel = build_fused_peel(
                window=window,
                block=cfg.block,
                schedule=cfg.schedule,
                max_iters=max_iters,
            )
            self.dispatches = 0
            return
        if support is None:
            if window is None:
                raise ValueError("window is required unless support= is given")
            support = make_problem_support(
                granularity=granularity,
                mode=mode,
                backend=backend,
                window=window,
                chunk=chunk,
                row_chunk=row_chunk,
            )
        self.support = support
        self.mesh = mesh
        self._peel = build_peel(support, max_iters=max_iters)
        self.dispatches = 0

    def peel(
        self,
        p: FineProblem,
        *,
        slot_ids,
        k0: Sequence[int] | np.ndarray,
        single_level: Sequence[bool] | np.ndarray | None = None,
        alive0: jax.Array | None = None,
        frozen: jax.Array | None = None,
        frozen_truss: jax.Array | None = None,
    ) -> PeelState:
        """Run the whole peel for one packed problem in one dispatch.

        ``frozen``/``frozen_truss`` mark lanes whose trussness is already
        known (see :func:`build_peel`); callers must keep ``alive0`` and
        ``frozen`` disjoint.  Defaults (all-free) reproduce the plain
        from-scratch peel bit-for-bit.
        """
        k0 = jnp.asarray(np.asarray(k0, dtype=np.int32))
        num_slots = int(k0.shape[0])
        if single_level is None:
            single_level = np.zeros(num_slots, dtype=bool)
        single_level = jnp.asarray(np.asarray(single_level, dtype=bool))
        slot_ids = jnp.asarray(np.asarray(slot_ids, dtype=np.int32))
        if alive0 is None:
            alive0 = p.colidx != 0
        if frozen is None:
            frozen = jnp.zeros(alive0.shape, bool)
        if frozen_truss is None:
            frozen_truss = jnp.zeros(alive0.shape, jnp.int32)
        if self.backend == "fused":
            # The megakernel tiles lanes by `block` and reduces per-slot
            # by reshaping to (slots, slot_nnz): refuse mis-tiled packs
            # loudly (typed, slot-attributed) instead of mixing members.
            from ..graphs.pack import validate_fused_tiling

            validate_fused_tiling(
                p, slots=num_slots, block=self.fused_config.block
            )
        if self.mesh is not None:
            from ..distributed.ktruss import shard_peel_args

            (p, slot_ids, k0, single_level, alive0, frozen, frozen_truss) = (
                shard_peel_args(
                    self.mesh, p, slot_ids, k0, single_level, alive0,
                    frozen, frozen_truss,
                )
            )
        self.dispatches += 1
        current_registry().inc("peel_dispatches")
        tracer = current_tracer()
        # "dispatch" is the (async) launch of the compiled peel — on a
        # first call per executor it includes the XLA compile; the
        # blocking readback below is the true device wait.
        with tracer.span("dispatch", slots=num_slots):
            st = self._peel(
                p, slot_ids, k0, single_level, alive0, frozen, frozen_truss
            )
        # Belt: the iteration cap is provably unreachable (see build_peel),
        # so an un-done slot means a peel bug — fail loudly rather than
        # letting callers read back a truncated state as final.
        with tracer.span("device-wait"):
            all_done = bool(np.asarray(st.done).all())
        if not all_done:
            # Typed (DeviceError is still a RuntimeError) so the
            # resilience layer treats a capped peel like any other
            # device-side dispatch failure: retry, then fall back.
            raise DeviceError(
                f"peel hit the iteration cap after {int(st.total_iters)} "
                f"trips with slots unfinished: done={np.asarray(st.done)}",
                site="peel",
            )
        return st
