"""repro.resilience — fault domains for the batched peel path.

The serving stack packs many users' graphs block-diagonally into ONE
compiled dispatch, so without isolation a single malformed CSR, failed
compile, or device fault fails every batch-mate.  This package is the
policy layer that keeps fault domains per-query:

* :mod:`.faults`     — deterministic fault-injection harness: a
  :class:`FaultPlan` (``Session(faults=...)`` or the ``REPRO_FAULTS``
  env var) fires typed failures by site + seed — compile error, device
  OOM, dispatch exception, poisoned batch member, clock skew — so the
  chaos suite drives every failure path on demand;
* :mod:`.retry`      — :class:`RetryPolicy`: bounded attempts with
  exponential backoff (on the fake-able obs clock) and the registry
  fallback switch;
* :mod:`.runner`     — :class:`ResilientRunner`: quarantines
  member-attributed failures, retries transient device faults, falls
  down the backend registry (pallas→xla, fine→coarse — bit-identical by
  the parity contract) on compile/kernel faults, and bisects batches to
  isolate unattributed poison members.  One poison query yields one
  typed per-query error; every batch-mate still resolves bit-identically;
* :mod:`.checkpoint` — streaming checkpoint/restore: a
  ``StreamingTrussSession``'s CSR + trussness + TriangleCache serialized
  at update boundaries and restored after a crash, equal to an
  uninterrupted session.

Every retry, fallback, quarantine, bisect, and shed is counted in the
session's :mod:`repro.obs` metrics registry, so tests assert on
observable behavior, not logs.
"""

from .checkpoint import (
    CHECKPOINT_VERSION,
    Checkpoint,
    latest_checkpoint,
    load_checkpoint,
    restore_session,
    save_checkpoint,
)
from .faults import (
    FAULT_SITES,
    FAULTS_ENV_VAR,
    FaultPlan,
    FaultSpec,
    current_plan,
    inject,
    parse_faults,
    poison_csr_arrays,
    use_plan,
)
from .retry import RetryPolicy
from .runner import ResilientRunner

__all__ = [
    # fault injection
    "FAULT_SITES",
    "FAULTS_ENV_VAR",
    "FaultSpec",
    "FaultPlan",
    "parse_faults",
    "current_plan",
    "use_plan",
    "inject",
    "poison_csr_arrays",
    # retry/fallback policy + runner
    "RetryPolicy",
    "ResilientRunner",
    # streaming checkpoint/restore
    "CHECKPOINT_VERSION",
    "Checkpoint",
    "save_checkpoint",
    "load_checkpoint",
    "restore_session",
    "latest_checkpoint",
]
