"""Deterministic fault injection for the batched peel path.

The chaos suite needs to drive every failure path — compile error,
device OOM, dispatch exception, poisoned batch member, clock skew — on
demand and *reproducibly*, so a failing chaos seed can be replayed
byte-for-byte.  This module is the harness:

* a :class:`FaultSpec` names one **site** plus firing rules (fire the
  first N times, skip the first M hits, fire with probability p under a
  seeded RNG, fire only when context fields match ``where``);
* a :class:`FaultPlan` bundles specs with a seed and is threaded through
  ``Session(faults=...)`` — or picked up process-wide from the
  ``REPRO_FAULTS`` env var (:func:`FaultPlan.from_env`);
* production code calls :func:`inject` at its fault sites with whatever
  context it has (bucket, backend, slot, query id).  With no active plan
  the call is a cheap no-op; with one, matching specs raise the mapped
  typed error (marked ``injected=True``) or perform their action
  (clock skew advances the active :class:`~repro.obs.clock.FakeClock`).

Sites and their mapped failures:

========== ==============================================================
site        effect
========== ==============================================================
compile     :class:`~repro.errors.CompileError` before the bucket's
            executable is built — exercises registry fallback.
device_oom  :class:`~repro.errors.DeviceError` with ``oom=True`` before
            dispatch — exercises retry/backoff then fallback.
dispatch    :class:`~repro.errors.DeviceError` before dispatch —
            generic kernel fault, same retry path.
poison      :class:`~repro.errors.InvalidGraphError` attributed to one
            packed member — exercises quarantine + survivor re-dispatch.
clock_skew  no exception: advances the active FakeClock by ``skew_s``
            (real clocks are left alone) — exercises deadline/timeout
            handling under time jumps.
network     :class:`~repro.errors.DeviceError` at a serving-tier RPC
            boundary (``repro.serve``) — exercises router re-route and
            replica quarantine.
replica_kill no exception: an *action* site — the fleet chaos storm
            polls it (``if inject("replica_kill", replica=...)``) and
            kills the named replica process when it fires, exercising
            warm stream handoff to a survivor.
========== ==============================================================

Every fired fault is counted in the current metrics registry as
``faults_injected{site=...}``; :func:`inject` returns the fired
:class:`FaultSpec` (``None`` when nothing fired) so action sites can
react without a raise.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import os
import threading
import zlib

import numpy as np

from ..errors import CompileError, DeviceError, InvalidGraphError
from ..obs import clock as obs_clock
from ..obs.clock import FakeClock
from ..obs.metrics import current_registry

__all__ = [
    "FAULT_SITES",
    "FAULTS_ENV_VAR",
    "FaultSpec",
    "FaultPlan",
    "parse_faults",
    "current_plan",
    "use_plan",
    "inject",
    "poison_csr_arrays",
]

FAULT_SITES = (
    "compile",
    "dispatch",
    "device_oom",
    "poison",
    "clock_skew",
    "network",
    "replica_kill",
)
FAULTS_ENV_VAR = "REPRO_FAULTS"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule: fire at ``site`` when the gates all pass.

    ``times``     — fire at most this many times (``None`` = unlimited).
    ``skip``      — let the first ``skip`` matching hits through unharmed
                    (e.g. ``skip=1`` faults the *second* dispatch only).
    ``p``         — fire probability per hit, decided by a seeded RNG so
                    the same plan replays identically.
    ``where``     — ``((key, value), ...)`` context gates; a hit only
                    counts when every key is present in the injection
                    context and matches (equality, or membership when the
                    context value is a tuple/list — e.g. ``("query", 7)``
                    matches a batch whose ``queries`` tuple contains 7).
    ``skew_s``    — clock_skew only: seconds to advance the fake clock.
    ``message``   — override the raised error's message.
    """

    site: str
    times: int | None = 1
    skip: int = 0
    p: float = 1.0
    where: tuple[tuple[str, object], ...] = ()
    skew_s: float = 0.0
    message: str | None = None

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; expected one of {FAULT_SITES}"
            )
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault probability must be in [0, 1], got {self.p}")

    def matches(self, ctx: dict) -> bool:
        for key, want in self.where:
            if key not in ctx:
                return False
            have = ctx[key]
            if isinstance(have, (tuple, list, set, frozenset)):
                if want not in have:
                    return False
            elif have != want:
                return False
        return True


class FaultPlan:
    """A seeded set of :class:`FaultSpec`\\ s with per-spec firing state.

    The plan is mutable state (hit/fire counters advance as sites are
    visited) guarded by a lock, so one plan can be shared by a session's
    worker threads.  ``reset()`` rewinds the counters for replay.
    """

    def __init__(self, specs: tuple[FaultSpec, ...] | list[FaultSpec] = (), seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._hits = [0] * len(self.specs)
        self._fired = [0] * len(self.specs)

    @classmethod
    def from_env(cls, env: str | None = None) -> "FaultPlan | None":
        """Build a plan from ``REPRO_FAULTS`` (or ``env``); None if unset."""
        text = os.environ.get(FAULTS_ENV_VAR) if env is None else env
        if not text or not text.strip():
            return None
        return parse_faults(text)

    def reset(self) -> None:
        with self._lock:
            self._hits = [0] * len(self.specs)
            self._fired = [0] * len(self.specs)

    def fired(self, site: str | None = None) -> int:
        """How many faults have fired (optionally for one site)."""
        with self._lock:
            return sum(
                f
                for s, f in zip(self.specs, self._fired)
                if site is None or s.site == site
            )

    def should_fire(self, site: str, ctx: dict) -> FaultSpec | None:
        """Advance firing state for ``site``; the spec to fire, or None."""
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.site != site or not spec.matches(ctx):
                    continue
                hit = self._hits[i]
                self._hits[i] += 1
                if hit < spec.skip:
                    continue
                if spec.times is not None and self._fired[i] >= spec.times:
                    continue
                if spec.p < 1.0:
                    # Seeded per (plan seed, spec index, hit ordinal, site):
                    # the same plan replayed fires at the same hits.
                    rng = np.random.default_rng(
                        (self.seed, i, hit, zlib.crc32(site.encode()))
                    )
                    if rng.random() >= spec.p:
                        continue
                self._fired[i] += 1
                return spec
        return None

    def __repr__(self):
        return f"FaultPlan(specs={self.specs!r}, seed={self.seed})"


_current_plan: contextvars.ContextVar[FaultPlan | None] = contextvars.ContextVar(
    "repro_fault_plan", default=None
)


def current_plan() -> FaultPlan | None:
    """The context-active fault plan (None in production)."""
    return _current_plan.get()


@contextlib.contextmanager
def use_plan(plan: FaultPlan | None):
    """Scoped plan install: ``with use_plan(plan): session work``.

    Installing ``None`` explicitly masks any outer plan, so nested
    fault-free scopes (e.g. an oracle run inside a chaos test) work.
    """
    token = _current_plan.set(plan)
    try:
        yield plan
    finally:
        _current_plan.reset(token)


def inject(site: str, **ctx) -> FaultSpec | None:
    """Fault site hook: raise/act if the active plan says so, else no-op.

    Call this from production code at each site with whatever context is
    known (``bucket=``, ``backend=``, ``slot=``, ``query=``,
    ``queries=``, ``replica=``).  Exception sites raise typed errors
    with ``injected=True``; action sites (``clock_skew`` performs the
    skew, ``replica_kill`` leaves the action to the caller) return the
    fired spec so call sites can react — ``None`` means nothing fired.
    """
    plan = _current_plan.get()
    if plan is None:
        return None
    spec = plan.should_fire(site, ctx)
    if spec is None:
        return None
    current_registry().inc("faults_injected", site=site)
    bucket = ctx.get("bucket")
    backend = ctx.get("backend")
    msg = spec.message or f"injected fault at site {site!r}"
    if site == "clock_skew":
        clk = obs_clock.get_clock()
        if isinstance(clk, FakeClock):
            clk.advance(max(0.0, float(spec.skew_s)))
        return spec
    if site == "replica_kill":
        # Pure action site: the fleet's monitor polls it and does the
        # killing itself — there is no in-process exception to raise.
        return spec
    if site == "network":
        raise DeviceError(
            msg, backend=backend, site=site, injected=True
        )
    if site == "compile":
        raise CompileError(
            msg, bucket=bucket, backend=backend, site=site, injected=True
        )
    if site == "device_oom":
        raise DeviceError(
            msg, oom=True, bucket=bucket, backend=backend, site=site, injected=True
        )
    if site == "dispatch":
        raise DeviceError(
            msg, bucket=bucket, backend=backend, site=site, injected=True
        )
    if site == "poison":
        raise InvalidGraphError(
            msg,
            kind="poisoned",
            bucket=bucket,
            backend=backend,
            slot=ctx.get("slot"),
            query_id=ctx.get("query"),
            site=site,
            injected=True,
        )
    raise AssertionError(f"unhandled fault site {site!r}")  # pragma: no cover


# ---------------------------------------------------------------------- #
# REPRO_FAULTS parsing
# ---------------------------------------------------------------------- #
# Grammar (semicolon-separated clauses):
#   REPRO_FAULTS="dispatch:times=1;device_oom:skip=2:times=1;seed=7"
#   clause  := site (":" option)*   |   "seed=" int
#   option  := "times=" (int|"inf"|"*") | "skip=" int | "p=" float
#            | "skew=" float | "where.<key>=" value | "msg=" text
# Values for where.<key> are parsed as int when possible, else kept as
# strings (backend/bucket gates compare against str(ctx value)).


def parse_faults(text: str) -> FaultPlan:
    """Parse the ``REPRO_FAULTS`` mini-language into a :class:`FaultPlan`."""
    specs: list[FaultSpec] = []
    seed = 0
    for clause in text.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        if clause.startswith("seed="):
            seed = int(clause[len("seed="):])
            continue
        parts = clause.split(":")
        site = parts[0].strip()
        kw: dict = {"site": site}
        where: list[tuple[str, object]] = []
        for opt in parts[1:]:
            opt = opt.strip()
            if not opt:
                continue
            if "=" not in opt:
                raise ValueError(f"bad fault option {opt!r} in clause {clause!r}")
            key, val = opt.split("=", 1)
            key = key.strip()
            val = val.strip()
            if key == "times":
                kw["times"] = None if val in ("inf", "*") else int(val)
            elif key == "skip":
                kw["skip"] = int(val)
            elif key == "p":
                kw["p"] = float(val)
            elif key == "skew":
                kw["skew_s"] = float(val)
            elif key == "msg":
                kw["message"] = val
            elif key.startswith("where."):
                field = key[len("where."):]
                try:
                    parsed: object = int(val)
                except ValueError:
                    parsed = val
                where.append((field, parsed))
            else:
                raise ValueError(f"unknown fault option {key!r} in clause {clause!r}")
        kw["where"] = tuple(where)
        specs.append(FaultSpec(**kw))
    return FaultPlan(tuple(specs), seed=seed)


# ---------------------------------------------------------------------- #
# Malformed-graph corpus for validation tests
# ---------------------------------------------------------------------- #
POISON_KINDS = ("col_range", "self_loop", "duplicate", "unsorted_row", "rowptr_unsorted")


def poison_csr_arrays(
    n: int, rowptr: np.ndarray, colidx: np.ndarray, *, seed: int = 0
) -> tuple[int, np.ndarray, np.ndarray, str]:
    """Deterministically corrupt a valid CSR into ``(n, rowptr, colidx, kind)``.

    Picks one invariant violation by seed and applies it to copies of the
    inputs, returning the :class:`~repro.errors.InvalidGraphError` kind
    the validator must report.  Used by the chaos/validation tests to
    cover every branch of ``validate_csr`` from real graph shapes.
    """
    rowptr = np.array(rowptr, copy=True)
    colidx = np.array(colidx, copy=True)
    nnz = int(colidx.shape[0])
    if nnz == 0:
        raise ValueError("cannot poison an empty graph")
    rng = np.random.default_rng(seed)
    # Only pick kinds that are expressible on this shape.
    kinds = [k for k in POISON_KINDS if k != "rowptr_unsorted" or n >= 2]
    kind = kinds[int(rng.integers(len(kinds)))]
    e = int(rng.integers(nnz))
    if kind == "col_range":
        colidx[e] = n + 1 + int(rng.integers(3))
    elif kind == "self_loop":
        row = int(np.searchsorted(rowptr, e, side="right"))  # 1-based row of e
        colidx[e] = row
    elif kind == "duplicate":
        counts = np.diff(rowptr)
        wide = np.flatnonzero(counts >= 2)
        if wide.size == 0:
            colidx[e] = n + 1  # no row can hold a duplicate; degrade
            kind = "col_range"
        else:
            r = int(wide[int(rng.integers(wide.size))])
            colidx[rowptr[r] + 1] = colidx[rowptr[r]]
    elif kind == "unsorted_row":
        counts = np.diff(rowptr)
        wide = np.flatnonzero(counts >= 2)
        if wide.size == 0:
            colidx[e] = n + 1
            kind = "col_range"
        else:
            r = int(wide[int(rng.integers(wide.size))])
            a, b = int(rowptr[r]), int(rowptr[r]) + 1
            if colidx[a] == colidx[b]:
                kind = "duplicate"  # already equal: swap is a no-op
            colidx[a], colidx[b] = colidx[b], colidx[a]
    elif kind == "rowptr_unsorted":
        # Either dent rowptr[r] below its predecessor, or (when the
        # predecessor is 0) bump it past nnz so the next diff goes
        # negative — both trip the monotonicity check first.
        r = 1 + int(rng.integers(max(1, n - 1)))
        rowptr[r] = rowptr[r - 1] - 1 if rowptr[r - 1] > 0 else rowptr[r] + nnz + 1
    return n, rowptr, colidx, kind
