"""Streaming checkpoint/restore: crash-durable truss maintenance.

A :class:`~repro.stream.session.StreamingTrussSession` carries state that
is expensive to rebuild — the maintained CSR, the exact trussness, and
the :class:`~repro.stream.tricache.TriangleCache`'s triangle list (the
one full enumeration the cache ever does).  A crash between updates
loses all of it; this module makes the session durable:

* :func:`save_checkpoint` serializes ``(graph, trussness, tri_keys)`` to
  a single compressed ``.npz`` written **atomically** (tmp file +
  ``os.replace``), with a JSON meta record carrying a format version and
  a CRC over every array so torn/corrupt files are detected at load,
  not silently decoded;
* :func:`load_checkpoint` verifies version, checksum, CSR invariants
  (through ordinary :class:`~repro.graphs.csr.CSRGraph` construction)
  and trussness length, raising :class:`~repro.errors.CheckpointError`
  with the offending path on any mismatch;
* :func:`restore_session` rebuilds a ``StreamingTrussSession`` from a
  checkpoint **without re-running the initial decompose or the full
  triangle enumeration** — the restored session is property-tested
  (``tests/test_resilience.py``) to continue bit-identically to one
  that never crashed.

Sessions auto-checkpoint at update boundaries when constructed with
``checkpoint_dir=`` (every ``checkpoint_every`` commits, keeping the
last two files so a crash mid-write still leaves a good predecessor);
:func:`latest_checkpoint` finds the newest one after a crash.
"""

from __future__ import annotations

import dataclasses
import io
import json
import os
import zlib

import numpy as np

from ..errors import CheckpointError
from ..graphs.csr import CSRGraph

__all__ = [
    "CHECKPOINT_VERSION",
    "Checkpoint",
    "save_checkpoint",
    "load_checkpoint",
    "restore_session",
    "latest_checkpoint",
]

CHECKPOINT_VERSION = 1
_CKPT_PREFIX = "ckpt-"
_CKPT_SUFFIX = ".npz"


@dataclasses.dataclass(frozen=True)
class Checkpoint:
    """One verified on-disk session state (the load side's return type)."""

    graph: CSRGraph
    trussness: np.ndarray
    tri_keys: np.ndarray | None  # None = session ran cache_triangles=False
    meta: dict

    @property
    def kmax(self) -> int:
        return int(self.trussness.max(initial=0)) if self.trussness.size else 0


def _checksum(arrays: dict[str, np.ndarray]) -> int:
    """Order-stable CRC32 over every array's dtype/shape/bytes."""
    crc = 0
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        crc = zlib.crc32(f"{name}:{a.dtype.str}:{a.shape}".encode(), crc)
        crc = zlib.crc32(a.tobytes(), crc)
    return crc


def save_checkpoint(
    path: str,
    *,
    graph: CSRGraph,
    trussness: np.ndarray,
    tri_keys: np.ndarray | None = None,
    updates_applied: int = 0,
) -> str:
    """Atomically write a session checkpoint to ``path``; returns ``path``.

    The write goes to ``path + ".tmp"`` first and is renamed into place,
    so readers (and :func:`latest_checkpoint`) never observe a torn file.
    """
    trussness = np.asarray(trussness, np.int32)
    if trussness.shape[0] != graph.nnz:
        raise CheckpointError(
            f"trussness has {trussness.shape[0]} entries, graph has "
            f"{graph.nnz} — refusing to write an inconsistent checkpoint",
            path=path,
        )
    arrays = {
        "rowptr": np.asarray(graph.rowptr, np.int64),
        "colidx": np.asarray(graph.colidx, np.int32),
        "trussness": trussness,
    }
    if tri_keys is not None:
        arrays["tri_keys"] = np.asarray(tri_keys, np.int64)
    meta = {
        "version": CHECKPOINT_VERSION,
        "n": graph.n,
        "nnz": graph.nnz,
        "name": graph.name,
        "kmax": int(trussness.max(initial=0)) if trussness.size else 0,
        "cache_triangles": tri_keys is not None,
        "updates_applied": int(updates_applied),
        "checksum": _checksum(arrays),
    }
    tmp = path + ".tmp"
    try:
        buf = io.BytesIO()
        np.savez_compressed(buf, meta=np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8
        ), **arrays)
        with open(tmp, "wb") as f:
            f.write(buf.getvalue())
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except OSError as e:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise CheckpointError(f"checkpoint write failed: {e}", path=path, cause=e) from e
    return path


def load_checkpoint(path: str) -> Checkpoint:
    """Read and fully verify a checkpoint (version, CRC, CSR invariants)."""
    try:
        with np.load(path) as z:
            data = {k: z[k] for k in z.files}
    except (OSError, ValueError, zlib.error) as e:
        raise CheckpointError(f"checkpoint unreadable: {e}", path=path, cause=e) from e
    if "meta" not in data:
        raise CheckpointError("checkpoint has no meta record", path=path)
    try:
        meta = json.loads(bytes(data.pop("meta")).decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CheckpointError(f"checkpoint meta is corrupt: {e}", path=path, cause=e) from e
    version = meta.get("version")
    if version != CHECKPOINT_VERSION:
        raise CheckpointError(
            f"checkpoint version {version!r} != supported {CHECKPOINT_VERSION}",
            path=path,
        )
    for key in ("rowptr", "colidx", "trussness"):
        if key not in data:
            raise CheckpointError(f"checkpoint missing array {key!r}", path=path)
    crc = _checksum(data)
    if crc != meta.get("checksum"):
        raise CheckpointError(
            f"checkpoint checksum mismatch (stored {meta.get('checksum')}, "
            f"computed {crc}) — file is corrupt or torn",
            path=path,
        )
    try:
        # Ordinary construction re-validates every CSR invariant, so a
        # checkpoint that passes CRC but carries bad data still fails
        # loudly (typed) instead of poisoning the restored session.
        graph = CSRGraph(
            int(meta["n"]),
            data["rowptr"],
            data["colidx"],
            name=str(meta.get("name", "graph")),
        )
    except ValueError as e:
        raise CheckpointError(
            f"checkpoint graph fails CSR validation: {e}", path=path, cause=e
        ) from e
    trussness = np.asarray(data["trussness"], np.int32)
    if trussness.shape[0] != graph.nnz:
        raise CheckpointError(
            f"checkpoint trussness has {trussness.shape[0]} entries, graph "
            f"has {graph.nnz}",
            path=path,
        )
    tri_keys = data.get("tri_keys")
    if tri_keys is None and meta.get("cache_triangles"):
        raise CheckpointError(
            "checkpoint meta promises a triangle cache but tri_keys is missing",
            path=path,
        )
    return Checkpoint(graph=graph, trussness=trussness, tri_keys=tri_keys, meta=meta)


def restore_session(path: str, session=None, **session_kwargs):
    """Rebuild a :class:`~repro.stream.session.StreamingTrussSession` from
    ``path`` — no decompose dispatch, no full triangle re-enumeration.

    ``session`` is the owning :class:`repro.api.Session` (a private one
    is created from ``session_kwargs`` if omitted, matching
    ``StreamingTrussSession.for_graph``).  The restored session resumes
    auto-checkpointing if ``checkpoint_dir=`` is passed through.
    """
    from ..api.session import Session
    from ..stream.session import StreamingTrussSession
    from ..stream.tricache import TriangleCache

    ckpt = load_checkpoint(path)
    checkpoint_kwargs = {
        k: session_kwargs.pop(k)
        for k in ("checkpoint_dir", "checkpoint_every")
        if k in session_kwargs
    }
    if session is None:
        session_kwargs.setdefault("max_batch", 1)
        session = Session(**session_kwargs)
    stream = StreamingTrussSession(
        session,
        ckpt.graph,
        trussness=ckpt.trussness,
        cache_triangles=ckpt.tri_keys is not None,
        **checkpoint_kwargs,
    )
    if ckpt.tri_keys is not None:
        stream._tri_cache = TriangleCache(ckpt.graph, tri_keys=ckpt.tri_keys)
    # The checkpoint's updates_applied meta is the durable lifetime count:
    # it seeds both the auto-checkpoint filename sequence and the restored
    # session's updates_total, so a restore + re-checkpoint keeps strictly
    # increasing sequence numbers (latest_checkpoint stays a name sort)
    # and stream replay offsets survive the handoff.
    stream._ckpt_seq = int(ckpt.meta.get("updates_applied", 0))
    stream._updates_total = int(ckpt.meta.get("updates_applied", 0))
    return stream


def latest_checkpoint(directory: str) -> str | None:
    """Newest auto-checkpoint file in ``directory`` (None if there are none).

    Auto-checkpoints are named ``ckpt-<seq>.npz`` with a monotonically
    increasing sequence number, so "latest" is a filename sort, not an
    mtime race.
    """
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return None
    ckpts = sorted(
        n
        for n in names
        if n.startswith(_CKPT_PREFIX) and n.endswith(_CKPT_SUFFIX)
    )
    return os.path.join(directory, ckpts[-1]) if ckpts else None
