"""ResilientRunner: per-query fault domains around one packed dispatch.

A packed batch is ONE device call over many users' graphs, so a naive
session turns any member's fault into everyone's failure.  The runner
wraps the dispatch with the taxonomy-keyed policy:

* :class:`~repro.errors.InvalidGraphError` attributed to a member (by
  ``query_id`` or packed ``slot``) → **quarantine** that member with a
  terminal :class:`~repro.errors.QueryFailedError` and re-dispatch the
  survivors — bit-identical by construction, because packed members are
  independent blocks of a disjoint union;
* :class:`~repro.errors.DeviceError` (kernel fault / OOM) → **retry**
  with exponential backoff on the observability clock, up to
  ``policy.max_attempts`` per backend;
* :class:`~repro.errors.CompileError` (or exhausted retries) → **fall
  back** down :func:`~repro.api.registry.fallback_backends`
  (pallas→xla, fine→coarse) — safe because every registered backend is
  parity-tested bit-identical;
* unattributed fault with the whole chain exhausted → **bisect** the
  batch and recurse, so one poison member is isolated in O(log batch)
  dispatches instead of failing its batch-mates.

Every decision is counted in the session's metrics registry:
``retries``, ``backend_fallbacks{from,to}``, ``queries_quarantined``,
``batch_bisects``, ``dispatch_failures{site}``.

The runner is deliberately ignorant of queues, futures, and compile
caches — it drives a session-provided ``dispatch(PlannedBatch) ->
results`` callable and returns per-query outcomes; :class:`repro.api.
Session` resolves futures from them.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Callable

from ..errors import (
    CompileError,
    DeviceError,
    InvalidGraphError,
    QueryFailedError,
    TrussError,
)
from ..obs import clock as obs_clock
from ..obs.metrics import MetricsRegistry, current_registry
from .retry import RetryPolicy

if TYPE_CHECKING:  # pragma: no cover — avoids a cycle: planner imports faults
    from ..api.planner import PlannedBatch, QueryState
    from ..api.registry import BackendKey

__all__ = ["Outcome", "ResilientRunner"]


class Outcome:
    """One query's verdict: ``result`` on success, typed ``error`` if not."""

    __slots__ = ("state", "result", "error")

    def __init__(self, state: QueryState, result: Any = None, error=None):
        self.state = state
        self.result = result
        self.error = error

    @property
    def ok(self) -> bool:
        return self.error is None

    def __repr__(self):
        verdict = "ok" if self.ok else f"error={type(self.error).__name__}"
        return f"Outcome(query={self.state.id}, {verdict})"


class ResilientRunner:
    """Runs planned batches through ``dispatch`` under a :class:`RetryPolicy`."""

    def __init__(
        self,
        dispatch: Callable[[PlannedBatch], list[Any]],
        *,
        policy: RetryPolicy | None = None,
        metrics: MetricsRegistry | None = None,
    ):
        self.dispatch = dispatch
        self.policy = policy or RetryPolicy()
        self._metrics = metrics

    @property
    def metrics(self) -> MetricsRegistry:
        return self._metrics or current_registry()

    # ------------------------------------------------------------------ #
    def run(self, planned: PlannedBatch) -> list[Outcome]:
        """Dispatch ``planned`` with isolation; one outcome per query,
        in the batch's order.  Only :class:`TrussError` faults are
        policy-handled — anything else propagates to the caller."""
        # Lazy: the registry lives in repro.api, which imports this module
        # — a top-level import would make `import repro.resilience` depend
        # on import order (repro.serve imports resilience before api).
        from ..api.registry import fallback_backends

        chain = [planned.backend]
        if self.policy.fallback:
            chain.extend(fallback_backends(planned.backend))
        outcomes: dict[int, Outcome] = {}
        self._run(planned, list(planned.queries), chain, outcomes)
        return [outcomes[st.id] for st in planned.queries]

    # ------------------------------------------------------------------ #
    def _rebatch(
        self, template: PlannedBatch, states: list[QueryState], backend: BackendKey
    ) -> PlannedBatch:
        for st in states:
            st.stats.backend = backend  # observability: the backend that ran
        return dataclasses.replace(template, backend=backend, queries=states)

    @staticmethod
    def _attribute(states: list[QueryState], err: TrussError) -> QueryState | None:
        """The member a fault names, by query id first, packed slot second."""
        if err.query_id is not None:
            for st in states:
                if st.id == err.query_id:
                    return st
        if err.slot is not None and 0 <= err.slot < len(states):
            return states[err.slot]
        return None

    def _terminal(
        self,
        st: QueryState,
        err: TrussError,
        *,
        attempts: int,
        backends_tried: list[BackendKey],
    ) -> QueryFailedError:
        return QueryFailedError(
            f"query {st.id} ({st.query.workload}) failed after {attempts} "
            f"attempt(s) over backends "
            f"{[str(b) for b in backends_tried]}: {err}",
            bucket=st.bucket,
            backend=backends_tried[-1] if backends_tried else st.backend,
            query_id=st.id,
            attempts=attempts,
            backends_tried=tuple(backends_tried),
            cause=err,
        )

    def _run(
        self,
        template: PlannedBatch,
        states: list[QueryState],
        chain: list[BackendKey],
        outcomes: dict[int, Outcome],
    ) -> None:
        if not states:
            return
        backends_tried: list[BackendKey] = []
        attempts = 0
        last_err: TrussError | None = None
        for ci, backend in enumerate(chain):
            if backends_tried:
                self.metrics.inc(
                    "backend_fallbacks",
                    **{"from": str(backends_tried[-1]), "to": str(backend)},
                )
            backends_tried.append(backend)
            attempt = 0
            while attempt < self.policy.max_attempts:
                attempt += 1
                attempts += 1
                try:
                    results = self.dispatch(self._rebatch(template, states, backend))
                    for st, res in zip(states, results):
                        outcomes[st.id] = Outcome(st, result=res)
                    return
                except InvalidGraphError as e:
                    last_err = e
                    self.metrics.inc("dispatch_failures", site="invalid")
                    culprit = self._attribute(states, e)
                    if culprit is not None:
                        # Deterministic, member-attributed: quarantine and
                        # re-dispatch the survivors (still on this chain
                        # position — the backend itself is not at fault).
                        self.metrics.inc("queries_quarantined")
                        outcomes[culprit.id] = Outcome(
                            culprit,
                            error=self._terminal(
                                culprit,
                                e,
                                attempts=attempts,
                                backends_tried=backends_tried,
                            ),
                        )
                        survivors = [s for s in states if s is not culprit]
                        self._run(template, survivors, chain[ci:], outcomes)
                        return
                    # Unattributed bad input: no backend will fix it — skip
                    # the rest of the chain and let bisection isolate it.
                    break
                except CompileError as e:
                    last_err = e
                    self.metrics.inc("dispatch_failures", site="compile")
                    break  # deterministic per backend: next chain entry
                except DeviceError as e:
                    last_err = e
                    self.metrics.inc(
                        "dispatch_failures", site="oom" if e.oom else "device"
                    )
                    if attempt >= self.policy.max_attempts:
                        break  # retries exhausted: next chain entry
                    self.metrics.inc("retries", backend=str(backend))
                    obs_clock.sleep(self.policy.delay(attempt))
            if isinstance(last_err, InvalidGraphError):
                break  # input-determined: don't walk more backends
        # Chain exhausted.  With several members and an unattributed fault,
        # split to isolate the poison member in O(log n) dispatches.
        if len(states) > 1 and self.policy.bisect:
            self.metrics.inc("batch_bisects")
            mid = len(states) // 2
            self._run(template, states[:mid], chain, outcomes)
            self._run(template, states[mid:], chain, outcomes)
            return
        for st in states:
            outcomes[st.id] = Outcome(
                st,
                error=self._terminal(
                    st,
                    last_err,
                    attempts=attempts,
                    backends_tried=backends_tried,
                ),
            )
