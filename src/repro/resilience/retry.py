"""Retry/backoff policy for transient device faults.

One frozen dataclass holds every knob the :class:`~.runner.ResilientRunner`
consults, so a session's whole failure-handling posture is a single
``Session(retry=RetryPolicy(...))`` argument — and a test can turn the
policy into "no waiting, no fallback" in one place.

Backoff waits go through :func:`repro.obs.clock.sleep`, i.e. the active
observability clock: under a :class:`~repro.obs.clock.FakeClock` the wait
advances fake time and returns immediately, so retry tests never sleep.
"""

from __future__ import annotations

import dataclasses

__all__ = ["RetryPolicy"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How hard the runner fights before declaring a query failed.

    ``max_attempts``   — dispatch attempts per backend for transient
                         (:class:`~repro.errors.DeviceError`) faults;
                         the first try counts, so 3 = 1 try + 2 retries.
    ``backoff_base_s`` — wait before the first retry; doubles (or
                         ``backoff_mult``-s) per retry, capped at
                         ``backoff_max_s``.
    ``fallback``       — walk the registry fallback chain
                         (pallas→xla, fine→coarse) on compile faults or
                         exhausted retries.  Safe because every
                         registered backend is parity-tested
                         bit-identical.
    ``bisect``         — on an unattributed batch fault, split the batch
                         and recurse to isolate the poisoned member
                         instead of failing everyone.
    """

    max_attempts: int = 3
    backoff_base_s: float = 0.01
    backoff_mult: float = 2.0
    backoff_max_s: float = 1.0
    fallback: bool = True
    bisect: bool = True

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.backoff_base_s < 0 or self.backoff_max_s < 0:
            raise ValueError("backoff seconds must be >= 0")
        if self.backoff_mult < 1.0:
            raise ValueError(f"backoff_mult must be >= 1, got {self.backoff_mult}")

    def delay(self, attempt: int) -> float:
        """Seconds to wait before retry number ``attempt`` (1-based)."""
        if attempt < 1:
            return 0.0
        return min(
            self.backoff_max_s,
            self.backoff_base_s * self.backoff_mult ** (attempt - 1),
        )
