"""repro.obs — unified tracing, metrics, and load-imbalance telemetry.

The one instrument layer for the whole query path.  Three pieces:

* :mod:`.trace`      — thread-safe span tracer (plan / pack / compile /
                       dispatch / device-wait / unpack), ring-buffered,
                       exported as Chrome trace-event JSON
                       (``obs.export_trace(path)``);
* :mod:`.metrics`    — named counters / gauges / histograms with label
                       sets, JSON snapshot (``obs.metrics_snapshot()``)
                       and Prometheus text exposition
                       (``obs.prometheus_text()``);
* :mod:`.peel_stats` — the paper's load-imbalance statistic observed at
                       runtime: per-slot iteration / level / alive-edge
                       histograms per ``(bucket, backend)``, feeding the
                       planner's future cost-model calibration.

Plus :mod:`.clock`, the single time source (fake-able in tests) behind
every duration, deadline, and trace timestamp.

Turn tracing on per session (``Session(trace="trace.json")``), per call
(``solve(qs, trace="trace.json")``), or process-wide via the
``REPRO_TRACE=path`` environment variable.  Disabled, the tracer is a
shared no-op singleton — no clock reads, no allocation.

An :class:`Observability` bundle (tracer + metrics + export path) is what
a :class:`repro.api.Session` owns; ``activate()`` installs it as the
context-current sink so instrumented library code (planner, exec,
stream) records into the owning session without explicit threading.
"""

from __future__ import annotations

import contextlib
import os

from .clock import (
    Clock,
    FakeClock,
    MonotonicClock,
    get_clock,
    now,
    remaining,
    set_clock,
    use_clock,
)
from .metrics import (
    DEFAULT_BUCKETS,
    HistogramData,
    MetricsRegistry,
    current_registry,
    get_registry,
    metrics_snapshot,
    prometheus_text,
    use_registry,
)
from .peel_stats import (
    EDGE_BUCKETS,
    IMBALANCE_BUCKETS,
    ITER_BUCKETS,
    PeelBatchTelemetry,
    imbalance_summary,
    record_peel_batch,
)
from .trace import (
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    current_tracer,
    export_trace,
    use_tracer,
)

__all__ = [
    # clock
    "Clock",
    "MonotonicClock",
    "FakeClock",
    "get_clock",
    "set_clock",
    "use_clock",
    "now",
    "remaining",
    # metrics
    "MetricsRegistry",
    "HistogramData",
    "DEFAULT_BUCKETS",
    "get_registry",
    "current_registry",
    "use_registry",
    "metrics_snapshot",
    "prometheus_text",
    # tracing
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "Span",
    "current_tracer",
    "use_tracer",
    "export_trace",
    # peel telemetry
    "record_peel_batch",
    "PeelBatchTelemetry",
    "imbalance_summary",
    "ITER_BUCKETS",
    "EDGE_BUCKETS",
    "IMBALANCE_BUCKETS",
    # the session-owned bundle
    "Observability",
    "TRACE_ENV_VAR",
]

TRACE_ENV_VAR = "REPRO_TRACE"


class Observability:
    """One session's instrument bundle: tracer + metrics + export path.

    ``trace`` selects the tracing mode:
      * ``None``  — consult the ``REPRO_TRACE`` env var: unset/empty means
        disabled; a path means trace and export there;
      * ``False`` — disabled (the shared no-op tracer);
      * ``True``  — trace in memory (export via :meth:`export_trace`);
      * a path    — trace and export there (the session auto-exports
        after ``solve()``/``flush()``).

    The metrics registry is private to the bundle and chains to the
    process-global default, so per-session metrics stay isolated while
    the global view aggregates (``repro.obs.metrics_snapshot()``).
    """

    def __init__(
        self,
        *,
        trace: bool | str | None = None,
        metrics: MetricsRegistry | None = None,
        capacity: int = 65536,
    ):
        if trace is None:
            trace = os.environ.get(TRACE_ENV_VAR) or False
        self.trace_path: str | None = trace if isinstance(trace, str) else None
        enabled = bool(trace)
        self.tracer: Tracer = Tracer(capacity=capacity) if enabled else NULL_TRACER
        self.metrics = (
            metrics
            if metrics is not None
            else MetricsRegistry(parent=get_registry())
        )

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

    @contextlib.contextmanager
    def activate(self):
        """Make this bundle the context-current metrics/tracer sink."""
        with use_registry(self.metrics), use_tracer(self.tracer):
            yield self

    def export_trace(self, path: str | None = None) -> str | None:
        """Write the Chrome trace JSON (to ``path`` or the configured one).

        Returns the written path, or ``None`` when tracing is disabled or
        no path is known.
        """
        path = path or self.trace_path
        if path is None or not self.tracer.enabled:
            return None
        return self.tracer.export(path)

    def metrics_snapshot(self) -> dict:
        """JSON snapshot of this bundle's (session-scoped) metrics."""
        return self.metrics.snapshot()

    def prometheus_text(self) -> str:
        return self.metrics.prometheus_text()
