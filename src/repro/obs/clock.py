"""The observability clock: one time source for every instrument.

Every duration, deadline, and trace timestamp in the query path reads
this clock instead of calling ``time.perf_counter`` directly.  That buys
two things:

* **one timeline** — span timestamps, queue/deadline accounting, and
  metrics all agree, so a trace's ``dur`` fields and ``RequestStats``
  are the same numbers;
* **fake time in tests** — installing a :class:`FakeClock`
  (``with use_clock(FakeClock()): ...``) lets deadline/timeout tests
  advance time explicitly instead of sleeping.

The deadline helper :func:`remaining` is the single place "how much of
this query's budget is left" is computed; ``TrussFuture.result()`` and
the batch former both use it, so a fake clock moves every deadline
consistently.
"""

from __future__ import annotations

import contextlib
import contextvars
import time

__all__ = [
    "Clock",
    "MonotonicClock",
    "FakeClock",
    "get_clock",
    "set_clock",
    "use_clock",
    "now",
    "sleep",
    "remaining",
]


class Clock:
    """Monotonic seconds source (the perf_counter contract)."""

    def now(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def sleep(self, dt: float) -> None:
        """Block for ``dt`` seconds of this clock's time.

        The resilience layer's retry backoff waits through here, so a
        fake clock makes backoff tests instantaneous (time advances,
        nothing actually sleeps).
        """
        time.sleep(max(0.0, float(dt)))


class MonotonicClock(Clock):
    """The real clock: ``time.perf_counter``."""

    def now(self) -> float:
        return time.perf_counter()


class FakeClock(Clock):
    """Manually advanced clock for tests — no sleeping.

    ``advance(dt)`` moves time forward; ``now()`` never moves on its own,
    so a timeout loop under a fake clock either expires immediately (the
    budget is already spent) or never (nothing advances it).
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards (dt={dt})")
        self._t += float(dt)

    def sleep(self, dt: float) -> None:
        self.advance(max(0.0, float(dt)))


_default_clock = MonotonicClock()
_current: contextvars.ContextVar[Clock | None] = contextvars.ContextVar(
    "repro_obs_clock", default=None
)


def get_clock() -> Clock:
    """The active clock: the context-installed one, else the real clock."""
    return _current.get() or _default_clock


def set_clock(clock: Clock | None) -> None:
    """Install ``clock`` for the current context (``None`` restores real time)."""
    _current.set(clock)


@contextlib.contextmanager
def use_clock(clock: Clock):
    """Scoped clock install: ``with use_clock(FakeClock()) as clk: ...``"""
    token = _current.set(clock)
    try:
        yield clock
    finally:
        _current.reset(token)


def now() -> float:
    """Current time on the active clock (monotonic seconds)."""
    return get_clock().now()


def sleep(dt: float) -> None:
    """Sleep ``dt`` seconds on the active clock (fake clocks just advance)."""
    get_clock().sleep(dt)


def remaining(submitted_at: float, deadline_s: float | None) -> float | None:
    """Seconds left of a query's deadline budget (the ONE deadline rule).

    ``None`` deadline means no budget (returns ``None``); otherwise the
    remainder is clamped at 0 — an expired deadline is "no time left",
    never negative.
    """
    if deadline_s is None:
        return None
    return max(0.0, submitted_at + deadline_s - now())
