"""Canonical metric-name registry.

Every counter, gauge, and histogram name used anywhere in the repo is
declared here, in one place.  The R5 lint (``repro.analysis.rules_metrics``)
cross-checks each ``inc``/``observe``/``set_gauge``/``value`` call site —
in src, tests, and benchmarks — against these sets, so a typo'd metric
name (a dashboard silently reading zeros) is a lint failure, not a
production mystery.

Names follow Prometheus conventions loosely: ``*_total``-style counters
keep their historical names, gauges are instantaneous, histograms carry
the unit suffix (``_s``, ``_frac``) where one applies.
"""

from __future__ import annotations

__all__ = ["COUNTERS", "GAUGES", "HISTOGRAMS", "ALL_METRIC_NAMES"]

COUNTERS: frozenset[str] = frozenset(
    {
        # core peel/exec layer
        "batch_bisects",
        "batches_run",
        "deadline_misses",
        "device_seconds_total",
        "dispatch_failures",
        "dispatches",
        "peel_batches",
        "peel_device_seconds_total",
        "peel_dispatches",
        "peel_fused_levels",
        "peel_slots",
        # compile cache
        "cache_bucket_compiles",
        "cache_bucket_hits",
        "cache_compiles",
        "cache_hits",
        # session / query lifecycle
        "queries_failed",
        "queries_quarantined",
        "queries_shed",
        "requests_served",
        # resilience
        "backend_fallbacks",
        "faults_injected",
        "retries",
        # streaming
        "stream_checkpoints",
        "stream_edges_repeeled",
        "stream_enumerations",
        "stream_update_dispatches",
        "stream_updates",
        # serving tier (router + fleet)
        "fleet_replica_restarts",
        "fleet_stream_handoffs",
        "router_affinity_cold",
        "router_affinity_hits",
        "router_affinity_redistributed",
        "router_quarantines",
        "router_queries_shed",
        "router_query_retries",
        "router_replica_spill_in",
        "router_replicas_quarantined",
        "router_spillovers",
    }
)

GAUGES: frozenset[str] = frozenset(
    {
        "queue_depth",
        "replica_compiled_buckets",
        "replica_live_queries",
        "replica_queue_depth",
        # router-side mirrors of replica counters (ingested snapshots land
        # as gauges: the router tracks each replica's latest value, not a
        # monotonic sum of its own)
        "replica_queries_failed",
        "replica_queries_quarantined",
        "replica_queries_shed",
        "replica_requests_served",
        "replica_retries",
    }
)

HISTOGRAMS: frozenset[str] = frozenset(
    {
        "batch_occupancy",
        "peel_batch_imbalance",
        "peel_device_time_s",
        "peel_level_edges",
        "peel_slot_iters",
        "peel_slot_levels",
        "stream_frontier_frac",
    }
)

ALL_METRIC_NAMES: frozenset[str] = COUNTERS | GAUGES | HISTOGRAMS
