"""Span tracer: where does a query's time actually go?

A :class:`Tracer` records nested spans — plan / pack / compile / dispatch /
device-wait / unpack — into a bounded ring buffer and exports them as
Chrome trace-event JSON (``chrome://tracing`` / Perfetto's legacy format:
``"ph": "X"`` complete events with microsecond ``ts``/``dur``).  Spans
carry attributes (bucket, backend, batch size, ...) in the event ``args``.

Design points:

* **near-zero overhead when disabled** — the module-default tracer is the
  :data:`NULL_TRACER` singleton whose ``span()`` returns one shared no-op
  context manager: no clock read, no allocation, no lock;
* **thread-safe** — spans from concurrent callers interleave safely
  (the ring is lock-guarded; ``tid`` is the recording thread, so the
  Chrome viewer lays concurrent work out on separate tracks);
* **bounded** — the ring keeps the most recent ``capacity`` events, so a
  long-lived serving session can leave tracing on without growing
  memory.

Timestamps come from the observability clock (:mod:`repro.obs.clock`),
so traces, metrics, and deadline accounting share one timeline.
"""

from __future__ import annotations

import collections
import contextlib
import contextvars
import json
import os
import threading
from typing import Any

from .clock import now as _now

__all__ = [
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "current_tracer",
    "use_tracer",
    "export_trace",
]


class Span:
    """One in-flight span; records a complete ("X") event on exit.

    ``attrs`` may be extended while the span is open
    (``sp.attrs["batch"] = 4``); the dict is written into the event's
    ``args`` at close.
    """

    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "Span":
        self._t0 = _now()
        return self

    def __exit__(self, *exc) -> None:
        t1 = _now()
        self._tracer._record(self.name, self._t0, t1 - self._t0, self.attrs)


class _NullSpan:
    """Shared no-op span: the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    @property
    def attrs(self) -> dict:
        return {}  # mutations are discarded — tracing is off

    name = ""


_NULL_SPAN = _NullSpan()


class Tracer:
    """Ring-buffered span recorder with Chrome trace-event export."""

    def __init__(self, *, capacity: int = 65536, enabled: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self._events: collections.deque[dict] = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.dropped = 0  # events evicted by the ring

    # -- recording ----------------------------------------------------- #
    def span(self, name: str, **attrs):
        """Context manager timing one named span (nesting by call stack)."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """A zero-duration marker ("i" event) — e.g. deadline-miss."""
        if not self.enabled:
            return
        ev = {
            "name": name,
            "ph": "i",
            "ts": _now() * 1e6,
            "s": "t",
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0xFFFFFFFF,
        }
        if attrs:
            ev["args"] = attrs
        self._push(ev)

    def _record(self, name: str, t0: float, dur: float, attrs: dict) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "ts": t0 * 1e6,
            "dur": dur * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0xFFFFFFFF,
        }
        if attrs:
            ev["args"] = {k: _jsonable(v) for k, v in attrs.items()}
        self._push(ev)

    def _push(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)

    # -- reading / export ---------------------------------------------- #
    def events(self) -> list[dict]:
        """The buffered events, oldest first."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def export(self, path: str) -> str:
        """Write Chrome trace-event JSON; returns ``path``.

        Load via ``chrome://tracing``, Perfetto ("legacy JSON"), or
        ``json.load`` (``{"traceEvents": [...]}``).
        """
        doc = {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }
        with open(path, "w") as f:
            json.dump(doc, f)
        return str(path)


class NullTracer(Tracer):
    """Permanently disabled tracer (the module default)."""

    def __init__(self):
        super().__init__(capacity=1, enabled=False)

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def instant(self, name: str, **attrs) -> None:
        pass


NULL_TRACER = NullTracer()


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# ---------------------------------------------------------------------- #
# Context plumbing: whose trace are we recording into?
# ---------------------------------------------------------------------- #
_current: contextvars.ContextVar[Tracer | None] = contextvars.ContextVar(
    "repro_obs_tracer", default=None
)


def current_tracer() -> Tracer:
    """The context-installed tracer, else the no-op :data:`NULL_TRACER`.

    Instrumented library code (planner, exec, stream) records here; a
    traced session installs its tracer for the duration of its work
    (``Observability.activate``), and untraced paths cost one contextvar
    read per span site.
    """
    return _current.get() or NULL_TRACER


@contextlib.contextmanager
def use_tracer(tracer: Tracer):
    """Scoped install: record this context's spans into ``tracer``."""
    token = _current.set(tracer)
    try:
        yield tracer
    finally:
        _current.reset(token)


def export_trace(path: str, tracer: Tracer | None = None) -> str:
    """Export ``tracer`` (default: the context-current one) to ``path``."""
    return (tracer or current_tracer()).export(path)
