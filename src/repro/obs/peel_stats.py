"""Peel telemetry: the paper's load-imbalance statistic, observed at runtime.

``repro.graphs.stats.imbalance_stats`` *predicts* imbalance from the
degree structure (max/mean task work — the quantity the fine-grained
formulation fixes); this module *measures* it on real dispatches.  The
device peel already carries per-slot state in its while-loop —
``levels`` (fixed points peeled), ``iters`` (prune trips while the slot
was live) and ``edges_alive`` (the final level's alive-edge count) — so
every batch yields a free imbalance sample: the slowest slot holds the
whole dispatch, exactly like the paper's slowest SIMD lane holds its
warp, and ``max(iters) / mean(iters)`` is the batch-level analog of the
paper's max/mean work ratio.

Samples are recorded per ``(bucket, backend)`` label set so the
planner's auto rule can later be calibrated from observed device time
instead of the static two-threshold heuristic (see ROADMAP's cost-model
item): the registry accumulates, per backend per shape class,

* ``peel_device_time_s``   — dispatch wall time histogram,
* ``peel_slot_iters``      — per-slot iteration histogram (the
  imbalance's raw material),
* ``peel_batch_imbalance`` — per-batch max/mean slot-iteration ratio
  (1.0 == perfectly balanced, the paper's statistic),
* ``peel_level_edges``     — per-slot final-level alive-edge counts,
* ``peel_batches`` / ``peel_slots`` counters.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .metrics import MetricsRegistry, current_registry

__all__ = [
    "ITER_BUCKETS",
    "IMBALANCE_BUCKETS",
    "EDGE_BUCKETS",
    "PeelBatchTelemetry",
    "record_peel_batch",
    "imbalance_summary",
]

# Powers of two: iteration counts and edge counts are size-like.
ITER_BUCKETS = tuple(float(1 << i) for i in range(0, 12))
EDGE_BUCKETS = tuple(float(1 << i) for i in range(0, 24, 2))
# Ratio-like: 1.0 is perfect balance, heavy tails run past 8x.
IMBALANCE_BUCKETS = (1.0, 1.25, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 16.0, 32.0)


@dataclasses.dataclass(frozen=True)
class PeelBatchTelemetry:
    """One dispatch's imbalance sample (also recorded into the registry)."""

    batch_size: int  # real members (pad slots excluded)
    max_iters: int
    mean_iters: float
    imbalance: float  # max/mean slot iterations; 1.0 == balanced
    max_levels: int
    device_time_s: float


def record_peel_batch(
    *,
    bucket,
    backend,
    levels: Sequence[int] | np.ndarray,
    iters: Sequence[int] | np.ndarray,
    edges_alive: Sequence[int] | np.ndarray | None = None,
    batch_size: int | None = None,
    device_time_s: float = 0.0,
    metrics: MetricsRegistry | None = None,
) -> PeelBatchTelemetry:
    """Record one dispatch's per-slot peel state into the metrics registry.

    ``levels`` / ``iters`` / ``edges_alive`` are the executor's per-slot
    arrays (``PeelState``); only the first ``batch_size`` slots are real
    members — pad slots are excluded from the statistics (they retire on
    the first trip and would dilute the imbalance toward 1/B).
    """
    m = metrics if metrics is not None else current_registry()
    labels = {"bucket": _bucket_label(bucket), "backend": str(backend)}
    iters = np.asarray(iters, np.int64)
    levels = np.asarray(levels, np.int64)
    b = int(batch_size) if batch_size is not None else int(iters.shape[0])
    live_iters = iters[:b]
    live_levels = levels[:b]
    mean_it = float(live_iters.mean()) if b else 0.0
    max_it = int(live_iters.max(initial=0))
    imb = float(max_it / mean_it) if mean_it > 0 else 1.0

    m.inc("peel_batches", **labels)
    m.inc("peel_slots", b, **labels)
    m.inc("peel_device_seconds_total", device_time_s, **labels)
    m.observe("peel_device_time_s", device_time_s, **labels)
    m.observe("peel_batch_imbalance", imb, buckets=IMBALANCE_BUCKETS, **labels)
    for it in live_iters.tolist():
        m.observe("peel_slot_iters", it, buckets=ITER_BUCKETS, **labels)
    for lv in live_levels.tolist():
        m.observe("peel_slot_levels", lv, buckets=ITER_BUCKETS, **labels)
    if edges_alive is not None:
        ea = np.asarray(edges_alive, np.int64)[:b]
        for e in ea.tolist():
            m.observe("peel_level_edges", e, buckets=EDGE_BUCKETS, **labels)
    return PeelBatchTelemetry(
        batch_size=b,
        max_iters=max_it,
        mean_iters=mean_it,
        imbalance=imb,
        max_levels=int(live_levels.max(initial=0)),
        device_time_s=device_time_s,
    )


def _bucket_label(bucket) -> str:
    try:
        return f"n{bucket.n_pad}-nnz{bucket.nnz_pad}-w{bucket.window}"
    except AttributeError:
        return str(bucket)


def imbalance_summary(metrics: MetricsRegistry | None = None) -> list[dict]:
    """Per-(bucket, backend) roll-up of the recorded peel telemetry.

    One row per label series with the observed device time, slot
    iteration spread, and mean batch imbalance — the table the cost-model
    calibration (and ``BENCH_obs.json``) reads.
    """
    m = metrics if metrics is not None else current_registry()
    rows: list[dict] = []
    for key, h in sorted(m.histograms_named("peel_batch_imbalance").items()):
        labels = key[key.index("{") + 1 : -1] if "{" in key else ""
        it = m.histograms_named("peel_slot_iters").get(
            "peel_slot_iters" + (("{" + labels + "}") if labels else "")
        )
        dt = m.histograms_named("peel_device_time_s").get(
            "peel_device_time_s" + (("{" + labels + "}") if labels else "")
        )
        parsed = dict(
            part.split("=", 1) for part in labels.split(",") if "=" in part
        )
        rows.append(
            {
                "labels": labels,
                "bucket": parsed.get("bucket", ""),
                "backend": parsed.get("backend", ""),
                "batches": h.count,
                "mean_imbalance": round(h.mean, 4),
                "max_imbalance": round(h.max if h.count else 0.0, 4),
                "slot_iters_mean": round(it.mean, 4) if it else 0.0,
                "slot_iters_max": int(it.max) if it and it.count else 0,
                "device_time_s_total": round(dt.sum, 6) if dt else 0.0,
                "device_time_s_mean": round(dt.mean, 6) if dt else 0.0,
            }
        )
    return rows
