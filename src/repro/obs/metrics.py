"""Metrics registry: named counters / gauges / histograms with label sets.

One thread-safe registry replaces the ad-hoc counter dicts that grew all
over the serving stack (``CacheStats`` ints, ``ENUM_COUNTS``, per-object
``stats()`` tallies).  Instruments are identified by ``(name, labels)``
where labels are keyword pairs (``inc("dispatches", bucket=..., backend=...)``),
so per-(bucket, backend) breakdowns — the data the planner's cost-model
calibration needs — fall out of the key structure instead of bespoke
dicts.

Registries **chain to a parent**: a :class:`repro.api.Session` owns a
private registry parented to the process-global default, so per-session
metrics stay isolated (concurrent sessions / test runs don't pollute
each other) while the global view still aggregates everything.  Library
code that has no session handle records into :func:`current_registry`
— the session installs its registry for the duration of its work via
``use_registry`` (see ``Observability.activate``), and standalone calls
fall through to the global default.

Exports: :meth:`MetricsRegistry.snapshot` (JSON-able dict) and
:meth:`MetricsRegistry.prometheus_text` (Prometheus text exposition,
ready for a scrape endpoint or a textfile collector).
"""

from __future__ import annotations

import contextlib
import contextvars
import math
import re
import threading
from typing import Iterable, Mapping

__all__ = [
    "DEFAULT_BUCKETS",
    "HistogramData",
    "MetricsRegistry",
    "get_registry",
    "current_registry",
    "use_registry",
    "metrics_snapshot",
    "prometheus_text",
]

# Seconds-flavored default: spans 10 µs .. 100 s, the range of everything
# we time (plan µs through cold-compile seconds).  Call sites with
# different units (iterations, ratios, fractions) pass buckets=.
DEFAULT_BUCKETS = (
    1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0, 100.0,
)

_LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: Mapping[str, object]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape_label(value: str) -> str:
    """Backslash-escape the characters ``_fmt_key`` uses structurally."""
    return (
        value.replace("\\", "\\\\").replace(",", "\\,").replace("=", "\\=")
    )


def _split_unescaped(text: str, sep: str) -> list[str]:
    """Split on ``sep`` occurrences not preceded by a backslash escape."""
    parts: list[str] = []
    buf: list[str] = []
    i = 0
    while i < len(text):
        c = text[i]
        if c == "\\" and i + 1 < len(text):
            buf.append(c)
            buf.append(text[i + 1])
            i += 2
            continue
        if c == sep:
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(c)
        i += 1
    parts.append("".join(buf))
    return parts


def _unescape_label(value: str) -> str:
    out: list[str] = []
    i = 0
    while i < len(value):
        if value[i] == "\\" and i + 1 < len(value):
            out.append(value[i + 1])
            i += 2
        else:
            out.append(value[i])
            i += 1
    return "".join(out)


def _fmt_key(name: str, labels: _LabelKey) -> str:
    if not labels:
        return name
    # Label values are escaped so a value containing "," or "=" (graph
    # names, backend strings) still round-trips through _parse_key.
    inner = ",".join(f"{k}={_escape_label(v)}" for k, v in labels)
    return f"{name}{{{inner}}}"


def _parse_key(key: str) -> tuple[str, dict[str, str]]:
    """Invert :func:`_fmt_key`: ``"name{a=b,c=d}"`` -> (name, labels)."""
    if "{" not in key or not key.endswith("}"):
        return key, {}
    name, inner = key.split("{", 1)
    labels: dict[str, str] = {}
    for pair in _split_unescaped(inner[:-1], ","):
        if pair:
            # Split on the first unescaped "=": the key never contains
            # one, and "="s inside the value arrive escaped.
            head, *rest = _split_unescaped(pair, "=")
            labels[_unescape_label(head)] = _unescape_label("=".join(rest))
    return name, labels


class HistogramData:
    """One histogram series: cumulative-bucket counts + sum/min/max.

    Bucket bounds are fixed at first observation (later ``buckets=``
    arguments for the same series are ignored) with an implicit +inf
    overflow bucket, Prometheus-style.
    """

    __slots__ = ("bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, bounds: Iterable[float]):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram bounds must be sorted: {self.bounds}")
        self.counts = [0] * (len(self.bounds) + 1)  # +1: the +inf bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        i = 0
        for b in self.bounds:
            if value <= b:
                break
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def row(self) -> dict:
        buckets = {}
        cum = 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            buckets[f"{b:g}"] = cum
        buckets["+Inf"] = self.count
        return {
            "count": self.count,
            "sum": round(self.sum, 9),
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": round(self.mean, 9),
            "buckets": buckets,
        }


class MetricsRegistry:
    """Thread-safe counters / gauges / histograms with label sets."""

    def __init__(self, *, parent: "MetricsRegistry | None" = None):
        self.parent = parent
        self._lock = threading.Lock()
        self._counters: dict[tuple[str, _LabelKey], float] = {}
        self._gauges: dict[tuple[str, _LabelKey], float] = {}
        self._histograms: dict[tuple[str, _LabelKey], HistogramData] = {}

    # -- write side ---------------------------------------------------- #
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        """Add ``value`` to counter ``name{labels}`` (and the parent's)."""
        key = (name, _label_key(labels))
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + value
        if self.parent is not None:
            self.parent.inc(name, value, **labels)

    def set_gauge(self, name: str, value: float, **labels) -> None:
        """Set gauge ``name{labels}`` to ``value`` (last write wins)."""
        key = (name, _label_key(labels))
        with self._lock:
            self._gauges[key] = float(value)
        if self.parent is not None:
            self.parent.set_gauge(name, value, **labels)

    def observe(
        self,
        name: str,
        value: float,
        *,
        buckets: Iterable[float] | None = None,
        **labels,
    ) -> None:
        """Record ``value`` into histogram ``name{labels}``."""
        key = (name, _label_key(labels))
        with self._lock:
            h = self._histograms.get(key)
            if h is None:
                h = self._histograms[key] = HistogramData(
                    buckets if buckets is not None else DEFAULT_BUCKETS
                )
            h.observe(float(value))
        if self.parent is not None:
            self.parent.observe(name, value, buckets=buckets, **labels)

    def ingest(self, counters: Mapping[str, float], **labels) -> None:
        """Mirror another process's cumulative counters into this registry.

        ``counters`` maps snapshot keys — plain names or the
        ``name{k=v,...}`` strings :meth:`snapshot` emits — to cumulative
        values read from a remote source (e.g. a replica's
        ``HealthReport``).  Each series is recorded as a **gauge** (last
        write wins): the remote values are already totals, so replaying
        them through :meth:`inc` on every poll would double-count.
        ``labels`` are merged into every series (``replica=...``), which
        keeps a fleet roll-up per-source while the parent chain still
        aggregates the whole fleet in one snapshot."""
        for key, value in counters.items():
            name, parsed = _parse_key(str(key))
            self.set_gauge(name, float(value), **{**parsed, **labels})

    # -- read side ----------------------------------------------------- #
    def value(self, name: str, **labels) -> float:
        """Current counter value (0.0 if never incremented)."""
        with self._lock:
            return self._counters.get((name, _label_key(labels)), 0.0)

    def gauge_value(self, name: str, **labels) -> float:
        with self._lock:
            return self._gauges.get((name, _label_key(labels)), 0.0)

    def histogram(self, name: str, **labels) -> HistogramData | None:
        with self._lock:
            return self._histograms.get((name, _label_key(labels)))

    def histograms_named(self, name: str) -> dict[str, HistogramData]:
        """Every label-series of histogram ``name`` (formatted-key map)."""
        with self._lock:
            return {
                _fmt_key(n, lk): h
                for (n, lk), h in self._histograms.items()
                if n == name
            }

    def snapshot(self) -> dict:
        """JSON-able view: ``{"counters": .., "gauges": .., "histograms": ..}``.

        Keys are ``name{label=value,...}`` strings (labels sorted), so the
        snapshot round-trips through ``json.dumps`` unchanged.
        """
        with self._lock:
            return {
                "counters": {
                    _fmt_key(n, lk): v for (n, lk), v in sorted(self._counters.items())
                },
                "gauges": {
                    _fmt_key(n, lk): v for (n, lk), v in sorted(self._gauges.items())
                },
                "histograms": {
                    _fmt_key(n, lk): h.row()
                    for (n, lk), h in sorted(self._histograms.items())
                },
            }

    def prometheus_text(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        out: list[str] = []
        with self._lock:
            counters = sorted(self._counters.items())
            gauges = sorted(self._gauges.items())
            hists = sorted(self._histograms.items())
        seen: set[str] = set()
        for (name, lk), v in counters:
            pname = _prom_name(name)
            if pname not in seen:
                seen.add(pname)
                out.append(f"# TYPE {pname} counter")
            out.append(f"{pname}{_prom_labels(lk)} {v:g}")
        for (name, lk), v in gauges:
            pname = _prom_name(name)
            if pname not in seen:
                seen.add(pname)
                out.append(f"# TYPE {pname} gauge")
            out.append(f"{pname}{_prom_labels(lk)} {v:g}")
        for (name, lk), h in hists:
            pname = _prom_name(name)
            if pname not in seen:
                seen.add(pname)
                out.append(f"# TYPE {pname} histogram")
            cum = 0
            for b, c in zip(h.bounds, h.counts):
                cum += c
                out.append(
                    f"{pname}_bucket{_prom_labels(lk, le=f'{b:g}')} {cum}"
                )
            out.append(f"{pname}_bucket{_prom_labels(lk, le='+Inf')} {h.count}")
            out.append(f"{pname}_sum{_prom_labels(lk)} {h.sum:g}")
            out.append(f"{pname}_count{_prom_labels(lk)} {h.count}")
        return "\n".join(out) + ("\n" if out else "")

    def reset(self) -> None:
        """Drop every recorded series (test/bench isolation helper)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    return name if not name[:1].isdigit() else "_" + name


def _prom_labels(lk: _LabelKey, **extra: str) -> str:
    pairs = [*lk, *extra.items()]
    if not pairs:
        return ""
    inner = ",".join(f'{_NAME_RE.sub("_", k)}="{v}"' for k, v in pairs)
    return "{" + inner + "}"


# ---------------------------------------------------------------------- #
# The default (process-global) registry + the context-scoped current one
# ---------------------------------------------------------------------- #
_default_registry = MetricsRegistry()
_current: contextvars.ContextVar[MetricsRegistry | None] = contextvars.ContextVar(
    "repro_obs_metrics", default=None
)


def get_registry() -> MetricsRegistry:
    """The process-global default registry (every session's parent)."""
    return _default_registry


def current_registry() -> MetricsRegistry:
    """The context-installed registry, else the global default.

    Library code without a session handle (``repro.stream.frontier``,
    ``repro.exec.peel``) records here; a session's ``activate()`` scope
    redirects it to the session's own registry.
    """
    return _current.get() or _default_registry


@contextlib.contextmanager
def use_registry(registry: MetricsRegistry):
    """Scoped install: record this context's metrics into ``registry``."""
    token = _current.set(registry)
    try:
        yield registry
    finally:
        _current.reset(token)


def metrics_snapshot(registry: MetricsRegistry | None = None) -> dict:
    """JSON snapshot of ``registry`` (default: the global registry)."""
    return (registry or _default_registry).snapshot()


def prometheus_text(registry: MetricsRegistry | None = None) -> str:
    """Prometheus exposition of ``registry`` (default: the global registry)."""
    return (registry or _default_registry).prometheus_text()
