"""K-truss driver: support → prune fixed-point loop, K_max search.

The paper-faithful single-graph object (``repro.api`` is the system's
front door; multi-level workloads here are adapters over it):

    engine = KTrussEngine(graph, granularity="fine", mode="eager")
    res = engine.ktruss(k=3)           # alive mask + supports + iterations
    kmax = engine.kmax()               # largest non-empty truss (via repro.api)

``granularity`` selects the paper's axis of study:
  * ``"coarse"`` — Algorithm 2 (row tasks; the baseline).
  * ``"fine"``   — Algorithm 3 (nonzero tasks; the contribution).
``mode`` selects the update dataflow (``"eager"`` scatter vs ``"owner"``
collision-free; DESIGN.md §4), and ``backend`` selects XLA ops or the
Pallas TPU kernels (interpret-mode on CPU) — together they map onto a
``repro.api`` registry backend for the ``kmax``/``decompose`` paths.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.csr import CSRGraph
from .eager_fine import (
    FineProblem,
    bucket_tasks,
    prepare_fine,
    support_fine_bucketed,
)

__all__ = ["KTrussResult", "TrussDecomposition", "KTrussEngine", "make_support_fn"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class KTrussResult:
    k: int
    alive: np.ndarray  # (nnz,) bool over the graph's real edges
    support: np.ndarray  # (nnz,) int32 (post-prune supports)
    iterations: int
    edges_remaining: int


@dataclasses.dataclass(frozen=True)
class TrussDecomposition:
    """Full truss decomposition: the trussness of every edge.

    ``trussness[e]`` is the largest k such that edge e belongs to the
    k-truss; every edge is trivially in the 2-truss, so values are >= 2
    (PKT-style decomposition — the workload users actually want, not just
    one-k membership).
    """

    trussness: np.ndarray  # (nnz,) int32, >= 2
    kmax: int  # max(trussness) (0 on edgeless graphs)
    levels: int  # number of fixed-point levels peeled


def make_support_fn(
    p: FineProblem,
    *,
    granularity: str = "fine",
    mode: str = "eager",
    backend: str = "xla",
    window: int,
    chunk: int = 1024,
    row_chunk: int = 32,
) -> Callable[[jax.Array], jax.Array]:
    """Build ``alive -> support`` for one decomposition/dataflow/backend.

    The problem-bound view of ``repro.exec.make_problem_support`` — one
    copy of the granularity/mode/backend dispatch serves both the engine
    and the exec/serving layers.
    """
    from ..exec.peel import make_problem_support  # lazy: avoids import cycle

    fn = make_problem_support(
        granularity=granularity,
        mode=mode,
        backend=backend,
        window=window,
        chunk=chunk,
        row_chunk=row_chunk,
    )
    return functools.partial(fn, p)


class KTrussEngine:
    """Compiled K-truss solver for one graph (static shapes reused per k)."""

    def __init__(
        self,
        g: CSRGraph,
        *,
        granularity: str = "fine",
        mode: str = "eager",
        backend: str = "xla",
        window: int | None = None,
        chunk: int = 1024,
        row_chunk: int | None = None,
        max_iters: int = 1_000,
        bucketed: bool = False,
    ):
        self.g = g
        self.granularity = granularity
        self.mode = mode
        self.backend = backend
        self.bucketed = bucketed
        self.problem = prepare_fine(g, chunk=chunk)
        max_out = g.max_degree()
        max_und = int(g.undirected_csr().max_degree())
        need = max_und if (mode == "owner" or backend == "pallas") else max_out
        self.window = int(window) if window is not None else max(8, _round_up(need, 8))
        self.chunk = chunk
        # Keep the coarse chunk's (C, W, W) working set near ~2^24 lanes.
        self.row_chunk = (
            int(row_chunk)
            if row_chunk is not None
            else max(1, min(64, (1 << 24) // max(1, self.window**2)))
        )
        self.max_iters = max_iters
        if bucketed:
            if granularity != "fine" or mode != "eager" or backend != "xla":
                raise ValueError("bucketed requires fine/eager/xla")
            buckets = [
                (wb, jnp.asarray(ids))
                for wb, ids in bucket_tasks(g, chunk=min(chunk, 256))
            ]
            self._support = functools.partial(
                support_fine_bucketed,
                self.problem,
                buckets=buckets,
                chunk=min(chunk, 256),
            )
        else:
            self._support = make_support_fn(
                self.problem,
                granularity=granularity,
                mode=mode,
                backend=backend,
                window=self.window,
                chunk=chunk,
                row_chunk=self.row_chunk,
            )
        self._fixed_point = jax.jit(self._fixed_point_impl, static_argnums=(1,))
        self._api = None

    # ------------------------------------------------------------------ #
    def support(self, alive: jax.Array) -> jax.Array:
        """One support computation (no pruning) — benchmark entry point."""
        return self._support(alive)

    def initial_alive(self) -> jax.Array:
        return jnp.asarray(self.problem.colidx != 0)

    def _fixed_point_impl(self, alive0: jax.Array, k: int):
        thresh = jnp.int32(k - 2)

        def cond(state):
            _, _, changed, it = state
            return changed & (it < self.max_iters)

        def body(state):
            alive, _, _, it = state
            s = self._support(alive)
            new_alive = alive & (s >= thresh)
            changed = jnp.any(new_alive != alive)
            return new_alive, s * new_alive.astype(s.dtype), changed, it + 1

        state = (alive0, jnp.zeros_like(alive0, jnp.int32), jnp.asarray(True), 0)
        alive, s, _, it = jax.lax.while_loop(cond, body, state)
        return alive, s, it

    # ------------------------------------------------------------------ #
    def ktruss(self, k: int, alive0: jax.Array | None = None) -> KTrussResult:
        alive0 = self.initial_alive() if alive0 is None else alive0
        alive, s, it = self._fixed_point(alive0, int(k))
        alive_np = np.asarray(alive)[: self.g.nnz]
        return KTrussResult(
            k=int(k),
            alive=alive_np,
            support=np.asarray(s)[: self.g.nnz],
            iterations=int(it),
            edges_remaining=int(alive_np.sum()),
        )

    # ------------------------------------------------------------------ #
    # Device-resident peel: kmax / decompose in ONE dispatch, lowered
    # through repro.api (the one pack/cache/dispatch path)
    # ------------------------------------------------------------------ #
    def _api_session(self):
        """Lazily built 1-slot :class:`repro.api.Session` pinned to this
        engine's (granularity, kernel, mode) as a registry backend.

        ``kmax``/``decompose`` are adapters over it — the engine keeps no
        peel/pack/cache glue of its own.  The api path buckets the graph
        itself (power-of-two window from the undirected degree), so the
        engine's custom ``window``/``bucketed`` knobs only shape its own
        ``ktruss``/``support`` closures.  Each call re-packs the graph
        into its bucket (O(nnz) host numpy) — unlike the old
        engine-resident problem, but dominated by the device peel it
        precedes; the compiled executable itself is cached per bucket.
        """
        if self._api is None:
            from ..api import BackendKey, Session  # lazy: core stays api-free

            chunk = self.chunk
            if chunk & (chunk - 1):  # api packing wants a power of two
                chunk = 1 << (chunk.bit_length() - 1)
            self._api = Session(
                backend=BackendKey(
                    "coarse" if self.granularity == "coarse" else "fine",
                    self.backend,
                    "aligned",
                ),
                mode=self.mode,
                max_batch=1,
                chunk=max(8, chunk),
            )
        return self._api

    @property
    def peel_executor(self):
        """The on-device peel executor behind :meth:`kmax`/:meth:`decompose`
        (one compiled ``lax.while_loop``, no per-level host round-trips).
        Its ``dispatches`` counter is the test hook for the one-dispatch
        contract."""
        return self._api_session().executor_for(self.g)

    def kmax(self, k_start: int = 3) -> int:
        """Largest k with a non-empty truss (0 if even the ``k_start``-truss
        is empty) — the whole peel in one device dispatch.

        Per-level masks/supports live on :meth:`peel_levels`.
        """
        from ..api import TrussQuery  # lazy: core stays api-free

        return int(self._api_session().solve([TrussQuery.kmax(self.g, k_start)])[0])

    def decompose(self, k_start: int = 3) -> TrussDecomposition:
        """Full truss decomposition in one device dispatch.

        An edge's trussness is the last k whose truss still contains it;
        edges never reaching the ``k_start``-truss keep trussness
        ``k_start - 1`` (= 2 by default: membership in the 2-truss is
        vacuous).
        """
        from ..api import TrussQuery  # lazy: core stays api-free

        return self._api_session().solve([TrussQuery.decompose(self.g, k_start)])[0]

    # ------------------------------------------------------------------ #
    # Host-side level peel: per-level results (the only API that needs a
    # dispatch per level; kmax/decompose run on-device above)
    # ------------------------------------------------------------------ #
    def _peel(self, k_start: int = 3):
        """Yield (k, result) per level, warm-starting each k from the
        (k-1)-truss; ends after the first level whose truss is empty."""
        alive = self.initial_alive()
        k = k_start
        while bool(np.asarray(alive).any()):
            res = self.ktruss(k, alive0=alive)
            yield k, res
            pad = self.problem.nnz_pad - self.g.nnz
            alive = jnp.asarray(np.pad(res.alive, (0, pad)))
            k += 1

    def peel_levels(self, k_start: int = 3) -> tuple[int, list[KTrussResult]]:
        """(kmax, per-level results) for callers that need every level's
        alive mask/supports; costs one dispatch per level."""
        results: list[KTrussResult] = []
        kmax = 0
        for k, res in self._peel(k_start):
            if res.edges_remaining:
                kmax = k
                results.append(res)
        return kmax, results
