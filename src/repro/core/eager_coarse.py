"""Coarse-grained parallel Eager K-truss support computation (Algorithm 2).

One task per **row** (vertex) of the upper-triangular adjacency — the
baseline decomposition of Low et al. that this paper's contribution
replaces.  On vector hardware every row task is padded to the maximum
degree in *both* the neighbor dimension and the per-neighbor window, so the
work per chunk of C rows is ``C × W × W`` regardless of how sparse the rows
actually are.  That padding waste is the SIMD/TPU manifestation of the
thread-level load imbalance the paper measures (DESIGN.md §2), and the
benchmarks report it side by side with the fine-grained version.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .eager_fine import FineProblem
from .taskmap import sorted_window_member

__all__ = ["support_coarse_eager"]


def support_coarse_eager(
    p: FineProblem, alive: jax.Array, *, window: int, row_chunk: int = 32
) -> jax.Array:
    """Support per directed edge via row-parallel eager updates (Alg. 2).

    Args:
      p: problem arrays (``prepare_fine`` — shared with the fine algorithm).
      alive: (nnzp,) bool mask over directed edges.
      window: static width ≥ max out-degree.
      row_chunk: rows per scan step (memory scales with row_chunk·window²).

    Returns:
      (nnzp,) int32 support (0 on dead/pad lanes).
    """
    n, nnzp = p.n, p.nnz_pad
    w = int(window)
    c = int(row_chunk)
    large = jnp.int32(n + 2)
    offs = jnp.arange(w, dtype=jnp.int32)

    num_chunks = (n + c - 1) // c

    def body(s_acc: jax.Array, chunk_idx: jax.Array):
        # 1-based row ids; rows beyond n map to the empty sentinel row 0.
        v = chunk_idx * c + 1 + jnp.arange(c, dtype=jnp.int32)
        v = jnp.where(v <= n, v, 0)

        start = p.rowptr[jnp.maximum(v, 1) - 1] * (v > 0)  # (C,)
        a_idx = start[:, None] + offs[None, :]  # (C, W) global slots
        a_in = offs[None, :] < p.deg[v][:, None]
        a_idx_c = jnp.clip(a_idx, 0, nnzp - 1)
        a_vals = jnp.where(a_in, p.colidx[a_idx_c], 0)  # κ per (c, j)
        a_alive = a_in & alive[a_idx_c]

        # Row-κ windows for every j: (C, W, W).
        kappa = a_vals
        b_start = p.rowptr[jnp.maximum(kappa, 1) - 1] * (kappa > 0)  # (C, W)
        b_idx = b_start[:, :, None] + offs[None, None, :]
        b_in = offs[None, None, :] < p.deg[kappa][:, :, None]
        b_idx_c = jnp.clip(b_idx, 0, nnzp - 1)
        b_nav = jnp.where(b_in, p.colidx[b_idx_c], large)
        b_alive = b_in & alive[b_idx_c]

        # Suffix queries: task (c, j) intersects a_vals[c, j+1:] with row κ.
        task_ok = a_alive  # edge (v_c, κ_j) itself must be alive
        suffix = offs[None, None, :] > offs[None, :, None]  # w > j
        q = jnp.where(
            suffix & a_alive[:, None, :] & task_ok[:, :, None],
            a_vals[:, None, :],
            0,
        )  # (C, W, W): queries for task (c, j)

        member, pos = sorted_window_member(
            q.reshape(c * w, w), b_nav.reshape(c * w, w)
        )
        member = member.reshape(c, w, w)
        pos_c = jnp.minimum(pos.reshape(c, w, w), w - 1)
        member &= jnp.take_along_axis(b_alive, pos_c, axis=2, mode="clip")
        ones = member.astype(jnp.int32)

        # u1: edge (v, κ_j) gains the intersection count.
        u1_tgt = jnp.where(task_ok, a_idx_c, nnzp)
        s_acc = s_acc.at[u1_tgt.reshape(-1)].add(
            jnp.sum(ones, axis=2).reshape(-1), mode="drop"
        )
        # u2: matched suffix entries (edges (v, m)).
        u2_tgt = jnp.where(jnp.any(member, axis=1), a_idx_c, nnzp)
        s_acc = s_acc.at[u2_tgt.reshape(-1)].add(
            jnp.sum(ones, axis=1).reshape(-1), mode="drop"
        )
        # u3: matched row-κ entries (edges (κ, m)).
        u3_tgt = jnp.where(member, b_start[:, :, None] + pos_c, nnzp)
        s_acc = s_acc.at[u3_tgt.reshape(-1)].add(ones.reshape(-1), mode="drop")
        return s_acc, None

    s0 = jnp.zeros(nnzp, jnp.int32)
    s_final, _ = jax.lax.scan(body, s0, jnp.arange(num_chunks, dtype=jnp.int32))
    return s_final
