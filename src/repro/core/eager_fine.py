"""Fine-grained parallel Eager K-truss support computation (Algorithm 3).

One task per **nonzero** (edge) of the upper-triangular adjacency.  Task
``t`` — the j-th nonzero of row ``i`` with column ``κ = colidx[t]`` —
intersects the row-``i`` suffix ``a_i12[j+1:]`` with row ``A(κ,:)`` and
performs the paper's three eager updates:

  u1:  S[t]            += |suffix ∩ N⁺(κ)|      (edge (i,κ) itself)
  u2:  S[pos of m in i] += 1  per match m        (edges (i,m))
  u3:  S[pos of m in κ] += 1  per match m        (edges (κ,m))

Two execution modes (DESIGN.md §4):

* ``eager`` — the faithful dataflow: scatter-adds replace GPU atomics
  (associativity ⇒ determinism under XLA's sorted combiners).
* ``owner`` — collision-free reformulation: each edge's support is computed
  wholly by its own task as |N(a) ∩ N(b)| over the *undirected* alive
  neighborhoods.  Algebraically identical (property-tested); this is the
  form the Pallas TPU kernel implements, since TPU grid cells cannot
  atomically collide.

All shapes are static: windows of width ``window`` (≥ max degree), tasks
processed in chunks of ``chunk`` via ``lax.scan`` to bound memory.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.csr import CSRGraph
from .taskmap import sorted_window_member

__all__ = [
    "FineProblem",
    "prepare_fine",
    "support_fine_eager",
    "support_fine_owner",
    "support_fine_stacked",
]


class FineProblem(NamedTuple):
    """Static-shape device arrays for the fine-grained algorithm.

    Directed (upper-triangular) arrays drive the eager mode; the undirected
    mirror (u*) drives the owner mode.  ``u2d`` maps each undirected nonzero
    to its directed edge id so a single ``alive`` vector (over directed
    edges) masks both views.

    Contract: ``rowptr``/``urowptr`` are read only as row *starts*
    (``rowptr[v-1]`` begins row v; extents come from ``deg``/``udeg``), so
    layouts may leave unowned pad lanes between rows — the slot-aligned
    packing (``repro.graphs.pack``, ``layout="aligned"``) relies on this.
    """

    rowptr: jax.Array  # (n+1,) int32
    colidx: jax.Array  # (nnzp,) int32, 0 = pad
    edge_row: jax.Array  # (nnzp,) int32
    deg: jax.Array  # (n+1,) int32
    urowptr: jax.Array  # (n+1,) int32
    ucolidx: jax.Array  # (unnzp,) int32
    u2d: jax.Array  # (unnzp,) int32 -> directed edge id (nnzp for pad)
    uedge_row: jax.Array  # (unnzp,) int32  (row id of undirected entry)
    udeg: jax.Array  # (n+1,) int32

    @property
    def n(self) -> int:
        return int(self.rowptr.shape[0] - 1)

    @property
    def nnz_pad(self) -> int:
        return int(self.colidx.shape[0])


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def prepare_fine(
    g: CSRGraph,
    chunk: int = 1024,
    *,
    nnz_pad: int | None = None,
    unnz_pad: int | None = None,
) -> FineProblem:
    """Host-side packing of a CSR graph into :class:`FineProblem` arrays.

    ``nnz_pad``/``unnz_pad`` override the default round-up-to-chunk padding
    with explicit targets so callers (the serving compile cache) can
    canonicalize many graphs onto one static shape.
    """
    nnzp = max(_round_up(g.nnz, chunk), chunk) if nnz_pad is None else int(nnz_pad)
    if nnzp < g.nnz or nnzp % chunk:
        raise ValueError(f"nnz_pad={nnzp} must be a chunk multiple >= nnz={g.nnz}")
    d = g.device_csr(nnzp)
    u = g.undirected_csr()
    unnzp = max(_round_up(u.nnz, chunk), chunk) if unnz_pad is None else int(unnz_pad)
    if unnzp < u.nnz:
        raise ValueError(f"unnz_pad={unnzp} < undirected nnz={u.nnz}")

    # Map undirected nonzeros to directed edge ids: entry (a,b) of the
    # symmetric CSR corresponds to directed edge (min(a,b), max(a,b)).  The
    # directed nonzeros are globally sorted under the composite key
    # row * (n + 2) + col (rows ascending, colidx ascending within a row),
    # so one vectorized searchsorted over those keys resolves every
    # undirected entry at once — no per-edge Python loop.
    urows = u.row_of_edge()
    lo = np.minimum(urows, u.colidx).astype(np.int64)
    hi = np.maximum(urows, u.colidx).astype(np.int64)
    stride = np.int64(g.n + 2)
    dkeys = g.row_of_edge().astype(np.int64) * stride + g.colidx
    u2d = np.searchsorted(dkeys, lo * stride + hi)
    pad_u = unnzp - u.nnz

    return FineProblem(
        rowptr=jnp.asarray(d.rowptr),
        colidx=jnp.asarray(d.colidx),
        edge_row=jnp.asarray(d.edge_row),
        deg=jnp.asarray(d.deg),
        urowptr=jnp.asarray(u.rowptr.astype(np.int32)),
        ucolidx=jnp.asarray(np.pad(u.colidx.astype(np.int32), (0, pad_u))),
        u2d=jnp.asarray(
            np.pad(u2d.astype(np.int32), (0, pad_u), constant_values=nnzp)
        ),
        uedge_row=jnp.asarray(np.pad(u.row_of_edge().astype(np.int32), (0, pad_u))),
        udeg=jnp.asarray(u.degrees().astype(np.int32)),
    )


# ---------------------------------------------------------------------- #
# Mode "eager": faithful Algorithm 3 dataflow (scatter-adds for atomics)
# ---------------------------------------------------------------------- #
def support_fine_eager(
    p: FineProblem,
    alive: jax.Array,
    *,
    window: int,
    chunk: int = 1024,
    tasks: jax.Array | None = None,
    s_init: jax.Array | None = None,
) -> jax.Array:
    """Support per directed edge via the eager triple-update (Alg. 3).

    Args:
      p: problem arrays (``prepare_fine``).
      alive: (nnzp,) bool — surviving edges (pad lanes False).
      window: static window width ≥ max degree of the graph.
      chunk: tasks per scan step.
      tasks: optional (multiple-of-chunk,) explicit task ids to process
        (``nnz_pad`` = skip) — the degree-bucketing hook: each bucket runs
        with a window sized to its own degree class instead of the global
        max (EXPERIMENTS §Perf-ktruss).
      s_init: optional accumulator to add into (bucket chaining).

    Returns:
      (nnzp,) int32 support (0 on dead/pad lanes).
    """
    nnzp = p.nnz_pad
    n_tasks = nnzp if tasks is None else int(tasks.shape[0])
    if n_tasks % chunk:
        raise ValueError(f"tasks={n_tasks} not a multiple of chunk={chunk}")
    w = int(window)
    large = jnp.int32(p.n + 2)
    offs = jnp.arange(w, dtype=jnp.int32)[None, :]

    def body(s_acc: jax.Array, chunk_start: jax.Array):
        idx = chunk_start + jnp.arange(chunk, dtype=jnp.int32)
        if tasks is not None:
            raw = tasks[idx]
            skip = raw >= nnzp
            t = jnp.minimum(raw, nnzp - 1).astype(jnp.int32)
        else:
            t = idx
            skip = jnp.zeros((chunk,), bool)
        kappa = p.colidx[t]
        i = p.edge_row[t]
        valid_t = (kappa != 0) & alive[t] & ~skip

        # --- row-i suffix window (queries) -------------------------------
        a_idx = t[:, None] + 1 + offs  # global colidx positions
        # Row end as start + degree (not rowptr[i]): rowptr is read only as
        # row *starts* so slot-aligned packings may interleave pad lanes
        # between slots without violating any prefix-sum invariant.
        i_start = p.rowptr[jnp.maximum(i, 1) - 1] * (i > 0)
        row_end = (i_start + p.deg[i])[:, None]
        a_in = a_idx < row_end
        a_idx_c = jnp.clip(a_idx, 0, nnzp - 1)
        a_vals = jnp.where(a_in, p.colidx[a_idx_c], 0)
        a_alive = a_in & alive[a_idx_c]
        q = jnp.where(a_alive & valid_t[:, None], a_vals, 0)

        # --- row-κ window (sorted navigation values) ---------------------
        b_start = p.rowptr[jnp.maximum(kappa, 1) - 1] * (kappa > 0)
        b_idx = b_start[:, None] + offs
        b_in = offs < p.deg[kappa][:, None]
        b_idx_c = jnp.clip(b_idx, 0, nnzp - 1)
        b_nav = jnp.where(b_in, p.colidx[b_idx_c], large)
        b_alive = b_in & alive[b_idx_c]

        if w <= 32:
            # Small windows: O(W²) broadcast equality beats the binary
            # search — no gathers at all (§Perf-ktruss iteration K2; also
            # the schedule the Pallas kernel's "compare" path uses).
            eq = (q[:, :, None] == b_nav[:, None, :]) & b_alive[:, None, :]
            member = jnp.any(eq, axis=2)
            pos_c = jnp.argmax(eq, axis=2).astype(jnp.int32)
        else:
            member, pos = sorted_window_member(q, b_nav)
            pos_c = jnp.minimum(pos, w - 1)
            member &= jnp.take_along_axis(b_alive, pos_c, axis=1, mode="clip")
        ones = member.astype(jnp.int32)

        # u1: the task's own edge accumulates the intersection size.
        s_acc = s_acc.at[t].add(jnp.sum(ones, axis=1) * valid_t.astype(jnp.int32))
        # u2: matched suffix entries (edges (i, m)) — scatter to row i slots.
        u2_tgt = jnp.where(member, a_idx_c, nnzp)
        s_acc = s_acc.at[u2_tgt.reshape(-1)].add(ones.reshape(-1), mode="drop")
        # u3: matched row-κ entries (edges (κ, m)) — scatter to row κ slots.
        u3_tgt = jnp.where(member, b_start[:, None] + pos_c, nnzp)
        s_acc = s_acc.at[u3_tgt.reshape(-1)].add(ones.reshape(-1), mode="drop")
        return s_acc, None

    starts = jnp.arange(0, n_tasks, chunk, dtype=jnp.int32)
    s0 = jnp.zeros(nnzp, jnp.int32) if s_init is None else s_init
    s_final, _ = jax.lax.scan(body, s0, starts)
    return s_final


def bucket_tasks(g: CSRGraph, chunk: int = 256) -> list[tuple[int, np.ndarray]]:
    """Partition edge tasks into power-of-two window buckets.

    Task t of edge (i,κ) needs window ≥ max(deg(i)−pos−1, deg(κ)); using
    the global max degree pads every task to the heaviest one — the same
    waste the paper removes at the row level, now removed at the window
    level (the "ultra-fine-grained" direction the paper defers).  Returns
    [(window, task_ids padded to chunk multiples with nnz_pad sentinels)].
    """
    deg = g.degrees()
    rows = g.row_of_edge()
    pos = g.pos_in_row()
    need = np.maximum(deg[rows] - pos - 1, deg[g.colidx]).astype(np.int64)
    need = np.maximum(need, 1)
    bucket_of = np.maximum(8, 2 ** np.ceil(np.log2(need)).astype(np.int64))
    out = []
    for wb in sorted(set(bucket_of.tolist())):
        ids = np.nonzero(bucket_of == wb)[0].astype(np.int32)
        padded = -(-len(ids) // chunk) * chunk
        ids = np.pad(ids, (0, padded - len(ids)), constant_values=np.iinfo(np.int32).max)
        out.append((int(wb), ids))
    return out


def support_fine_bucketed(
    p: FineProblem,
    alive: jax.Array,
    buckets: list[tuple[int, jax.Array]],
    *,
    chunk: int = 256,
) -> jax.Array:
    """Fine eager support with per-bucket windows (chained accumulation)."""
    s = jnp.zeros(p.nnz_pad, jnp.int32)
    for wb, ids in buckets:
        s = support_fine_eager(
            p, alive, window=wb, chunk=min(chunk, ids.shape[0]), tasks=ids, s_init=s
        )
    return s


# ---------------------------------------------------------------------- #
# Mode "owner": collision-free symmetric reformulation (TPU-kernel form)
# ---------------------------------------------------------------------- #
def support_fine_owner(
    p: FineProblem, alive: jax.Array, *, window: int, chunk: int = 1024
) -> jax.Array:
    """Support per directed edge as |N(a) ∩ N(b)| over undirected alive rows.

    ``window`` must be ≥ max *undirected* degree.  No scatters: each output
    lane is written by exactly one task (ownership partitioning).
    """
    nnzp = p.nnz_pad
    if nnzp % chunk:
        raise ValueError(f"nnz_pad={nnzp} not a multiple of chunk={chunk}")
    w = int(window)
    unnzp = int(p.ucolidx.shape[0])
    large = jnp.int32(p.n + 2)
    offs = jnp.arange(w, dtype=jnp.int32)[None, :]

    # alive mask lifted to the undirected view (pad u2d lanes -> False).
    alive_pad = jnp.concatenate([alive, jnp.zeros((1,), alive.dtype)])
    ualive = alive_pad[jnp.minimum(p.u2d, nnzp)] & (p.ucolidx != 0)

    def row_window(v: jax.Array):
        """(C, w) undirected window of vertex v: (nav values, alive mask)."""
        start = p.urowptr[jnp.maximum(v, 1) - 1] * (v > 0)
        idx = start[:, None] + offs
        n_in = offs < p.udeg[v][:, None]
        idx_c = jnp.clip(idx, 0, unnzp - 1)
        nav = jnp.where(n_in, p.ucolidx[idx_c], large)
        return nav, n_in & ualive[idx_c]

    def body(_, chunk_start: jax.Array):
        t = chunk_start + jnp.arange(chunk, dtype=jnp.int32)
        a = p.edge_row[t]
        b = p.colidx[t]
        valid_t = (b != 0) & alive[t]

        a_nav, a_alive = row_window(a)
        b_nav, b_alive = row_window(b)
        q = jnp.where(a_alive & valid_t[:, None], a_nav, 0)
        # a_nav uses `large` for invalid lanes; queries must be 0 there.
        q = jnp.where(q >= large, 0, q)
        member, pos = sorted_window_member(q, b_nav)
        member &= jnp.take_along_axis(b_alive, jnp.minimum(pos, w - 1), axis=1, mode="clip")
        return _, jnp.sum(member.astype(jnp.int32), axis=1) * valid_t.astype(
            jnp.int32
        )

    starts = jnp.arange(0, nnzp, chunk, dtype=jnp.int32)
    _, s_chunks = jax.lax.scan(body, None, starts)
    return s_chunks.reshape(-1)


# ---------------------------------------------------------------------- #
# Batched entry point: many same-shape graphs in one device dispatch
# ---------------------------------------------------------------------- #
def support_fine_stacked(
    p: FineProblem,
    alive: jax.Array,
    *,
    window: int,
    chunk: int = 1024,
    mode: str = "eager",
) -> jax.Array:
    """``alive -> support`` over a leading batch axis.

    ``p`` is a :class:`FineProblem` whose every field carries a leading
    ``(B, ...)`` batch dimension (see ``repro.graphs.pack.stack_problems``)
    and ``alive`` is ``(B, nnzp)``.  All B graphs must share one shape
    bucket; the batch is sequenced through one compiled program via
    ``lax.map`` so a micro-batch costs one dispatch, not B.

    Returns (B, nnzp) int32 supports.
    """
    if mode == "eager":
        fn = functools.partial(support_fine_eager, window=window, chunk=chunk)
    elif mode == "owner":
        fn = functools.partial(support_fine_owner, window=window, chunk=chunk)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return jax.lax.map(lambda pa: fn(pa[0], pa[1]), (p, alive))
