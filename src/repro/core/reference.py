"""Reference K-truss implementations (oracles for tests and kernels).

Two independent oracles:

* :func:`support_dense` / :func:`ktruss_dense` — Algorithm 1 of the paper,
  the linear-algebraic form ``S = (A·A) ∘ A`` over the *symmetric* dense
  adjacency, pruned to a fixed point.  jnp, jit-able; O(n³) — small graphs.
* :func:`support_numpy` — pure-numpy set-intersection triangle counting on
  the upper-triangular CSR; structurally independent of both the dense form
  and the eager implementations (belt and braces for the test suite).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.csr import CSRGraph

__all__ = [
    "support_dense",
    "ktruss_dense",
    "support_numpy",
    "ktruss_numpy",
    "trussness_numpy",
    "kmax_numpy",
]


# ---------------------------------------------------------------------- #
# Dense linear-algebraic oracle (Algorithm 1)
# ---------------------------------------------------------------------- #
def support_dense(adj_sym: jax.Array) -> jax.Array:
    """S = (A @ A) ∘ A on a dense symmetric 0/1 adjacency (f32)."""
    return (adj_sym @ adj_sym) * adj_sym


def ktruss_dense(adj_sym: jax.Array, k: int, max_iters: int = 10_000):
    """Fixed-point prune loop of Algorithm 1 on the dense symmetric form.

    Returns (adj_final, support_final); ``adj_final`` is the K-truss.
    """

    def body(state):
        adj, _, _ = state
        s = support_dense(adj)
        mask = (s >= (k - 2)).astype(adj.dtype) * adj
        changed = jnp.any(mask != adj)
        return mask, s * mask, changed

    def cond(state):
        return state[2]

    adj = adj_sym.astype(jnp.float32)
    s0 = support_dense(adj)
    state = (adj, s0, jnp.asarray(True))
    # lax.while_loop with the (adj, support, changed) carry.
    adj, s, _ = jax.lax.while_loop(cond, body, state)
    return adj, s


# ---------------------------------------------------------------------- #
# Numpy set-intersection oracle (independent of the linear-algebraic form)
# ---------------------------------------------------------------------- #
def support_numpy(g: CSRGraph, alive: np.ndarray | None = None) -> np.ndarray:
    """Per-(upper-)edge triangle counts via sorted set intersection.

    Args:
      g: upper-triangular CSR graph.
      alive: optional (nnz,) bool mask of surviving edges.

    Returns:
      (nnz,) int64 support per nonzero (0 for dead edges).
    """
    alive = np.ones(g.nnz, bool) if alive is None else alive.astype(bool)
    # Undirected alive neighbor sets.
    rows = g.row_of_edge()
    src = np.concatenate([rows[alive], g.colidx[alive]])
    dst = np.concatenate([g.colidx[alive], rows[alive]])
    nbrs: list[np.ndarray] = [np.empty(0, np.int64)] * (g.n + 1)
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    bounds = np.searchsorted(src_s, np.arange(g.n + 2))
    for v in range(1, g.n + 1):
        nbrs[v] = np.sort(dst_s[bounds[v] : bounds[v + 1]])
    out = np.zeros(g.nnz, np.int64)
    for t in range(g.nnz):
        if not alive[t]:
            continue
        a, b = rows[t], g.colidx[t]
        out[t] = np.intersect1d(nbrs[a], nbrs[b], assume_unique=True).size
    return out


def ktruss_numpy(g: CSRGraph, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Fixed-point K-truss on the numpy oracle: returns (alive, support)."""
    alive = np.ones(g.nnz, bool)
    while True:
        s = support_numpy(g, alive)
        new_alive = alive & (s >= k - 2)
        if np.array_equal(new_alive, alive):
            return alive, s * alive
        alive = new_alive


def trussness_numpy(g: CSRGraph, k_start: int = 3) -> np.ndarray:
    """(nnz,) trussness per edge via level-by-level numpy peeling.

    Independent oracle for ``KTrussEngine.decompose()`` and the streaming
    maintenance invariant: an edge's trussness is the last k whose truss
    still contains it; edges never reaching the ``k_start``-truss keep the
    vacuous floor ``k_start - 1``.
    """
    trussness = np.full(g.nnz, max(2, k_start - 1), np.int64)
    alive = np.ones(g.nnz, bool)
    k = k_start
    while alive.any():
        while True:
            s = support_numpy(g, alive)
            new_alive = alive & (s >= k - 2)
            if np.array_equal(new_alive, alive):
                break
            alive = new_alive
        trussness[alive] = k
        k += 1
    return trussness


def kmax_numpy(g: CSRGraph, k_start: int = 3) -> int:
    """Largest k with a non-empty k-truss (0 if even k=3 is empty)."""
    kmax = 0
    k = k_start
    alive = np.ones(g.nnz, bool)
    while alive.any():
        while True:
            s = support_numpy(g, alive)
            new_alive = alive & (s >= k - 2)
            if np.array_equal(new_alive, alive):
                break
            alive = new_alive
        if alive.any():
            kmax = k
        k += 1
    return kmax
