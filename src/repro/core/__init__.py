"""Core: the paper's contribution — Eager K-truss, coarse & fine grained."""

from .eager_coarse import support_coarse_eager
from .eager_fine import (
    FineProblem,
    bucket_tasks,
    prepare_fine,
    support_fine_bucketed,
    support_fine_eager,
    support_fine_owner,
    support_fine_stacked,
)
from .reference import (
    kmax_numpy,
    ktruss_dense,
    ktruss_numpy,
    support_dense,
    support_numpy,
    trussness_numpy,
)
from .taskmap import (
    batched_searchsorted,
    row_of_task,
    segment_offsets,
    sorted_window_member,
    window_gather,
)
from .truss import KTrussEngine, KTrussResult, TrussDecomposition, make_support_fn

__all__ = [
    "support_coarse_eager",
    "FineProblem",
    "bucket_tasks",
    "prepare_fine",
    "support_fine_bucketed",
    "support_fine_eager",
    "support_fine_owner",
    "support_fine_stacked",
    "kmax_numpy",
    "ktruss_dense",
    "ktruss_numpy",
    "support_dense",
    "support_numpy",
    "trussness_numpy",
    "batched_searchsorted",
    "row_of_task",
    "segment_offsets",
    "sorted_window_member",
    "window_gather",
    "KTrussEngine",
    "KTrussResult",
    "TrussDecomposition",
    "make_support_fn",
]
