"""Flat-task index mathematics — the paper's core device, factored out.

The fine-grained Eager K-truss iterates a *flat* range ``t ∈ [0, nnz)`` and
recovers each task's row from the CSR row pointers (the Kokkos
``RangePolicy`` + implicit CSR task encoding of §III-D).  The identical index
math shows up in every load-balanced irregular dispatch:

* K-truss: task ``t`` is the t-th nonzero; its row is
  ``searchsorted(rowptr, t, 'right')``.
* MoE fine-grained dispatch: "rows" are experts, "nonzeros" are routed
  tokens; group boundaries come from a sort + the same searchsorted.
* Ragged paged-KV gathers in serving.

These helpers back ``repro.core`` (the paper's algorithm); the same
row-of-task idiom generalizes to any ragged segmented gather.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "row_of_task",
    "window_gather",
    "batched_searchsorted",
    "sorted_window_member",
    "segment_offsets",
]


def row_of_task(rowptr: jax.Array, t: jax.Array) -> jax.Array:
    """Recover the 1-based row id of flat task(s) ``t``.

    ``rowptr`` is the (n+1,) CSR row-pointer array over 1-based rows: row v
    spans ``[rowptr[v-1], rowptr[v])``.  This is the paper's flat-range to
    row mapping, vectorized as one binary search per task.
    """
    return jnp.searchsorted(rowptr, t, side="right").astype(jnp.int32)


def window_gather(
    flat: jax.Array, starts: jax.Array, width: int, fill
) -> jax.Array:
    """Gather fixed-width windows ``flat[starts[e] : starts[e]+width]``.

    Out-of-range lanes read ``fill``.  Shapes: starts (E,) -> out (E, width).
    This is the static-shape stand-in for the paper's pointer-delimited CSR
    sub-vectors: every task sees a dense, identically-shaped working set.
    """
    offs = jnp.arange(width, dtype=jnp.int32)[None, :]
    idx = starts[:, None].astype(jnp.int32) + offs
    valid = (idx >= 0) & (idx < flat.shape[0])
    vals = flat[jnp.clip(idx, 0, flat.shape[0] - 1)]
    return jnp.where(valid, vals, fill)


def batched_searchsorted(b: jax.Array, q: jax.Array) -> jax.Array:
    """Row-wise ``searchsorted(b[e], q[e], side='left')`` without vmap.

    Branchless binary search unrolled to ``ceil(log2(Wb + 1))`` steps of
    take-along-axis + compare-select — the exact schedule the Pallas kernel
    uses on TPU (VREG-friendly: no data-dependent control flow).

    Args:
      b: (E, Wb) ascending per row.
      q: (E, Wq) query values.

    Returns:
      (E, Wq) int32 insertion positions in ``[0, Wb]``.
    """
    wb = b.shape[1]
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, wb, jnp.int32)
    big = jnp.iinfo(b.dtype).max
    steps = max(1, int(np.ceil(np.log2(wb + 1))))
    for _ in range(steps):
        mid = (lo + hi) >> 1
        bm = jnp.take_along_axis(b, jnp.clip(mid, 0, wb - 1), axis=1, mode="clip")
        # Out-of-range probes (lo == hi == wb) must never move lo further.
        bm = jnp.where(mid >= wb, big, bm)
        go_right = bm < q
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    return lo


def sorted_window_member(
    a: jax.Array, b: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Membership of each a-lane in the sorted window b (per row).

    Args:
      a: (E, Wa) query values (invalid lanes must be < 1, e.g. the 0
         sentinel — vertex ids are 1-based).
      b: (E, Wb) ascending windows (invalid lanes must be a +inf-like
         sentinel strictly greater than any valid id).

    Returns:
      member: (E, Wa) bool — a[e,w] appears in b[e,:].
      pos:    (E, Wa) int32 — position of the match in b (undefined where
              ``member`` is False; callers must mask).
    """
    pos = batched_searchsorted(b, a)
    safe = jnp.minimum(pos, b.shape[1] - 1)
    hit = jnp.take_along_axis(b, safe, axis=1, mode="clip") == a
    member = hit & (a >= 1) & (pos < b.shape[1])
    return member, pos


def segment_offsets(sorted_ids: jax.Array, num_segments: int) -> jax.Array:
    """Boundaries of equal-id runs in a sorted id array.

    Returns (num_segments + 1,) offsets such that segment s spans
    ``[off[s], off[s+1])`` — the inverse of :func:`row_of_task`, used by the
    MoE fine-grained dispatch to build its "rowptr" after sorting tokens by
    expert.
    """
    return jnp.searchsorted(
        sorted_ids, jnp.arange(num_segments + 1, dtype=sorted_ids.dtype)
    ).astype(jnp.int32)
