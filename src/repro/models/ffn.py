"""Dense feed-forward layers: SwiGLU / GeGLU (gated) per LLaMA/Gemma."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense, dense_init
from .config import ModelConfig

__all__ = ["ffn_init", "ffn_apply", "act_fn"]


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[
        name
    ]


def ffn_init(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    d_ff = cfg.d_ff if d_ff is None else d_ff
    return {
        "gate": dense_init(kg, cfg.d_model, d_ff, dtype=dt),
        "up": dense_init(ku, cfg.d_model, d_ff, dtype=dt),
        "down": dense_init(kd, d_ff, cfg.d_model, dtype=dt),
    }


def ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    g = act_fn(cfg.act)(dense(p["gate"], x, dt))
    return dense(p["down"], g * dense(p["up"], x, dt), dt)
