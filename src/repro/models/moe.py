"""Mixture-of-Experts FFN with coarse vs fine dispatch — the paper's
technique applied to the framework's own irregular-parallelism hot-spot.

The mapping (DESIGN.md §3): experts are "rows", routed (token, k)
assignments are "nonzeros".

* ``dispatch="coarse"`` — GShard/Switch-style **per-expert capacity
  buckets**: every expert gets a fixed (E, C) buffer; hot experts overflow
  (dropped tokens), cold experts pad (wasted FLOPs).  This is the
  row-granularity decomposition of Algorithm 2.
* ``dispatch="fine"``  — the paper's flat task space: (token, k) pairs are
  sorted by expert into **one flat buffer** whose group boundaries are
  recovered with the same ``searchsorted`` index math as the K-truss flat
  range (``repro.core.taskmap``); grouped GEMM via ``lax.ragged_dot``.
  Dropless on a single shard (buffer == T·K); per-shard transport buckets
  in the EP-sharded path are bounded by ``buffer_factor`` with overflow
  accounting.

Both modes share the router and the expert parameters, so the benchmark
(benchmarks/moe_dispatch.py) isolates exactly the decomposition — the same
variable the paper isolates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.taskmap import segment_offsets
from ..distributed.context import current_shard_ctx
from .common import dense_init
from .config import ModelConfig, MoEConfig
from .ffn import act_fn, ffn_apply, ffn_init

__all__ = ["moe_init", "moe_apply", "router_topk"]


def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.moe
    assert m is not None
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    std = d**-0.5
    p = {
        "router": dense_init(kr, d, e, dtype=dt),
        "gate": jax.random.truncated_normal(kg, -2, 2, (e, d, f), dt) * std,
        "up": jax.random.truncated_normal(ku, -2, 2, (e, d, f), dt) * std,
        "down": jax.random.truncated_normal(kd, -2, 2, (e, f, d), dt) * (f**-0.5),
    }
    if m.num_shared_experts:
        p["shared"] = ffn_init(ks, cfg, d_ff=f * m.num_shared_experts)
    return p


def router_topk(p: dict, x2d: jax.Array, m: MoEConfig):
    """Route tokens: returns (weights (T,K) f32, ids (T,K) i32, aux dict)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux + router z-loss.
    e = m.num_experts
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0
    )
    p_e = jnp.mean(probs, axis=0)
    aux = {
        "moe_aux_loss": e * jnp.sum(f_e * p_e) * m.aux_loss_coef,
        "moe_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        * m.router_z_loss,
        "expert_load": f_e,
    }
    return weights, ids.astype(jnp.int32), aux


def _expert_ffn_batched(p: dict, buf: jax.Array, act: str, dt) -> jax.Array:
    """(E, C, D) -> (E, C, D) batched per-expert gated FFN."""
    g = act_fn(act)(jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", g * u, p["down"].astype(dt))


def _expert_ffn_ragged(p: dict, xs: jax.Array, group_sizes: jax.Array, act, dt):
    """(M, D) sorted-by-expert -> (M, D) via grouped (ragged) GEMM."""
    g = act_fn(act)(jax.lax.ragged_dot(xs, p["gate"].astype(dt), group_sizes))
    u = jax.lax.ragged_dot(xs, p["up"].astype(dt), group_sizes)
    return jax.lax.ragged_dot(g * u, p["down"].astype(dt), group_sizes)


def tile_aligned_offsets(loc_e: jax.Array, el: int, tile: int, cap: int):
    """Tile-aligned destination slot for each sorted assignment.

    MegaBlocks-style: expert e's tokens start at a tile-aligned offset
    ``off[e] = Σ_{e'<e} ceil(count[e'] / tile) · tile``, so every ``tile``-
    row block of the buffer belongs to exactly ONE expert and the grouped
    GEMM becomes a scan of dense (tile, D) @ (D, F) matmuls — the paper's
    uniform-tiles-over-a-flat-task-space device, applied to experts.

    Args:
      loc_e: (M,) sorted local expert ids (el = invalid tail).
      Returns (slots (M,), tile_expert (cap//tile,), fits_mask (M,)).
    """
    counts = jnp.bincount(jnp.minimum(loc_e, el), length=el + 1)[:el]
    padded = ((counts + tile - 1) // tile) * tile
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(padded)]).astype(
        jnp.int32
    )
    pos_in_e = jnp.arange(loc_e.shape[0], dtype=jnp.int32) - jnp.searchsorted(
        loc_e, loc_e, side="left"
    ).astype(jnp.int32)
    slots = offs[jnp.minimum(loc_e, el - 1)] + pos_in_e
    valid = loc_e < el
    slots = jnp.where(valid & (slots < cap), slots, cap)  # overflow -> drop
    # Which expert owns each tile: first offset table lookup per tile start.
    tile_starts = jnp.arange(cap // tile, dtype=jnp.int32) * tile
    tile_expert = (
        jnp.searchsorted(offs, tile_starts, side="right").astype(jnp.int32) - 1
    )
    tile_expert = jnp.clip(tile_expert, 0, el - 1)
    return slots, tile_expert, valid & (slots < cap)


def _expert_ffn_tiled(
    wg: jax.Array,  # (El, D, F)
    wu: jax.Array,
    wd: jax.Array,  # (El, F, D)
    buf: jax.Array,  # (cap, D) tile-aligned sorted tokens
    tile_expert: jax.Array,  # (cap//tile,)
    act: str,
    dt,
    tile: int,
):
    """Dense (tile, D) @ per-tile expert weights, scanned over tiles.

    Replaces ``lax.ragged_dot`` in the sharded path: XLA's ragged_dot
    lowering materializes a dense (groups × M × D) select (28 GB/device on
    the kimi prefill dry-run — EXPERIMENTS §Perf); the tile scan keeps the
    working set at one expert's weights + one (tile, F) activation block.
    """
    a = act_fn(act)
    cap = buf.shape[0]
    bt = buf.reshape(cap // tile, tile, buf.shape[1])

    def body(_, inp):
        xb, e = inp
        g = a(jnp.einsum("td,df->tf", xb, wg[e].astype(dt)))
        u = jnp.einsum("td,df->tf", xb, wu[e].astype(dt))
        return _, jnp.einsum("tf,fd->td", g * u, wd[e].astype(dt))

    _, out = jax.lax.scan(body, None, (bt, tile_expert))
    return out.reshape(cap, buf.shape[1])


def moe_apply(
    p: dict, x2d: jax.Array, cfg: ModelConfig, *, buffer_cap: int | None = None
) -> tuple[jax.Array, dict]:
    """MoE FFN on flattened tokens (T, D). Returns (y, aux metrics).

    Dispatches to the shard_map expert-parallel path when a sharding
    context with a model axis is active (launch/dry-run), else runs the
    single-shard math below.  ``buffer_cap`` optionally bounds the fine
    path's flat buffer; default T·K = dropless.
    """
    m = cfg.moe
    assert m is not None
    ctx = current_shard_ctx()
    if (
        ctx is not None
        and ctx.model_size > 1
        and m.num_experts % ctx.model_size == 0
    ):
        return _moe_apply_sharded(p, x2d, cfg, ctx)
    dt = jnp.dtype(cfg.dtype)
    t, d = x2d.shape
    k = m.top_k
    e = m.num_experts

    weights, ids, aux = router_topk(p, x2d, m)
    flat_e = ids.reshape(-1)  # (T·K,)
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    if m.dispatch == "coarse":
        cap = int(max(1, round(t * k / e * m.capacity_factor)))
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T·K, E)
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos = jnp.sum(pos * onehot, axis=1)  # position within expert
        keep = pos < cap
        buf = jnp.zeros((e, cap, d), dt)
        be = jnp.where(keep, flat_e, e)  # drop -> out-of-range row
        buf = buf.at[be, jnp.minimum(pos, cap - 1)].add(
            x2d[flat_t].astype(dt) * keep[:, None], mode="drop"
        )
        out_buf = _expert_ffn_batched(p, buf, cfg.act, dt)
        y = jnp.zeros((t, d), jnp.float32)
        contrib = out_buf[be, jnp.minimum(pos, cap - 1)].astype(jnp.float32)
        y = y.at[flat_t].add(
            contrib * (flat_w * keep)[:, None], mode="drop"
        )
        aux["moe_drop_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
        aux["moe_pad_frac"] = 1.0 - jnp.sum(keep) / (e * cap)
    elif m.dispatch == "fine":
        cap = int(t * k if buffer_cap is None else buffer_cap)
        order = jnp.argsort(flat_e)  # stable in jnp
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        keep = jnp.arange(se.shape[0]) < cap
        se_k, st_k = se[:cap], st[:cap]
        # The paper's flat-task boundary recovery (taskmap.segment_offsets).
        offs = segment_offsets(se_k, e)
        group_sizes = jnp.diff(offs)
        xs = x2d[st_k].astype(dt)
        out = _expert_ffn_ragged(p, xs, group_sizes, cfg.act, dt)
        y = jnp.zeros((t, d), jnp.float32)
        y = y.at[st_k].add(
            out.astype(jnp.float32) * (sw[:cap] * keep[:cap])[:, None],
            mode="drop",
        )
        aux["moe_drop_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
        aux["moe_pad_frac"] = jnp.float32(0.0)
    else:
        raise ValueError(f"unknown dispatch {m.dispatch!r}")

    if m.num_shared_experts:
        y = y + ffn_apply(p["shared"], x2d, cfg).astype(jnp.float32)
    return y.astype(dt), aux


# ---------------------------------------------------------------------- #
# Expert-parallel shard_map path (EP over the 'model' axis)
# ---------------------------------------------------------------------- #
def _moe_apply_sharded(p: dict, x2d: jax.Array, cfg: ModelConfig, ctx):
    """TP-style EP: experts sharded over the model axis, tokens replicated.

    Activations reach every model shard anyway under tensor parallelism, so
    expert parallelism needs **no all-to-all**: each shard routes the full
    local-token set against its E/ep local experts and the partial outputs
    psum over the model axis (DESIGN.md §7).  Expert weights arrive
    FSDP-sharded and are all-gathered *inside* the shard (ZeRO-3).

    The coarse/fine contrast survives sharding intact:
      * fine: ONE flat sorted buffer per shard, bounded by
        ``buffer_factor × fair-share``; only aggregate overflow drops.
      * coarse: per-expert capacity buckets — hot experts overflow even
        when the shard's aggregate buffer has room (the paper's imbalance).
    """
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    ep = ctx.model_size
    e = m.num_experts
    el = e // ep
    k = m.top_k
    dp = ctx.dp_axes
    fsdp = ctx.fsdp_axes
    model_ax = ctx.model_axis
    t_glob, d = x2d.shape
    dp_size = 1
    for a in dp:
        dp_size *= ctx.mesh.shape[a]
    t_loc = t_glob // dp_size
    fine = m.dispatch == "fine"
    tile = 256
    if fine:
        base = int(round(t_loc * k / ep * m.buffer_factor))
        # + one tile per local expert of alignment slack (tile_aligned_offsets)
        cap = max(tile, ((base + el * tile + tile - 1) // tile) * tile)
    else:
        cap_e = max(1, int(round(t_loc * k / e * m.capacity_factor)))

    def local_fn(x_loc, router_w, wg, wu, wd):
        # x_loc: (T_loc, D) — replicated over the model axis by in_spec.
        wg_full = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
        wu_full = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
        wd_full = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        shard = jax.lax.axis_index(model_ax)

        logits = x_loc.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, ids = jax.lax.top_k(probs, k)
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
        flat_e = ids.reshape(-1).astype(jnp.int32)
        flat_w = weights.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
        is_local = (flat_e >= shard * el) & (flat_e < (shard + 1) * el)

        if fine:
            # Paper's flat task space: sort (token,k) pairs into ONE shared
            # buffer with tile-aligned expert boundaries, then scan dense
            # (tile, D) GEMMs — uniform tiles over the flat task range.
            sort_key = jnp.where(is_local, flat_e, e)
            order = jnp.argsort(sort_key)
            se = sort_key[order]
            st = flat_t[order]
            sw = flat_w[order]
            loc_e = jnp.where(se < e, se - shard * el, el)
            slots, tile_expert, keep = tile_aligned_offsets(loc_e, el, tile, cap)
            # slots[r] >= r (tile padding only pushes slots forward), so
            # every kept assignment lives in the first ``cap`` sorted rows
            # — gather/scatter only that prefix.  Gathering all T·K rows
            # cost 2 × 7.5 GB fp32 on the kimi prefill dry-run (§Perf).
            ncap = min(cap, slots.shape[0])
            st_c, sw_c = st[:ncap], sw[:ncap]
            slots_c, keep_c = slots[:ncap], keep[:ncap]
            buf = jnp.zeros((cap, d), dt)
            buf = buf.at[slots_c].add(
                x_loc[st_c].astype(dt) * keep_c[:, None], mode="drop"
            )
            out_buf = _expert_ffn_tiled(
                wg_full, wu_full, wd_full, buf, tile_expert, cfg.act, dt, tile
            )
            contrib = out_buf[jnp.minimum(slots_c, cap - 1)].astype(jnp.float32)
            y = jnp.zeros((t_loc, d), jnp.float32)
            y = y.at[st_c].add(contrib * (sw_c * keep_c)[:, None], mode="drop")
            kept = jnp.sum(keep.astype(jnp.float32))
        else:
            # Baseline: per-expert capacity buckets (Alg-2 granularity).
            loc_e = jnp.where(is_local, flat_e - shard * el, el)
            onehot = jax.nn.one_hot(loc_e, el, dtype=jnp.int32)
            pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=1)
            keep = is_local & (pos < cap_e)
            be = jnp.where(keep, loc_e, el)
            pc = jnp.minimum(pos, cap_e - 1)
            buf = jnp.zeros((el, cap_e, d), dt)
            buf = buf.at[be, pc].add(
                x_loc[flat_t].astype(dt) * keep[:, None], mode="drop"
            )
            g = act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, wg_full.astype(dt)))
            u = jnp.einsum("ecd,edf->ecf", buf, wu_full.astype(dt))
            out_buf = jnp.einsum("ecf,efd->ecd", g * u, wd_full.astype(dt))
            y = jnp.zeros((t_loc, d), jnp.float32)
            contrib = out_buf[be, pc].astype(jnp.float32)
            y = y.at[flat_t].add(contrib * (flat_w * keep)[:, None], mode="drop")
            kept = jnp.sum(keep.astype(jnp.float32))

        y = jax.lax.psum(y, model_ax)
        # Routing statistics (exact across the dp shards).
        assigned = jax.lax.psum(jnp.sum(is_local.astype(jnp.float32)), model_ax)
        kept = jax.lax.psum(kept, model_ax)
        n_tok = jnp.float32(t_loc * k)
        drop = 1.0 - jax.lax.pmean(kept / jnp.maximum(assigned, 1.0), dp)
        f_e = jax.lax.pmean(
            jnp.mean(jax.nn.one_hot(ids, e, dtype=jnp.float32).sum(1), axis=0),
            dp,
        )
        p_e = jax.lax.pmean(jnp.mean(probs, axis=0), dp)
        aux_loss = e * jnp.sum(f_e * p_e) * m.aux_loss_coef
        z = jax.lax.pmean(
            jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2), dp
        ) * m.router_z_loss
        del n_tok
        return y.astype(dt), aux_loss, z, drop, f_e

    in_specs = (
        P(dp, None),  # tokens
        P(None, None),  # router
        P(model_ax, fsdp, None),  # gate (E, D, F)
        P(model_ax, fsdp, None),  # up
        P(model_ax, None, fsdp),  # down (E, F, D)
    )
    out_specs = (P(dp, None), P(), P(), P(), P())
    y, aux_loss, z, drop, f_e = jax.shard_map(
        local_fn,
        mesh=ctx.mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False,
    )(
        x2d,
        p["router"]["kernel"],
        p["gate"],
        p["up"],
        p["down"],
    )
    aux = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z,
        "moe_drop_frac": drop,
        "expert_load": f_e,
        "moe_pad_frac": jnp.float32(0.0),
    }
    y = y.astype(jnp.float32)
    if m.num_shared_experts:
        y = y + ffn_apply(p["shared"], x2d, cfg).astype(jnp.float32)
    return y.astype(dt), aux
