"""Flash attention in pure JAX: online-softmax forward + chunked-recompute
custom-VJP backward.

Why a custom VJP: a naive ``lax.scan`` online softmax is memory-safe in the
*forward*, but autodiff saves each chunk's probability matrix as a scan
residual — reconstituting the full (Sq × Skv) score tensor in fp32 (the
smollm train_4k dry-run measured 64 GB/device of exactly this; EXPERIMENTS
§Perf iteration 1).  The custom backward recomputes each chunk's scores
from (q, k, lse) and accumulates dq/dk/dv chunk-by-chunk, so train-time
attention memory is O(Sq · chunk) like the forward.

Head layout: q keeps its FULL head axis (B, Sq, H, Dh) through every
einsum and K/V are expanded KV→H *per chunk* inside the loop.  A (KV, G)
split of a 'model'-sharded H axis is unrepresentable for GSPMD — it falls
back to sharding the contraction dim and every score chunk becomes a
partial-sum all-reduce (gemma2 prefill measured 21k all-reduces = 11.6 TB
per device; EXPERIMENTS §Perf iteration 8).  With H intact, head-sharded
attention is collective-free; the per-chunk KV expansion materializes only
(B, chunk, H, Dh).

Also supports: causal masks with absolute positions, sliding windows (ring
caches pass non-contiguous kv_positions), gemma2 logit soft-capping (the
backward applies the 1 − tanh² chain rule on recomputed raw scores).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention"]

_NEG = -1e30


class _Meta(NamedTuple):
    scale: float
    causal: bool
    window: int | None
    softcap: float | None
    chunk: int
    q_per_kv: int


def _chunk_kv(k, v, kv_pos, chunk):
    b, skv, kvh, dh = k.shape
    n = (skv + chunk - 1) // chunk
    pad = n * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    shape = (b, n, chunk, kvh, dh)
    return (
        jnp.moveaxis(k.reshape(shape), 1, 0),
        jnp.moveaxis(v.reshape(shape), 1, 0),
        kv_pos.reshape(n, chunk),
    )


def _expand_heads(x_i: jax.Array, g: int) -> jax.Array:
    """(B, C, KV, Dh) -> (B, C, H, Dh): repeat each kv head g times."""
    if g == 1:
        return x_i
    b, c, kvh, dh = x_i.shape
    return jnp.broadcast_to(
        x_i[:, :, :, None, :], (b, c, kvh, g, dh)
    ).reshape(b, c, kvh * g, dh)


def _scores(qf, k_i, p_i, q_pos, meta: _Meta):
    """Masked scores for one chunk (shared fwd/bwd).

    The mask is applied as a small additive (Sq, C) f32 bias, NOT a
    broadcast boolean ``where``: XLA's loop-invariant code motion hoists
    position-only masks out of the KV-chunk loop, and a broadcast pred of
    the full score shape measured 16 GB/device on the smollm train_4k
    dry-run (EXPERIMENTS §Perf).  The bias keeps the hoisted tensor at
    (chunks, Sq, C).
    """
    kh = _expand_heads(k_i, meta.q_per_kv).astype(jnp.float32)
    s = jnp.einsum("bqhd,bchd->bqhc", qf, kh)
    tanh_t = None
    if meta.softcap is not None:
        tanh_t = jnp.tanh(s / meta.softcap)
        s = meta.softcap * tanh_t
    valid = p_i[None, :] >= 0
    if meta.causal:
        valid = valid & (p_i[None, :] <= q_pos[:, None])
    if meta.window is not None:
        valid = valid & ((q_pos[:, None] - p_i[None, :]) < meta.window)
    bias = jnp.where(valid, 0.0, _NEG).astype(jnp.float32)  # (Sq, C)
    s = s + bias[None, :, None, :]
    return s, tanh_t


def _fwd_scan(q, k, v, q_pos, kv_pos, meta: _Meta):
    b, sq, h, dh = q.shape
    qf = q.astype(jnp.float32) * meta.scale
    ks, vs, ps = _chunk_kv(k, v, kv_pos, meta.chunk)

    def body(carry, inp):
        m, l, acc = carry
        k_i, v_i, p_i = inp
        s, _ = _scores(qf, k_i, p_i, q_pos, meta)
        vh = _expand_heads(v_i, meta.q_per_kv).astype(jnp.float32)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bqhc,bchd->bqhd", p, vh)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, h), _NEG)
    l0 = jnp.zeros((b, sq, h))
    acc0 = jnp.zeros((b, sq, h, dh))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (ks, vs, ps))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _flash(q, k, v, q_pos, kv_pos, meta: _Meta):
    return _fwd_scan(q, k, v, q_pos, kv_pos, meta)[0]


def _flash_fwd(q, k, v, q_pos, kv_pos, meta: _Meta):
    out, lse = _fwd_scan(q, k, v, q_pos, kv_pos, meta)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(meta: _Meta, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    g = meta.q_per_kv
    qf = q.astype(jnp.float32) * meta.scale
    do = dout.astype(jnp.float32)
    # D = rowsum(dO ∘ O): the softmax-normalization cotangent term.
    delta = jnp.sum(do * out.astype(jnp.float32), axis=-1)  # (B, Sq, H)

    ks, vs, ps = _chunk_kv(k, v, kv_pos, meta.chunk)

    def body(dq_acc, inp):
        k_i, v_i, p_i = inp
        s, tanh_t = _scores(qf, k_i, p_i, q_pos, meta)
        p = jnp.exp(s - lse[..., None])  # (B, Sq, H, C) — one chunk only
        vh = _expand_heads(v_i, g).astype(jnp.float32)
        dvh = jnp.einsum("bqhc,bqhd->bchd", p, do)
        dp = jnp.einsum("bqhd,bchd->bqhc", do, vh)
        ds = p * (dp - delta[..., None])
        if meta.softcap is not None:
            ds = ds * (1.0 - tanh_t * tanh_t)
        kh = _expand_heads(k_i, g).astype(jnp.float32)
        dq_acc = dq_acc + jnp.einsum("bqhc,bchd->bqhd", ds, kh)
        dkh = jnp.einsum("bqhc,bqhd->bchd", ds, qf)
        c = k_i.shape[1]
        # Fold the expanded-head gradients back onto the kv heads.
        dk_i = dkh.reshape(b, c, kvh, g, dh).sum(axis=3)
        dv_i = dvh.reshape(b, c, kvh, g, dh).sum(axis=3)
        return dq_acc, (dk_i, dv_i)

    dq0 = jnp.zeros((b, sq, h, dh), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (ks, vs, ps))
    dq = (dq * meta.scale).astype(q.dtype)

    def _unchunk(x):
        xx = jnp.moveaxis(x, 0, 1).reshape(b, -1, kvh, dh)
        return xx[:, :skv]

    dk = _unchunk(dks).astype(k.dtype)
    dv = _unchunk(dvs).astype(v.dtype)
    return dq, dk, dv, None, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, Dh) — full head axis (never split; see above)
    k: jax.Array,  # (B, Skv, KV, Dh)
    v: jax.Array,  # (B, Skv, KV, Dh)
    *,
    scale: float,
    causal: bool,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    window: int | None,
    softcap: float | None,
    chunk: int,
) -> jax.Array:
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    assert h % kvh == 0
    meta = _Meta(
        scale=float(scale),
        causal=bool(causal),
        window=None if window is None else int(window),
        softcap=None if softcap is None else float(softcap),
        chunk=int(min(chunk, k.shape[1])),
        q_per_kv=h // kvh,
    )
    return _flash(
        q, k, v, q_positions.astype(jnp.int32), kv_positions.astype(jnp.int32), meta
    )
