"""Model configuration dataclasses for all assigned architectures.

One frozen config fully determines parameter shapes, layer pattern, and
entry-point semantics.  ``layer_pattern`` is a repeating cycle of layer
kinds, e.g. ``("local", "global")`` for Gemma-2's alternating attention or
``("rglru", "rglru", "attn")`` for RecurrentGemma's 2:1 mix; a non-divisible
``num_layers`` keeps the leftover prefix of the cycle at the end.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["MoEConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # Paper-technique axis: "fine" = flat sorted dispatch (dropless within
    # the buffer bound, the paper's decomposition); "coarse" = per-expert
    # capacity buckets (the baseline the paper replaces).
    dispatch: str = "fine"
    capacity_factor: float = 1.25  # per-expert bucket slack (coarse)
    buffer_factor: float = 1.25  # flat-buffer slack (fine)
    # Layers l with l >= first_dense and (l - first_dense) % period == 0
    # use MoE FFN; others dense.
    first_dense: int = 0
    period: int = 1
    router_z_loss: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Attention options
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # used by "local" layers
    layer_pattern: Tuple[str, ...] = ("attn",)
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    attn_scale: float | None = None  # default 1/sqrt(head_dim)
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) input scaling
    sandwich_norm: bool = False  # gemma2 post-norms
    act: str = "silu"
    norm_eps: float = 1e-6

    # MoE
    moe: MoEConfig | None = None

    # Encoder-decoder
    encoder_layers: int = 0
    encoder_pattern: Tuple[str, ...] = ("attn",)

    # Modality frontend stub: the backbone consumes precomputed embeddings
    # for the first ``frontend_len`` positions ("audio" frames / "vision"
    # patches) — per the assignment spec, frontends are stubs.
    frontend: str | None = None
    frontend_len: int = 0

    # Recurrent blocks
    rglru_width: int = 0  # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4
    rwkv_head_dim: int = 64

    # Numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    scan_layers: bool = True
    remat: str = "full"  # none | full | selective
    attn_chunk: int = 512  # flash-attention KV chunk
    # Megatron-style sequence-parallel residual boundaries (giant models:
    # shards the layer-scan's saved activation stacks over 'model').
    seq_shard_boundary: bool = False

    # ------------------------------------------------------------------ #
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        return self.num_heads // max(self.num_kv_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Concrete kind per decoder layer (cycle repeated/truncated)."""
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def encoder_kinds(self) -> Tuple[str, ...]:
        pat = self.encoder_pattern
        return tuple(pat[i % len(pat)] for i in range(self.encoder_layers))

    def uses_moe(self, layer_idx: int) -> bool:
        m = self.moe
        if m is None:
            return False
        return layer_idx >= m.first_dense and (layer_idx - m.first_dense) % m.period == 0

    def sub_quadratic(self) -> bool:
        """True iff no layer performs unbounded full attention (long_500k)."""
        full_attn = {"attn", "global"}
        return not any(k in full_attn for k in self.layer_kinds() + self.encoder_kinds())

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
