"""Encoder-decoder model (seamless-m4t backbone: audio frontend stub).

The encoder consumes precomputed frame embeddings (B, S_src, d_model) —
the modality frontend is a stub per the assignment spec — through a
bidirectional attention stack.  The decoder is a causal stack whose blocks
carry an extra cross-attention sublayer over the encoder output.

Serve path: the encoder runs once at prefill; the encoder output rides in
``states['enc_out']`` and is re-projected by each decode step's
cross-attention (K/V recompute; caching cross-K/V is a recorded
optimization opportunity in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .blocks import stack_apply, stack_init, stack_init_states
from .common import dense, dense_init, embed_init, rmsnorm, rmsnorm_init
from .config import ModelConfig

__all__ = ["encdec_init", "encdec_apply", "encdec_encode", "encdec_init_states"]


def encdec_init(cfg: ModelConfig, key: jax.Array) -> dict:
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dt),
        "encoder": stack_init(kenc, cfg, cfg.encoder_kinds(), cross=False),
        "enc_norm": rmsnorm_init(cfg.d_model, dt),
        "decoder": stack_init(kdec, cfg, cfg.layer_kinds(), cross=True),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(kh, cfg.d_model, cfg.vocab_size, dtype=dt)
    return p


def encdec_init_states(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dec = stack_init_states(
        cfg, cfg.layer_kinds(), batch, max_len, jnp.dtype(cfg.dtype)
    )
    return {
        "decoder": dec,
        "enc_out": jnp.zeros(
            (batch, cfg.frontend_len, cfg.d_model), jnp.dtype(cfg.dtype)
        ),
    }


def encdec_encode(params: dict, cfg: ModelConfig, src_embeds: jax.Array) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings."""
    positions = jnp.arange(src_embeds.shape[1], dtype=jnp.int32)
    x, _, _ = stack_apply(
        params["encoder"],
        src_embeds.astype(jnp.dtype(cfg.dtype)),
        cfg=cfg,
        kinds=cfg.encoder_kinds(),
        positions=positions,
        causal=False,
    )
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def encdec_apply(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, St) decoder tokens
    *,
    src_embeds: jax.Array | None = None,  # encoder input (train / prefill)
    states: dict | None = None,
    pos_offset: jax.Array | int = 0,
    return_features: bool = False,
) -> tuple[jax.Array, dict | None, dict]:
    """Returns (logits, new_states, aux).

    Train: ``src_embeds`` given, states None.  Prefill: both given —
    encoder runs, its output is stored in states.  Decode: states only.
    """
    dt = jnp.dtype(cfg.dtype)
    if src_embeds is not None:
        enc_out = encdec_encode(params, cfg, src_embeds)
    else:
        assert states is not None, "decode needs states carrying enc_out"
        enc_out = states["enc_out"]

    x = params["embed"]["embedding"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    positions = jnp.asarray(pos_offset, jnp.int32) + jnp.arange(
        x.shape[1], dtype=jnp.int32
    )

    dec_states = states["decoder"] if states is not None else None
    x, new_dec, aux = stack_apply(
        params["decoder"],
        x,
        cfg=cfg,
        kinds=cfg.layer_kinds(),
        positions=positions,
        states=dec_states,
        causal=True,
        enc_out=enc_out,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_states = None
    if states is not None:
        new_states = {"decoder": new_dec, "enc_out": enc_out}
    if return_features:
        return x, new_states, aux
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["embedding"].astype(dt))
    else:
        logits = dense(params["head"], x, dt)
    if cfg.final_logit_softcap is not None:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits.astype(jnp.float32), new_states, aux
