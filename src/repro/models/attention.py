"""Attention: RoPE, chunked (flash-style) softmax, GQA/MQA, windows, caches.

One attention implementation serves every assigned architecture:

* GQA/MQA via an explicit (kv_heads, q_per_kv) head layout.
* Online-softmax over KV chunks (``lax.scan``) so the (Sq, Skv) score matrix
  is never materialized — required for prefill_32k / train_4k to fit HBM.
* ``window`` masks relative distance (gemma2 local layers, recurrentgemma's
  bounded local attention — this is what makes those archs long_500k-legal).
* ``softcap`` = gemma2 logit soft-capping: cap·tanh(logits/cap).
* Decode uses the same kernel with Sq == 1 against a cache; sliding-window
  layers use a ring cache of ``window`` slots (absolute positions are
  reconstructed arithmetically from the write cursor — no position array).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.context import (
    constrain_batch,
    constrain_cache,
    constrain_heads,
    current_shard_ctx,
)
from .common import dense, dense_init
from .config import ModelConfig
from .flash import flash_attention

__all__ = [
    "rope",
    "chunked_attention",
    "attn_init",
    "attn_apply",
    "init_cache",
    "KVCache",
]


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Skv, KV, Dh)
    v: jax.Array,  # (B, Skv, KV, Dh)
    *,
    scale: float,
    causal: bool,
    q_positions: jax.Array,  # (Sq,) absolute positions
    kv_positions: jax.Array,  # (Skv,) absolute positions (-1 = invalid slot)
    window: int | None,
    softcap: float | None,
    chunk: int,
) -> jax.Array:
    """Flash attention (models/flash.py): online-softmax forward, chunked-
    recompute custom-VJP backward. Returns (B, Sq, H, Dh)."""
    return flash_attention(
        q,
        k,
        v,
        scale=scale,
        causal=causal,
        q_positions=q_positions,
        kv_positions=kv_positions,
        window=window,
        softcap=softcap,
        chunk=chunk,
    )


# ---------------------------------------------------------------------- #
# GQA attention layer
# ---------------------------------------------------------------------- #
def attn_init(key: jax.Array, cfg: ModelConfig, *, cross: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "q": dense_init(kq, d, (h, dh), bias=cfg.qkv_bias, dtype=dt),
        "k": dense_init(kk, d, (kvh, dh), bias=cfg.qkv_bias, dtype=dt),
        "v": dense_init(kv, d, (kvh, dh), bias=cfg.qkv_bias, dtype=dt),
        "o": dense_init(ko, h * dh, d, dtype=dt),
    }


class KVCache(dict):
    """Per-layer cache: {'k': (B, Sc, KV, Dh), 'v': ..., 'pos': scalar}."""


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, window: int | None, dtype
) -> dict:
    sc = max_len if window is None else min(window, max_len)
    kvh, dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, sc, kvh, dh), dtype),
        "v": jnp.zeros((batch, sc, kvh, dh), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def _cache_positions(pos: jax.Array, s_cache: int, ring: bool) -> jax.Array:
    """Absolute position held by each cache slot (-1 if not yet written)."""
    slots = jnp.arange(s_cache, dtype=jnp.int32)
    if not ring:
        return jnp.where(slots < pos, slots, -1)
    # Ring: slot s holds the largest p < pos with p ≡ s (mod s_cache).
    p = pos - 1 - ((pos - 1 - slots) % s_cache)
    return jnp.where((p >= 0) & (pos > 0), p, -1)


def attn_apply(
    p: dict,
    x: jax.Array,  # (B, Sq, D)
    *,
    cfg: ModelConfig,
    positions: jax.Array,  # (Sq,) absolute positions of x
    window: int | None,
    causal: bool = True,
    use_rope: bool = True,
    cache: dict | None = None,
    kv_x: jax.Array | None = None,  # cross-attention memory (B, Skv, D)
) -> tuple[jax.Array, dict | None]:
    """GQA attention; optionally reads/updates a decode cache.

    Modes:
      * self-attention, no cache: k/v from x (train / encoder).
      * self-attention + cache: append x's k/v at ``cache['pos']`` (ring
        for windowed layers), attend over the cache (prefill & decode).
      * cross-attention (kv_x given): k/v from kv_x, no cache, no causal.
    """
    dt = jnp.dtype(cfg.dtype)
    b, sq, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    scale = cfg.attn_scale if cfg.attn_scale is not None else dh**-0.5

    q = constrain_batch(dense(p["q"], x, dt))  # (B, Sq, H, Dh)
    src = x if kv_x is None else kv_x
    k = constrain_batch(dense(p["k"], src, dt))
    v = constrain_batch(dense(p["v"], src, dt))

    if use_rope and kv_x is None:
        # Re-pin after RoPE: the KV-cache write's sharding (e.g. a
        # dh-sharded cache when kv-heads don't divide the axis) otherwise
        # back-propagates through rope into the score einsum's contraction
        # dim — 7.7k score all-reduces = 4.1 TB on smollm prefill
        # (EXPERIMENTS §Perf iterations 12-13).  Heads stay model-sharded
        # when divisible; head_dim never.
        q = constrain_heads(rope(q, positions, cfg.rope_theta))
        k = constrain_heads(rope(k, positions, cfg.rope_theta))

    new_cache = None
    if kv_x is not None:
        kv_pos = jnp.arange(src.shape[1], dtype=jnp.int32)
        causal, window = False, None
    elif cache is None:
        kv_pos = positions.astype(jnp.int32)
    else:
        sc = cache["k"].shape[1]
        ring = window is not None and sc == window
        pos0 = cache["pos"]
        new_pos = pos0 + sq
        if sq == 1:
            # Decode: append to the cache, attend over the cache.
            slot = (positions.astype(jnp.int32) % sc) if ring else positions
            ck = constrain_cache(
                cache["k"].at[:, slot].set(k.astype(cache["k"].dtype))
            )
            cv = constrain_cache(
                cache["v"].at[:, slot].set(v.astype(cache["v"].dtype))
            )
            new_cache = {"k": ck, "v": cv, "pos": new_pos}
            kv_pos = _cache_positions(new_pos, sc, ring)
            k, v = ck, cv
        else:
            # Prefill: attend over the prompt's own K/V (early queries need
            # positions a ring would have already evicted) and persist only
            # the last ``sc`` entries — writing all S positions into an
            # S > window ring would hit duplicate slots (undefined order).
            kv_pos = positions.astype(jnp.int32)
            tail = min(sq, sc)
            kk = k[:, -tail:]
            vv = v[:, -tail:]
            pp = positions[-tail:].astype(jnp.int32)
            slot = (pp % sc) if ring else pp
            ck = constrain_cache(
                cache["k"].at[:, slot].set(kk.astype(cache["k"].dtype))
            )
            cv = constrain_cache(
                cache["v"].at[:, slot].set(vv.astype(cache["v"].dtype))
            )
            new_cache = {"k": ck, "v": cv, "pos": new_pos}

    # Bound the per-chunk f32 score tensor (B_local·Sq·H·C·4B) to ~512 MB
    # per device: at 32k prefill × 64 heads a fixed 512-wide chunk costs
    # 4.3 GB/chunk.  Trace-time shapes are global; divide by the DP degree.
    ctx = current_shard_ctx()
    dp_size = 1
    if ctx is not None:
        for a in ctx.dp_axes:
            dp_size *= ctx.mesh.shape[a]
    b_loc = max(1, b // dp_size)
    budget = 1 << 29
    per_c = max(1, b_loc * sq * h * 4)
    chunk = max(128, min(cfg.attn_chunk, budget // per_c))
    out = chunked_attention(
        q,
        k,
        v,
        scale=scale,
        causal=causal,
        q_positions=positions.astype(jnp.int32),
        kv_positions=kv_pos,
        window=window,
        softcap=cfg.attn_logit_softcap,
        chunk=chunk,
    )
    # Pin the attention output before the o-projection: the o-kernel's
    # 'model' (row-parallel) sharding otherwise propagates backward through
    # the reshape into the flash scan when H doesn't divide the axis but
    # H·Dh does (smollm's 15×64=960: 7,776 score all-reduces = 4.1 TB;
    # EXPERIMENTS §Perf iteration 12).
    out = constrain_batch(out.reshape(b, sq, h * dh))
    return dense(p["o"], out, dt), new_cache
