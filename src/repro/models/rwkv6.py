"""RWKV-6 "Finch" block: data-dependent decay linear attention (attn-free).

Time mixing (per head, head state S ∈ ℝ^{dh×dh}):
    w_t = exp(−exp(w0 + lora_w(x̃_w)))       # data-dependent decay (the
                                              # defining RWKV-6 feature)
    S_t = diag(w_t) S_{t−1} + k_t v_tᵀ
    o_t = r_tᵀ (S_{t−1} + diag(u) k_t v_tᵀ)   # u = per-channel bonus
followed by per-head group-norm and a SiLU output gate.  Token shift uses
the RWKV-6 dynamic lerp: x̃_* = x + (x_prev − x) ⊙ (μ_* + lora_*(x)).

Channel mixing: k = relu(W_k x̃_k)², out = σ(W_r x̃_r) ⊙ (W_v k).

Train/prefill runs a ``lax.scan`` over time (state is O(H·dh²) per
sequence); decode is a single state update — O(1) per token, which is what
makes rwkv6 long_500k-legal.  Norms are RMSNorm (framework-uniform; noted
as a simplification vs upstream LayerNorm in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense, dense_init, rmsnorm, rmsnorm_init
from .config import ModelConfig

__all__ = [
    "rwkv_time_init",
    "rwkv_time_apply",
    "rwkv_channel_init",
    "rwkv_channel_apply",
    "rwkv_init_state",
]

_MIX_KEYS = ("r", "k", "v", "w", "g")
_LORA = 32


def rwkv_time_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    assert d % hd == 0
    keys = jax.random.split(key, 12)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "mu": {m: jnp.full((d,), 0.5, dt) for m in _MIX_KEYS},
        "lora_down": dense_init(keys[0], d, _LORA * len(_MIX_KEYS), dtype=dt),
        "lora_up": jax.random.normal(keys[1], (len(_MIX_KEYS), _LORA, d), dt) * 0.01,
        "w0": jnp.full((d,), -2.0, dt),
        "wlora_down": dense_init(keys[2], d, 64, dtype=dt),
        "wlora_up": jax.random.normal(keys[3], (64, d), dt) * 0.01,
        "u": jnp.zeros((d,), dt),
        "r": dense_init(keys[4], d, d, dtype=dt),
        "k": dense_init(keys[5], d, d, dtype=dt),
        "v": dense_init(keys[6], d, d, dtype=dt),
        "g": dense_init(keys[7], d, d, dtype=dt),
        "o": dense_init(keys[8], d, d, dtype=dt),
        "ln_x": rmsnorm_init(d, dt),
    }
    return p


def rwkv_channel_init(key: jax.Array, cfg: ModelConfig) -> dict:
    kk, kr, kv = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "k": dense_init(kk, d, cfg.d_ff, dtype=dt),
        "r": dense_init(kr, d, d, dtype=dt),
        "v": dense_init(kv, cfg.d_ff, d, dtype=dt),
    }


def rwkv_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return {
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "x_prev_t": jnp.zeros((batch, d), dtype),  # time-mix token shift
        "x_prev_c": jnp.zeros((batch, d), dtype),  # channel-mix token shift
    }


def _token_shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """(B, S, D) -> previous-token stream, seeded by carried x_prev."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def rwkv_time_apply(
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    cfg: ModelConfig,
    state: dict | None = None,
) -> tuple[jax.Array, dict]:
    dt = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd

    x_prev = (
        jnp.zeros((b, d), x.dtype) if state is None else state["x_prev_t"]
    )
    xp = _token_shift(x, x_prev)
    delta = xp - x

    # Dynamic lerp: μ_* + lora_*(x) per mix stream.
    lo = jnp.tanh(dense(p["lora_down"], x, dt)).reshape(b, s, len(_MIX_KEYS), _LORA)
    mixed = {}
    for idx, m in enumerate(_MIX_KEYS):
        dyn = jnp.einsum("bsl,ld->bsd", lo[:, :, idx], p["lora_up"][idx].astype(dt))
        mixed[m] = x + delta * (p["mu"][m].astype(dt) + dyn)

    r = dense(p["r"], mixed["r"], dt).reshape(b, s, h, hd)
    k = dense(p["k"], mixed["k"], dt).reshape(b, s, h, hd)
    v = dense(p["v"], mixed["v"], dt).reshape(b, s, h, hd)
    g = dense(p["g"], mixed["g"], dt)

    # Data-dependent decay w_t ∈ (0, 1).
    wl = jnp.tanh(dense(p["wlora_down"], mixed["w"], jnp.float32))
    w_log = p["w0"].astype(jnp.float32) + wl @ p["wlora_up"].astype(jnp.float32)
    w_t = jnp.exp(-jnp.exp(w_log)).reshape(b, s, h, hd)  # decay per channel
    u = p["u"].astype(jnp.float32).reshape(h, hd)

    s0 = (
        jnp.zeros((b, h, hd, hd), jnp.float32)
        if state is None
        else state["s"]
    )

    def step(carry, inp):
        s_prev = carry  # (B, H, hd, hd)
        r_t, k_t, v_t, w_tt = inp  # each (B, H, hd)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, hd, hd)
        out = jnp.einsum(
            "bhk,bhkv->bhv", r_t, s_prev + u[None, :, :, None] * kv
        )
        s_new = w_tt[..., :, None] * s_prev + kv
        return s_new, out

    rs = jnp.moveaxis(r.astype(jnp.float32), 1, 0)  # (S, B, H, hd)
    ks = jnp.moveaxis(k.astype(jnp.float32), 1, 0)
    vs = jnp.moveaxis(v.astype(jnp.float32), 1, 0)
    ws = jnp.moveaxis(w_t, 1, 0)
    s_last, outs = jax.lax.scan(step, s0, (rs, ks, vs, ws))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)  # (B, S, D)

    out = rmsnorm(p["ln_x"], out.astype(dt), cfg.norm_eps)
    out = out * jax.nn.silu(g)
    y = dense(p["o"], out, dt)

    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["s"] = s_last
        new_state["x_prev_t"] = x[:, -1, :]
    return y, new_state


def rwkv_channel_apply(
    p: dict,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    state: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    dt = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    x_prev = (
        jnp.zeros((b, d), x.dtype) if state is None else state["x_prev_c"]
    )
    xp = _token_shift(x, x_prev)
    delta = xp - x
    xk = x + delta * p["mu_k"].astype(dt)
    xr = x + delta * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(dense(p["k"], xk, dt)))
    y = jax.nn.sigmoid(dense(p["r"], xr, dt)) * dense(p["v"], k, dt)
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["x_prev_c"] = x[:, -1, :]
    return y, new_state
