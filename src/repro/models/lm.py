"""Decoder-only language model (covers dense / moe / hybrid / ssm / vlm).

Entry points used by train/serve/launch:

  * ``lm_init(cfg, key)`` — parameter pytree.
  * ``lm_apply(params, cfg, tokens, embeds=…, states=…, pos_offset=…)`` —
    one function for train (states=None, full sequence), prefill (states
    threaded, full prompt) and decode (states threaded, S == 1).

Multimodal ([vlm]/[audio] decoder-only) archs pass ``embeds``: precomputed
frontend embeddings occupying the first positions of the stream (the
assignment spec mandates stub frontends); loss/logits are produced for the
token positions only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.context import constrain_batch
from .blocks import stack_apply, stack_init, stack_init_states
from .common import embed_init, rmsnorm, rmsnorm_init, dense_init, dense
from .config import ModelConfig

__all__ = ["lm_init", "lm_apply", "lm_init_states"]


def lm_init(cfg: ModelConfig, key: jax.Array) -> dict:
    ke, ks, kh = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dt),
        "stack": stack_init(ks, cfg, cfg.layer_kinds()),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(kh, cfg.d_model, cfg.vocab_size, dtype=dt)
    return p


def lm_init_states(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return stack_init_states(
        cfg, cfg.layer_kinds(), batch, max_len, jnp.dtype(cfg.dtype)
    )


def lm_apply(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, St) int32
    *,
    embeds: jax.Array | None = None,  # (B, F, d_model) frontend prefix
    states: dict | None = None,
    pos_offset: jax.Array | int = 0,
    return_features: bool = False,
) -> tuple[jax.Array, dict | None, dict]:
    """Returns (logits (B, S_total, V), new_states, aux).

    ``return_features=True`` skips the unembedding and returns the final-
    norm features instead (the fused-CE training path, fused_loss.py).
    """
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"]["embedding"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(dt), x], axis=1)
    x = constrain_batch(x)
    s_total = x.shape[1]
    positions = jnp.asarray(pos_offset, jnp.int32) + jnp.arange(
        s_total, dtype=jnp.int32
    )

    x, new_states, aux = stack_apply(
        params["stack"],
        x,
        cfg=cfg,
        kinds=cfg.layer_kinds(),
        positions=positions,
        states=states,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_features:
        return x, new_states, aux
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"]["embedding"].astype(dt)
        )
    else:
        logits = dense(params["head"], x, dt)
    if cfg.final_logit_softcap is not None:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits.astype(jnp.float32), new_states, aux
