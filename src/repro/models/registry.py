"""Uniform model facade over decoder-only and encoder-decoder stacks.

``Model`` gives train/serve substrates and the dry-run one calling
convention regardless of family:

  * ``train_logits(params, batch)``  -> (logits aligned to labels, aux)
  * ``prefill(params, batch)``       -> (last-position logits, states)
  * ``decode(params, token, states, pos)`` -> (logits, states)

Batch layouts per family (all int32 tokens; embeds are stub-frontend
outputs per the assignment spec):

  dense/moe/hybrid/ssm : {tokens (B,S), labels (B,S)}
  vlm                  : {embeds (B,F,D), tokens (B,St), labels (B,St)}
  audio (enc-dec)      : {src_embeds (B,Se,D), tokens (B,St), labels (B,St)}
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .encdec import encdec_apply, encdec_init, encdec_init_states
from .lm import lm_apply, lm_init, lm_init_states

__all__ = ["Model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ #
    def init(self, key: jax.Array) -> dict:
        if self.cfg.is_encdec:
            return encdec_init(self.cfg, key)
        return lm_init(self.cfg, key)

    def init_states(self, batch: int, max_len: int) -> dict:
        if self.cfg.is_encdec:
            return encdec_init_states(self.cfg, batch, max_len)
        return lm_init_states(self.cfg, batch, max_len)

    # ------------------------------------------------------------------ #
    def train_logits(self, params: dict, batch: dict):
        cfg = self.cfg
        if cfg.is_encdec:
            logits, _, aux = encdec_apply(
                params, cfg, batch["tokens"], src_embeds=batch["src_embeds"]
            )
            return logits, aux
        if cfg.family == "vlm":
            logits, _, aux = lm_apply(
                params, cfg, batch["tokens"], embeds=batch["embeds"]
            )
            f = batch["embeds"].shape[1]
            return logits[:, f:, :], aux  # loss over text positions only
        logits, _, aux = lm_apply(params, cfg, batch["tokens"])
        return logits, aux

    def train_features(self, params: dict, batch: dict):
        """Fused-CE path: (features aligned to labels, unembed, transposed, aux).

        ``unembed`` is the (V, D) embedding when tied (transposed=True) or
        the (D, V) head kernel otherwise; the caller fuses the unembedding
        into the chunked loss (repro.train.fused_loss).
        """
        cfg = self.cfg
        if cfg.is_encdec:
            feats, _, aux = encdec_apply(
                params,
                cfg,
                batch["tokens"],
                src_embeds=batch["src_embeds"],
                return_features=True,
            )
        elif cfg.family == "vlm":
            feats, _, aux = lm_apply(
                params,
                cfg,
                batch["tokens"],
                embeds=batch["embeds"],
                return_features=True,
            )
            feats = feats[:, batch["embeds"].shape[1] :, :]
        else:
            feats, _, aux = lm_apply(
                params, cfg, batch["tokens"], return_features=True
            )
        if cfg.tie_embeddings:
            dt = jnp.dtype(cfg.dtype)
            return feats, params["embed"]["embedding"].astype(dt), True, aux
        return feats, params["head"]["kernel"].astype(jnp.dtype(cfg.dtype)), False, aux

    # ------------------------------------------------------------------ #
    def _unembed_last(self, params: dict, feats: jax.Array) -> jax.Array:
        """Logits for the final position only (prefill never materializes
        the full (B, S, V) logit tensor — at 32k × 256k vocab that would be
        orders of magnitude larger than HBM)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        last = feats[:, -1, :]
        if cfg.tie_embeddings:
            logits = last @ params["embed"]["embedding"].astype(dt).T
        else:
            logits = last @ params["head"]["kernel"].astype(dt)
        if cfg.final_logit_softcap is not None:
            c = cfg.final_logit_softcap
            logits = c * jnp.tanh(logits / c)
        return logits.astype(jnp.float32)

    def prefill(self, params: dict, batch: dict, max_len: int):
        """Run the prompt; returns (last logits (B,V), filled states)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        states = self.init_states(b, max_len)
        if cfg.is_encdec:
            feats, states, _ = encdec_apply(
                params,
                cfg,
                tokens,
                src_embeds=batch["src_embeds"],
                states=states,
                pos_offset=0,
                return_features=True,
            )
        elif cfg.family == "vlm":
            feats, states, _ = lm_apply(
                params,
                cfg,
                tokens,
                embeds=batch["embeds"],
                states=states,
                pos_offset=0,
                return_features=True,
            )
        else:
            feats, states, _ = lm_apply(
                params, cfg, tokens, states=states, pos_offset=0,
                return_features=True,
            )
        return self._unembed_last(params, feats), states

    def decode(self, params: dict, token: jax.Array, states: dict, pos):
        """One decode step: token (B, 1) at absolute position ``pos``."""
        cfg = self.cfg
        if cfg.is_encdec:
            logits, states, _ = encdec_apply(
                params, cfg, token, states=states, pos_offset=pos
            )
        else:
            logits, states, _ = lm_apply(
                params, cfg, token, states=states, pos_offset=pos
            )
        return logits[:, -1, :], states

    # ------------------------------------------------------------------ #
    def param_count(self, params: dict) -> int:
        return sum(x.size for x in jax.tree.leaves(params))

    def active_param_count(self, params: dict) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        m = self.cfg.moe
        total = self.param_count(params)
        if m is None:
            return total

        def expert_frac(path: str) -> bool:
            return any(s in path for s in ("gate", "up", "down"))

        moe_total = 0
        moe_active = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            keys = "/".join(str(k) for k in path)
            if "moe" in keys and ("'gate'" in keys or "'up'" in keys or "'down'" in keys):
                moe_total += leaf.size
                moe_active += leaf.size * m.top_k // m.num_experts
        return total - moe_total + moe_active
