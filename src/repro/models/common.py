"""Parameter/bootstrap helpers shared by all layers (no flax: pure pytrees).

Parameters are nested dicts of jnp arrays; every layer is an
``init(key, ...) -> params`` plus ``apply(params, x, ...) -> y`` pair.
Mixed precision follows the MaxText convention: params kept in
``param_dtype`` (fp32), casted to ``dtype`` (bf16) at use.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "embed_init",
    "DTYPES",
]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


def dense_init(
    key: jax.Array,
    d_in: int,
    d_out: int | tuple[int, ...],
    *,
    bias: bool = False,
    dtype=jnp.float32,
    scale: float | None = None,
) -> dict:
    """He/truncated-normal initialized dense kernel (d_in, *d_out)."""
    shape = (d_in,) + ((d_out,) if isinstance(d_out, int) else tuple(d_out))
    std = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"kernel": jax.random.truncated_normal(key, -2, 2, shape, dtype) * std}
    if bias:
        p["bias"] = jnp.zeros(shape[1:], dtype)
    return p


def dense(p: dict, x: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """x @ kernel (+ bias), contracting x's last dim with kernel dim 0."""
    k = p["kernel"].astype(dtype)
    y = jax.lax.dot_general(
        x.astype(dtype),
        k,
        (((x.ndim - 1,), (0,)), ((), ())),
    )
    if "bias" in p:
        y = y + p["bias"].astype(dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"embedding": jax.random.normal(key, (vocab, d), dtype) * 0.02}
