"""Transformer/recurrent blocks + pattern-aware scan-over-layers stacking.

A *block* = (norms, sequence mixer, FFN-or-MoE).  Mixer kinds:

  ``attn``   full causal attention          ``local``  sliding-window attn
  ``global`` full attention (gemma2 pair)   ``rglru``  Griffin recurrence
  ``rwkv``   RWKV-6 time mixing (its channel mix replaces the FFN)

Layer stacking compiles one XLA body per repeating *group* via ``lax.scan``
(weights stacked on a leading group axis — the MaxText trick that keeps
512-device compile times bounded).  Non-periodic prefixes/suffixes (e.g.
kimi's first dense layer, recurrentgemma's 38 = 12×3 + 2) run unscanned.
Per-layer decode state (KV caches / recurrent states) is threaded through
the scan as stacked xs/ys.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.context import constrain_batch, constrain_seq
from .attention import attn_apply, attn_init, init_cache
from .common import rmsnorm, rmsnorm_init
from .config import ModelConfig
from .ffn import ffn_apply, ffn_init
from .moe import moe_apply, moe_init
from .rglru import rglru_apply, rglru_init, rglru_init_state
from .rwkv6 import (
    rwkv_channel_apply,
    rwkv_channel_init,
    rwkv_init_state,
    rwkv_time_apply,
    rwkv_time_init,
)

__all__ = [
    "block_init",
    "block_apply",
    "block_init_state",
    "stack_init",
    "stack_apply",
    "stack_init_states",
    "layer_plan",
    "AUX_KEYS",
]

AUX_KEYS = ("moe_aux_loss", "moe_z_loss", "moe_drop_frac")


def _zero_aux() -> dict:
    return {k: jnp.float32(0.0) for k in AUX_KEYS}


def _add_aux(a: dict, b: dict) -> dict:
    return {k: a[k] + b[k] for k in AUX_KEYS}


# ---------------------------------------------------------------------- #
# Single block
# ---------------------------------------------------------------------- #
def block_init(
    key: jax.Array,
    cfg: ModelConfig,
    kind: str,
    use_moe: bool,
    *,
    cross: bool = False,
) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": rmsnorm_init(d, dt), "ln2": rmsnorm_init(d, dt)}
    if cfg.sandwich_norm:
        p["ln1_post"] = rmsnorm_init(d, dt)
        p["ln2_post"] = rmsnorm_init(d, dt)
    if kind in ("attn", "local", "global"):
        p["mixer"] = attn_init(k1, cfg)
    elif kind == "rglru":
        p["mixer"] = rglru_init(k1, cfg)
    elif kind == "rwkv":
        p["mixer"] = rwkv_time_init(k1, cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cross:
        p["ln_cross"] = rmsnorm_init(d, dt)
        p["cross"] = attn_init(k3, cfg, cross=True)
    if kind == "rwkv":
        p["ffn"] = rwkv_channel_init(k2, cfg)
    elif use_moe:
        p["moe"] = moe_init(k2, cfg)
    else:
        p["ffn"] = ffn_init(k2, cfg)
    return p


def block_init_state(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype
) -> dict:
    if kind in ("attn", "global"):
        return {"cache": init_cache(cfg, batch, max_len, window=None, dtype=dtype)}
    if kind == "local":
        return {
            "cache": init_cache(
                cfg, batch, max_len, window=cfg.sliding_window, dtype=dtype
            )
        }
    if kind == "rglru":
        return {"rec": rglru_init_state(cfg, batch, dtype)}
    if kind == "rwkv":
        return {"rec": rwkv_init_state(cfg, batch, dtype)}
    raise ValueError(kind)


def block_apply(
    p: dict,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    kind: str,
    positions: jax.Array,
    state: dict | None,
    causal: bool = True,
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, dict | None, dict]:
    """Returns (x, new_state, aux)."""
    aux = _zero_aux()
    new_state = dict(state) if state is not None else None

    x = constrain_batch(x)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "local", "global"):
        window = cfg.sliding_window if kind == "local" else None
        cache = state.get("cache") if state is not None else None
        h, new_cache = attn_apply(
            p["mixer"],
            h,
            cfg=cfg,
            positions=positions,
            window=window,
            causal=causal,
            cache=cache,
        )
        if new_state is not None:
            new_state["cache"] = new_cache
    elif kind == "rglru":
        h, rec = rglru_apply(
            p["mixer"], h, cfg=cfg, state=state.get("rec") if state else None
        )
        if new_state is not None:
            new_state["rec"] = rec
    elif kind == "rwkv":
        h, rec = rwkv_time_apply(
            p["mixer"], h, cfg=cfg, state=state.get("rec") if state else None
        )
        if new_state is not None:
            new_state["rec"] = rec
    if cfg.sandwich_norm:
        h = rmsnorm(p["ln1_post"], h, cfg.norm_eps)
    x = constrain_batch(x + h)

    if "cross" in p and enc_out is not None:
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        h, _ = attn_apply(
            p["cross"],
            h,
            cfg=cfg,
            positions=positions,
            window=None,
            causal=False,
            kv_x=enc_out,
        )
        x = x + h

    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "rwkv":
        h, rec2 = rwkv_channel_apply(
            p["ffn"], h, cfg=cfg, state=state.get("rec") if state else None
        )
        if new_state is not None and rec2 is not None:
            new_state["rec"] = dict(new_state["rec"], x_prev_c=rec2["x_prev_c"])
    elif "moe" in p:
        b, s, d = h.shape
        y2d, moe_aux = moe_apply(p["moe"], h.reshape(b * s, d), cfg)
        h = y2d.reshape(b, s, d)
        aux = _add_aux(
            aux, {k: moe_aux.get(k, jnp.float32(0.0)) for k in AUX_KEYS}
        )
    else:
        h = ffn_apply(p["ffn"], h, cfg)
    if cfg.sandwich_norm:
        h = rmsnorm(p["ln2_post"], h, cfg.norm_eps)
    x = constrain_batch(x + h)
    return x, new_state, aux


# ---------------------------------------------------------------------- #
# Layer plan: prefix + scanned periodic groups + suffix
# ---------------------------------------------------------------------- #
def layer_plan(cfg: ModelConfig, kinds: tuple[str, ...]) -> dict:
    """Split layer indices into (prefix, n_groups × period, suffix)."""
    n = len(kinds)
    sigs = [(kinds[i], cfg.uses_moe(i)) for i in range(n)]
    period = len(cfg.layer_pattern)
    if cfg.moe is not None:
        period = math.lcm(period, cfg.moe.period)
    none_plan = {"prefix": list(range(n)), "groups": 0, "period": period, "suffix": []}
    if not cfg.scan_layers or n < 2 * period:
        return none_plan
    start = None
    for s in range(0, min(period, n) + 1):
        body = sigs[s:]
        if all(body[i] == body[i % period] for i in range(len(body))):
            start = s
            break
    if start is None:
        return none_plan
    groups = (n - start) // period
    suffix_start = start + groups * period
    return {
        "prefix": list(range(start)),
        "groups": groups,
        "period": period,
        "group_kinds": [kinds[start + j] for j in range(period)],
        "group_moe": [cfg.uses_moe(start + j) for j in range(period)],
        "scan_start": start,
        "suffix": list(range(suffix_start, n)),
    }


def _stack(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_init(
    key: jax.Array,
    cfg: ModelConfig,
    kinds: tuple[str, ...],
    *,
    cross: bool = False,
) -> dict:
    """Init all blocks, stacking the periodic groups for lax.scan."""
    plan = layer_plan(cfg, kinds)
    n = len(kinds)
    lkeys = jax.random.split(key, max(n, 1))

    def mk(i: int) -> dict:
        return block_init(lkeys[i], cfg, kinds[i], cfg.uses_moe(i), cross=cross)

    params: dict[str, Any] = {
        "prefix": [mk(i) for i in plan["prefix"]],
        "suffix": [mk(i) for i in plan["suffix"]],
    }
    if plan["groups"]:
        per_group = [
            [mk(plan["scan_start"] + g * plan["period"] + j) for j in range(plan["period"])]
            for g in range(plan["groups"])
        ]
        params["scan"] = _stack(per_group)
    return params


def stack_init_states(
    cfg: ModelConfig, kinds: tuple[str, ...], batch: int, max_len: int, dtype
) -> dict:
    plan = layer_plan(cfg, kinds)
    states: dict[str, Any] = {
        "prefix": [
            block_init_state(cfg, kinds[i], batch, max_len, dtype)
            for i in plan["prefix"]
        ],
        "suffix": [
            block_init_state(cfg, kinds[i], batch, max_len, dtype)
            for i in plan["suffix"]
        ],
    }
    if plan["groups"]:
        per_group = [
            [
                block_init_state(cfg, plan["group_kinds"][j], batch, max_len, dtype)
                for j in range(plan["period"])
            ]
            for _ in range(plan["groups"])
        ]
        states["scan"] = _stack(per_group)
    return states


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def stack_apply(
    params: dict,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    kinds: tuple[str, ...],
    positions: jax.Array,
    states: dict | None = None,
    causal: bool = True,
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, dict | None, dict]:
    """Apply the full layer stack. Returns (x, new_states, aux-sums)."""
    plan = layer_plan(cfg, kinds)
    aux_tot = _zero_aux()
    new_states: dict[str, Any] | None = (
        {"prefix": [], "suffix": []} if states is not None else None
    )

    def run(block_p, xx, kind, st):
        return block_apply(
            block_p,
            xx,
            cfg=cfg,
            kind=kind,
            positions=positions,
            state=st,
            causal=causal,
            enc_out=enc_out,
        )

    for slot, i in enumerate(plan["prefix"]):
        st = states["prefix"][slot] if states is not None else None
        x, nst, aux = run(params["prefix"][slot], x, kinds[i], st)
        if new_states is not None:
            new_states["prefix"].append(nst)
        aux_tot = _add_aux(aux_tot, aux)

    if plan["groups"]:
        group_kinds = plan["group_kinds"]

        def group_body(xx, gp, gst):
            nst_list = []
            aux_g = _zero_aux()
            for j in range(plan["period"]):
                st = gst[j] if gst is not None else None
                xx, nst, aux = run(gp[j], xx, group_kinds[j], st)
                nst_list.append(nst)
                aux_g = _add_aux(aux_g, aux)
            if cfg.seq_shard_boundary:
                xx = constrain_seq(xx)  # SP residuals (DESIGN §7, §Perf)
            return xx, nst_list, aux_g

        body = _remat(group_body, cfg)

        if states is None:
            def scan_no_state(xx, gp):
                xx, _, aux_g = body(xx, gp, None)
                return xx, aux_g

            x, aux_s = jax.lax.scan(scan_no_state, x, params["scan"])
        else:
            def scan_with_state(xx, scanned):
                gp, gst = scanned
                xx, nst, aux_g = body(xx, gp, gst)
                return xx, (nst, aux_g)

            x, (nst, aux_s) = jax.lax.scan(
                scan_with_state, x, (params["scan"], states["scan"])
            )
            new_states["scan"] = nst
        aux_tot = _add_aux(aux_tot, {k: jnp.sum(aux_s[k]) for k in AUX_KEYS})

    for slot, i in enumerate(plan["suffix"]):
        st = states["suffix"][slot] if states is not None else None
        x, nst, aux = run(params["suffix"][slot], x, kinds[i], st)
        if new_states is not None:
            new_states["suffix"].append(nst)
        aux_tot = _add_aux(aux_tot, aux)

    return x, new_states, aux_tot
