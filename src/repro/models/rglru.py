"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block layout (Griffin §2.4): two linear branches from the residual stream;
branch 1 → causal depthwise conv1d (width 4) → RG-LRU; branch 2 → GeLU
gate; elementwise product → output projection.

RG-LRU recurrence (per channel):
    r_t = σ(W_a x_t + b_a)            # recurrence gate
    i_t = σ(W_x x_t + b_x)            # input gate
    a_t = exp(-c · softplus(Λ) · r_t) # data-dependent decay, c = 8
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill evaluates the linear recurrence with
``lax.associative_scan`` (log-depth, the TPU-native schedule); decode is a
single fused state update.  State per layer: {h, conv tail, pos} — O(d_rnn)
per sequence, which is what makes recurrentgemma long_500k-legal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense, dense_init
from .config import ModelConfig

__all__ = ["rglru_init", "rglru_apply", "rglru_init_state"]

_C = 8.0


def rglru_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d_rnn = cfg.rglru_width or cfg.d_model
    kx, kg, ka, ki, kc, ko, kl = jax.random.split(key, 7)
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    w = cfg.conv1d_width
    # Λ init so that a ∈ (0.9, 0.999) at r = 1 (Griffin appendix).
    lam = jax.random.uniform(kl, (d_rnn,), dt, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.exp(-jnp.log(lam) / (2 * _C)) - 1.0)  # softplus⁻¹
    return {
        "in_x": dense_init(kx, d, d_rnn, dtype=dt),
        "in_gate": dense_init(kg, d, d_rnn, dtype=dt),
        "gate_a": dense_init(ka, d_rnn, d_rnn, bias=True, dtype=dt),
        "gate_x": dense_init(ki, d_rnn, d_rnn, bias=True, dtype=dt),
        "conv_w": jax.random.normal(kc, (w, d_rnn), dt) * (w**-0.5),
        "conv_b": jnp.zeros((d_rnn,), dt),
        "out": dense_init(ko, d_rnn, d, dtype=dt),
        "lambda": lam,
    }


def rglru_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_rnn = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, d_rnn), dtype),
    }


def _causal_conv(p: dict, x: jax.Array, tail: jax.Array | None, dt):
    """Depthwise causal conv1d; returns (y, new_tail)."""
    w = p["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], w - 1, x.shape[-1]), x.dtype)
    xx = jnp.concatenate([tail, x], axis=1)  # (B, S + w - 1, C)
    y = sum(
        xx[:, i : i + x.shape[1], :] * p["conv_w"][i].astype(dt)
        for i in range(w)
    )
    return y + p["conv_b"].astype(dt), xx[:, -(w - 1) :, :]


def _lru_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t h_{t−1} + b_t over axis 1, given h0 (f32, log-depth)."""

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    # Fold h0 into the first step's additive term.
    b = b.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_apply(
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    cfg: ModelConfig,
    state: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    dt = jnp.dtype(cfg.dtype)
    xb = dense(p["in_x"], x, dt)  # (B, S, d_rnn)
    gate = jax.nn.gelu(dense(p["in_gate"], x, dt))

    tail = None if state is None else state["conv"]
    xc, new_tail = _causal_conv(p, xb, tail, dt)

    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(p["gate_a"], xc, jnp.float32))
    i = jax.nn.sigmoid(dense(p["gate_x"], xc, jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    h0 = (
        jnp.zeros((x.shape[0], xb.shape[-1]), jnp.float32)
        if state is None
        else state["h"]
    )
    h = _lru_scan(a, b, h0)  # (B, S, d_rnn) f32

    new_state = None
    if state is not None:
        new_state = {"h": h[:, -1], "conv": new_tail}
    y = dense(p["out"], h.astype(dt) * gate, dt)
    return y, new_state
