"""Model substrate: layers, blocks, and the 10 assigned architectures."""

from .config import ModelConfig, MoEConfig
from .registry import Model

__all__ = ["ModelConfig", "MoEConfig", "Model"]
