"""Block-diagonal packing of many graphs into one static-shape problem.

The serving layer batches same-bucket requests by placing each member graph
on its own vertex *slot* of width ``slot_n``: member ``i``'s 1-based vertex
``v`` becomes ``i * slot_n + v`` in the packed id space.  The packed
adjacency is the disjoint union, so every K-truss quantity (support,
fixed-point alive mask, trussness) of the union restricted to a member's
edge range equals the quantity computed on that member alone — components
never interact.  One device dispatch therefore serves B requests.

Shapes are fully determined by ``(slots, slot_n, slot_nnz)``: rowptr is
``(slots * slot_n + 1,)`` and colidx ``(slots * slot_nnz,)`` regardless of
which graphs occupy the slots, which is exactly what the compile cache
needs to reuse one XLA/Pallas executable across batches.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..errors import InvalidGraphError
from .csr import CSRGraph

__all__ = [
    "PackedGraph",
    "PackedProblem",
    "pack_graphs",
    "pack_problems",
    "stack_problems",
    "validate_fused_tiling",
]


@dataclasses.dataclass(frozen=True)
class PackedGraph:
    """Disjoint union of member graphs on a fixed vertex grid."""

    graph: CSRGraph
    slot_n: int
    slots: int
    # Member i's real (unpadded) edges occupy colidx[edge_ranges[i][0]:edge_ranges[i][1]].
    edge_ranges: tuple[tuple[int, int], ...]

    @property
    def num_members(self) -> int:
        return len(self.edge_ranges)


@dataclasses.dataclass(frozen=True)
class PackedProblem:
    """Member graphs lowered to device-ready block-diagonal ``FineProblem`` arrays.

    Two layouts:

    * ``"contig"``  — member edges are concatenated from lane 0 with one
      pad tail (the classic CSR prefix-sum layout).
    * ``"aligned"`` — member i's edges occupy lane block
      ``[i * slot_nnz, (i+1) * slot_nnz)`` with per-slot interior padding,
      so slot boundaries are also lane-block boundaries — what the sharded
      executor needs to place whole slots per device
      (``repro.distributed.ktruss``).
    """

    problem: "FineProblem"  # noqa: F821 - repro.core.eager_fine.FineProblem
    slot_nnz: int
    # Member i's real (unpadded) edges occupy colidx[edge_ranges[i][0]:edge_ranges[i][1]].
    edge_ranges: tuple[tuple[int, int], ...]
    slot_n: int
    slots: int
    layout: str = "contig"
    packed: PackedGraph | None = None  # union CSRGraph; contig layout only


def pack_graphs(
    graphs: list[CSRGraph] | tuple[CSRGraph, ...],
    *,
    slot_n: int | None = None,
    slots: int | None = None,
    name: str = "packed",
) -> PackedGraph:
    """Block-diagonal union of ``graphs`` on a ``slots × slot_n`` vertex grid.

    Unused slots (when ``len(graphs) < slots``) and the tail vertices of
    each slot are isolated, so padding batches to a fixed slot count keeps
    shapes — and hence compiled executables — stable.
    """
    if not graphs:
        raise ValueError("pack_graphs needs at least one graph")
    b = int(slots if slots is not None else len(graphs))
    sn = int(slot_n if slot_n is not None else max(g.n for g in graphs))
    if len(graphs) > b:
        raise ValueError(f"{len(graphs)} graphs > {b} slots")
    if any(g.n > sn for g in graphs):
        raise ValueError(f"member graph exceeds slot_n={sn}")
    if b * sn + 1 >= np.iinfo(np.int32).max:
        raise ValueError("packed vertex space overflows int32")

    counts = np.zeros(b * sn + 1, dtype=np.int64)
    col_parts: list[np.ndarray] = []
    edge_ranges: list[tuple[int, int]] = []
    at = 0
    for i, g in enumerate(graphs):
        counts[i * sn + 1 : i * sn + g.n + 1] = np.diff(g.rowptr)
        col_parts.append(g.colidx.astype(np.int64) + i * sn)
        edge_ranges.append((at, at + g.nnz))
        at += g.nnz
    colidx = (
        np.concatenate(col_parts) if col_parts else np.zeros(0, np.int64)
    ).astype(np.int32)
    union = CSRGraph(b * sn, np.cumsum(counts), colidx, name=name)
    return PackedGraph(
        graph=union, slot_n=sn, slots=b, edge_ranges=tuple(edge_ranges)
    )


def _check_member_capacity(graphs, *, slot_n: int, slot_nnz: int) -> None:
    """Per-member slot-capacity guard, shared by both layouts.

    Names the overflowing member and both capacities: a member larger than
    its aligned slot would otherwise pad into the next slot's lane region
    (corrupting slot-id thresholds and shard boundaries) — and in the
    contiguous layout a single oversized member can hide inside an
    under-full batch total.  Fail loudly instead.
    """
    for i, g in enumerate(graphs):
        if g.n > slot_n:
            raise InvalidGraphError(
                f"member {i} ({g.name!r}) has n={g.n} vertices, exceeding "
                f"its slot's capacity slot_n={slot_n}; use a bucket with "
                f"n_pad >= {g.n}",
                slot=i,
                graph=g.name,
                kind="slot_overflow",
            )
        if g.nnz > slot_nnz:
            raise InvalidGraphError(
                f"member {i} ({g.name!r}) has nnz={g.nnz} edges, exceeding "
                f"its slot's capacity slot_nnz={slot_nnz}; use a bucket "
                f"with nnz_pad >= {g.nnz}",
                slot=i,
                graph=g.name,
                kind="slot_overflow",
            )


def pack_problems(
    graphs: list[CSRGraph] | tuple[CSRGraph, ...],
    *,
    slot_n: int,
    slot_nnz: int,
    slots: int | None = None,
    chunk: int = 256,
    layout: str = "contig",
) -> PackedProblem:
    """Pack ``graphs`` into one block-diagonal ``FineProblem``.

    The packed arrays are padded to ``slots * slot_nnz`` directed nonzeros
    (and twice that undirected), so every batch drawn from the same
    ``(slot_n, slot_nnz, slots)`` bucket shares one executable.
    ``layout="aligned"`` additionally aligns each member's edge lanes to
    its own slot block (see :class:`PackedProblem`).
    """
    b = int(slots if slots is not None else len(graphs))
    if (b * slot_nnz) % chunk:
        raise ValueError(f"slots*slot_nnz={b * slot_nnz} not a multiple of chunk={chunk}")
    if layout == "aligned":
        return _pack_problems_aligned(
            graphs, slot_n=slot_n, slot_nnz=slot_nnz, slots=b, chunk=chunk
        )
    if layout != "contig":
        raise ValueError(f"unknown layout {layout!r}")
    from ..core.eager_fine import prepare_fine  # lazy: graphs stays core-free

    _check_member_capacity(graphs, slot_n=slot_n, slot_nnz=slot_nnz)
    total = sum(g.nnz for g in graphs)
    if total > b * slot_nnz:
        raise ValueError(
            f"batch nnz={total} exceeds the packed capacity "
            f"{b} slots x slot_nnz={slot_nnz} = {b * slot_nnz}"
        )
    pg = pack_graphs(graphs, slot_n=slot_n, slots=b)
    problem = prepare_fine(
        pg.graph, chunk=chunk, nnz_pad=b * slot_nnz, unnz_pad=2 * b * slot_nnz
    )
    return PackedProblem(
        problem=problem,
        slot_nnz=int(slot_nnz),
        edge_ranges=pg.edge_ranges,
        slot_n=int(slot_n),
        slots=b,
        layout="contig",
        packed=pg,
    )


def _pack_problems_aligned(
    graphs, *, slot_n: int, slot_nnz: int, slots: int, chunk: int
) -> PackedProblem:
    """Slot-aligned block-diagonal packing.

    Each member is prepared on its own ``(slot_n, slot_nnz)`` grid and the
    per-member arrays are concatenated with slot offsets, so member i's
    directed lanes are exactly ``[i * slot_nnz, (i+1) * slot_nnz)`` (and
    undirected lanes twice that).  Pad lanes sit *inside* each slot block;
    ``rowptr``/``urowptr`` store row starts (the only way the kernels read
    them — see ``FineProblem``), with row extents carried by the degree
    arrays.
    """
    import jax.numpy as jnp

    from ..core.eager_fine import FineProblem, prepare_fine

    if not graphs:
        raise ValueError("pack_problems needs at least one graph")
    if len(graphs) > slots:
        raise ValueError(f"{len(graphs)} graphs > {slots} slots")
    _check_member_capacity(graphs, slot_n=slot_n, slot_nnz=slot_nnz)
    if slot_nnz % chunk:
        raise ValueError(f"slot_nnz={slot_nnz} not a multiple of chunk={chunk}")
    if slots * slot_n + 1 >= np.iinfo(np.int32).max:
        raise ValueError("packed vertex space overflows int32")

    n_tot, nnzp, unnzp = slots * slot_n, slots * slot_nnz, 2 * slots * slot_nnz
    rowptr = np.zeros(n_tot + 1, np.int32)
    urowptr = np.zeros(n_tot + 1, np.int32)
    deg = np.zeros(n_tot + 1, np.int32)
    udeg = np.zeros(n_tot + 1, np.int32)
    colidx = np.zeros(nnzp, np.int32)
    edge_row = np.zeros(nnzp, np.int32)
    ucolidx = np.zeros(unnzp, np.int32)
    uedge_row = np.zeros(unnzp, np.int32)
    u2d = np.full(unnzp, nnzp, np.int32)
    rowptr[-1], urowptr[-1] = nnzp, unnzp
    edge_ranges: list[tuple[int, int]] = []

    for i in range(slots):
        vo, eo, uo = i * slot_n, i * slot_nnz, 2 * i * slot_nnz
        if i >= len(graphs):
            rowptr[vo : vo + slot_n] = eo
            urowptr[vo : vo + slot_n] = uo
            edge_ranges.append((eo, eo))
            continue
        g = graphs[i]
        p = prepare_fine(g, chunk=chunk, nnz_pad=slot_nnz, unnz_pad=2 * slot_nnz)
        lrp = np.asarray(p.rowptr)  # (g.n + 1,) local row starts
        lurp = np.asarray(p.urowptr)
        # rowptr[j] is the start of row j+1: rows 1..g.n take the member's
        # prefix sums; the slot's tail rows are empty at the member's end.
        rowptr[vo : vo + slot_n] = eo + lrp[np.minimum(np.arange(slot_n), g.n)]
        urowptr[vo : vo + slot_n] = uo + lurp[np.minimum(np.arange(slot_n), g.n)]
        deg[vo + 1 : vo + g.n + 1] = np.asarray(p.deg)[1:]
        udeg[vo + 1 : vo + g.n + 1] = np.asarray(p.udeg)[1:]
        lcol = np.asarray(p.colidx)
        colidx[eo : eo + slot_nnz] = np.where(lcol != 0, lcol + vo, 0)
        lrow = np.asarray(p.edge_row)
        edge_row[eo : eo + slot_nnz] = np.where(lrow != 0, lrow + vo, 0)
        lucol = np.asarray(p.ucolidx)
        ucolidx[uo : uo + 2 * slot_nnz] = np.where(lucol != 0, lucol + vo, 0)
        lurow = np.asarray(p.uedge_row)
        uedge_row[uo : uo + 2 * slot_nnz] = np.where(lurow != 0, lurow + vo, 0)
        lu2d = np.asarray(p.u2d)
        u2d[uo : uo + 2 * slot_nnz] = np.where(lu2d < slot_nnz, lu2d + eo, nnzp)
        edge_ranges.append((eo, eo + g.nnz))

    problem = FineProblem(
        rowptr=jnp.asarray(rowptr),
        colidx=jnp.asarray(colidx),
        edge_row=jnp.asarray(edge_row),
        deg=jnp.asarray(deg),
        urowptr=jnp.asarray(urowptr),
        ucolidx=jnp.asarray(ucolidx),
        u2d=jnp.asarray(u2d),
        uedge_row=jnp.asarray(uedge_row),
        udeg=jnp.asarray(udeg),
    )
    return PackedProblem(
        problem=problem,
        slot_nnz=int(slot_nnz),
        edge_ranges=tuple(edge_ranges),
        slot_n=int(slot_n),
        slots=int(slots),
        layout="aligned",
    )


def stack_problems(problems):
    """Stack same-shape ``FineProblem``s along a new leading batch axis.

    Input to the ``support_fine_stacked`` batched entry points; all members
    must come from one shape bucket (identical array shapes).
    """
    import jax
    import jax.numpy as jnp

    if not problems:
        raise ValueError("stack_problems needs at least one problem")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *problems)


def validate_fused_tiling(problem, *, slots: int, block: int) -> None:
    """Validate an aligned pack against the fused kernel's tiling.

    The fused peel megakernel (``repro.kernels.peel_fused``) walks edge
    lanes in ``block``-sized tiles it can skip when dead, and reduces
    per-slot convergence by reshaping lanes to ``(slots, slot_nnz)``.
    Both are only sound for the aligned layout's geometry: ``block`` must
    divide each slot's lane band, and every row's lanes must sit inside
    its slot's band ``[i * slot_nnz, (i+1) * slot_nnz)``.  A violation —
    a mis-sized block, or a pack whose row starts spill across a slot
    boundary — would silently mix members' edges into one tile/slot
    reduction; instead it raises the typed :class:`InvalidGraphError`
    naming the offending slot.
    """
    nnzp = int(problem.colidx.shape[0])
    if slots < 1 or nnzp % slots:
        raise InvalidGraphError(
            f"packed nnz={nnzp} does not divide into {slots} aligned slots",
            kind="fused_tiling",
        )
    slot_nnz = nnzp // slots
    if block < 1 or slot_nnz % block:
        raise InvalidGraphError(
            f"fused kernel block={block} does not divide slot_nnz="
            f"{slot_nnz}: a {block}-lane tile would straddle slot 1's "
            f"band boundary at lane {slot_nnz}; repack or clamp the "
            "config (FusedConfig.clamp)",
            slot=1 if slots > 1 else 0,
            kind="fused_tiling",
        )
    rowptr = np.asarray(problem.rowptr)
    deg = np.asarray(problem.deg)
    n_tot = rowptr.shape[0] - 1
    if n_tot % slots:
        raise InvalidGraphError(
            f"packed vertex count {n_tot} does not divide into {slots} slots",
            kind="fused_tiling",
        )
    slot_n = n_tot // slots
    v = np.arange(1, n_tot + 1)
    start = rowptr[:-1].astype(np.int64)  # rowptr[v-1] begins row v
    extent = deg[1:].astype(np.int64)
    slot_of = (v - 1) // slot_n
    lo = slot_of.astype(np.int64) * slot_nnz
    bad = (extent > 0) & ((start < lo) | (start + extent > lo + slot_nnz))
    if bad.any():
        i = int(np.argmax(bad))
        raise InvalidGraphError(
            f"slot {int(slot_of[i])}: row {int(v[i])} spans lanes "
            f"[{int(start[i])}, {int(start[i] + extent[i])}) outside its "
            f"aligned band [{int(lo[i])}, {int(lo[i] + slot_nnz)}); the "
            "fused kernel's per-slot tiles would mix members",
            slot=int(slot_of[i]),
            kind="fused_tiling",
        )
