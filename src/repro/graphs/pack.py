"""Block-diagonal packing of many graphs into one static-shape problem.

The serving layer batches same-bucket requests by placing each member graph
on its own vertex *slot* of width ``slot_n``: member ``i``'s 1-based vertex
``v`` becomes ``i * slot_n + v`` in the packed id space.  The packed
adjacency is the disjoint union, so every K-truss quantity (support,
fixed-point alive mask, trussness) of the union restricted to a member's
edge range equals the quantity computed on that member alone — components
never interact.  One device dispatch therefore serves B requests.

Shapes are fully determined by ``(slots, slot_n, slot_nnz)``: rowptr is
``(slots * slot_n + 1,)`` and colidx ``(slots * slot_nnz,)`` regardless of
which graphs occupy the slots, which is exactly what the compile cache
needs to reuse one XLA/Pallas executable across batches.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSRGraph

__all__ = ["PackedGraph", "PackedProblem", "pack_graphs", "pack_problems", "stack_problems"]


@dataclasses.dataclass(frozen=True)
class PackedGraph:
    """Disjoint union of member graphs on a fixed vertex grid."""

    graph: CSRGraph
    slot_n: int
    slots: int
    # Member i's real (unpadded) edges occupy colidx[edge_ranges[i][0]:edge_ranges[i][1]].
    edge_ranges: tuple[tuple[int, int], ...]

    @property
    def num_members(self) -> int:
        return len(self.edge_ranges)


@dataclasses.dataclass(frozen=True)
class PackedProblem:
    """A :class:`PackedGraph` lowered to device-ready ``FineProblem`` arrays."""

    problem: "FineProblem"  # noqa: F821 - repro.core.eager_fine.FineProblem
    packed: PackedGraph
    slot_nnz: int

    @property
    def edge_ranges(self) -> tuple[tuple[int, int], ...]:
        return self.packed.edge_ranges


def pack_graphs(
    graphs: list[CSRGraph] | tuple[CSRGraph, ...],
    *,
    slot_n: int | None = None,
    slots: int | None = None,
    name: str = "packed",
) -> PackedGraph:
    """Block-diagonal union of ``graphs`` on a ``slots × slot_n`` vertex grid.

    Unused slots (when ``len(graphs) < slots``) and the tail vertices of
    each slot are isolated, so padding batches to a fixed slot count keeps
    shapes — and hence compiled executables — stable.
    """
    if not graphs:
        raise ValueError("pack_graphs needs at least one graph")
    b = int(slots if slots is not None else len(graphs))
    sn = int(slot_n if slot_n is not None else max(g.n for g in graphs))
    if len(graphs) > b:
        raise ValueError(f"{len(graphs)} graphs > {b} slots")
    if any(g.n > sn for g in graphs):
        raise ValueError(f"member graph exceeds slot_n={sn}")
    if b * sn + 1 >= np.iinfo(np.int32).max:
        raise ValueError("packed vertex space overflows int32")

    counts = np.zeros(b * sn + 1, dtype=np.int64)
    col_parts: list[np.ndarray] = []
    edge_ranges: list[tuple[int, int]] = []
    at = 0
    for i, g in enumerate(graphs):
        counts[i * sn + 1 : i * sn + g.n + 1] = np.diff(g.rowptr)
        col_parts.append(g.colidx.astype(np.int64) + i * sn)
        edge_ranges.append((at, at + g.nnz))
        at += g.nnz
    colidx = (
        np.concatenate(col_parts) if col_parts else np.zeros(0, np.int64)
    ).astype(np.int32)
    union = CSRGraph(b * sn, np.cumsum(counts), colidx, name=name)
    return PackedGraph(
        graph=union, slot_n=sn, slots=b, edge_ranges=tuple(edge_ranges)
    )


def pack_problems(
    graphs: list[CSRGraph] | tuple[CSRGraph, ...],
    *,
    slot_n: int,
    slot_nnz: int,
    slots: int | None = None,
    chunk: int = 256,
) -> PackedProblem:
    """Pack ``graphs`` into one block-diagonal ``FineProblem``.

    The packed arrays are padded to ``slots * slot_nnz`` directed nonzeros
    (and twice that undirected), so every batch drawn from the same
    ``(slot_n, slot_nnz, slots)`` bucket shares one executable.
    """
    from ..core.eager_fine import prepare_fine  # lazy: graphs stays core-free

    b = int(slots if slots is not None else len(graphs))
    total = sum(g.nnz for g in graphs)
    if total > b * slot_nnz:
        raise ValueError(f"batch nnz={total} > {b} * slot_nnz={slot_nnz}")
    if (b * slot_nnz) % chunk:
        raise ValueError(f"slots*slot_nnz={b * slot_nnz} not a multiple of chunk={chunk}")
    pg = pack_graphs(graphs, slot_n=slot_n, slots=b)
    problem = prepare_fine(
        pg.graph, chunk=chunk, nnz_pad=b * slot_nnz, unnz_pad=2 * b * slot_nnz
    )
    return PackedProblem(problem=problem, packed=pg, slot_nnz=int(slot_nnz))


def stack_problems(problems):
    """Stack same-shape ``FineProblem``s along a new leading batch axis.

    Input to the ``support_fine_stacked`` batched entry points; all members
    must come from one shape bucket (identical array shapes).
    """
    import jax
    import jax.numpy as jnp

    if not problems:
        raise ValueError("stack_problems needs at least one problem")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *problems)
