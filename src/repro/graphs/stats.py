"""Load-imbalance statistics — the quantity the paper's technique fixes.

The paper's coarse-grained decomposition assigns one task per row; the work
of row ``i`` is (to first order) ``Σ_{j ∈ N⁺(i)} min window work``, i.e. it
scales with both the row length and the neighbor row lengths.  On a SIMD/MXU
machine the imbalance manifests as *padding waste*: every row is padded to
the longest row.  These statistics quantify exactly that, and the benchmark
tables report them next to the measured speedups so the mechanism — not just
the number — is visible (cf. paper §III-A).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSRGraph

__all__ = ["ImbalanceStats", "imbalance_stats", "coarse_task_work", "fine_task_work"]


def coarse_task_work(g: CSRGraph) -> np.ndarray:
    """Per-row work estimate for the coarse decomposition (Alg. 2).

    Row i's task intersects, for each j-th neighbor κ of i, the suffix
    a_i12[j+1:] with row κ.  Work(i) = Σ_{κ ∈ N⁺(i)} (deg(i) + deg(κ)),
    the standard merge-cost model for sorted intersections.
    """
    deg = g.degrees()
    rows = g.row_of_edge()
    per_edge = deg[rows] + deg[g.colidx]
    work = np.zeros(g.n + 1, dtype=np.int64)
    np.add.at(work, rows, per_edge)
    return work[1:]


def fine_task_work(g: CSRGraph) -> np.ndarray:
    """Per-edge work estimate for the fine decomposition (Alg. 3)."""
    deg = g.degrees()
    return (deg[g.row_of_edge()] + deg[g.colidx]).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class ImbalanceStats:
    name: str
    n: int
    nnz: int
    max_degree: int
    mean_degree: float
    # max/mean work ratio per decomposition: 1.0 == perfectly balanced.
    coarse_imbalance: float
    fine_imbalance: float
    # Fraction of SIMD lanes doing useful work when every task is padded to
    # the max task size (the TPU-native cost of imbalance).
    coarse_lane_efficiency: float
    fine_lane_efficiency: float
    # Parallelism available to fill a machine (task count).
    coarse_tasks: int
    fine_tasks: int

    def row(self) -> dict:
        return dataclasses.asdict(self)


def imbalance_stats(g: CSRGraph) -> ImbalanceStats:
    cw = coarse_task_work(g)
    fw = fine_task_work(g)
    cw_pos = cw[cw > 0]
    fw_pos = fw[fw > 0]

    def _imb(w: np.ndarray) -> float:
        return float(w.max() / max(w.mean(), 1e-9)) if w.size else 1.0

    def _lane_eff(w: np.ndarray) -> float:
        return float(w.mean() / max(w.max(), 1)) if w.size else 1.0

    deg = g.degrees()[1:]
    return ImbalanceStats(
        name=g.name,
        n=g.n,
        nnz=g.nnz,
        max_degree=g.max_degree(),
        mean_degree=float(deg.mean()) if g.n else 0.0,
        coarse_imbalance=_imb(cw_pos),
        fine_imbalance=_imb(fw_pos),
        coarse_lane_efficiency=_lane_eff(cw_pos),
        fine_lane_efficiency=_lane_eff(fw_pos),
        coarse_tasks=int((cw > 0).sum()),
        fine_tasks=int(fw_pos.size),
    )
