"""Synthetic graph generators calibrated to the paper's input families.

The paper benchmarks 50 SNAP graphs (GraphChallenge collection).  This
container is offline, so we generate synthetic graphs from the same degree
regimes the paper's inputs span:

* ``rmat``       — Kronecker/R-MAT power-law graphs: the soc-*/cit-*/oregon
                   regime (heavy-tailed degrees, dense triangle cores) where
                   the paper's fine-grained win is largest.
* ``barabasi``   — preferential attachment, a second heavy-tail family.
* ``erdos``      — Erdős–Rényi: near-uniform degrees (p2p-Gnutella regime,
                   modest wins in the paper).
* ``road``       — 2D lattice + shortcut diagonals: uniform tiny degrees
                   (roadNet-* regime, where the paper observes parity).
* ``clustered``  — planted-community graph with dense triangle-rich blocks
                   (email-Enron/ca-* regime; high K_max).

All return upper-triangular 1-based :class:`~repro.graphs.csr.CSRGraph`.
"""

from __future__ import annotations

import numpy as np

from .csr import CSRGraph, from_edges

__all__ = [
    "rmat",
    "barabasi",
    "erdos",
    "road",
    "clustered",
    "suite",
    "SUITE_SPECS",
]


def rmat(
    scale: int,
    edge_factor: int = 8,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> CSRGraph:
    """R-MAT graph with 2**scale vertices (Graph500 defaults)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        right = r >= ab  # bottom half for source
        r2 = rng.random(m)
        # Within chosen half, pick the column quadrant.
        col_right = np.where(
            right,
            r2 >= (c / (1.0 - ab)) if ab < 1.0 else False,
            r2 >= (a / ab),
        )
        src |= right.astype(np.int64) << bit
        dst |= col_right.astype(np.int64) << bit
    # Random vertex relabeling removes the Kronecker ordering artifact.
    perm = rng.permutation(n)
    return from_edges(n, np.stack([perm[src], perm[dst]], 1), name=f"rmat{scale}")


def barabasi(n: int, m_attach: int = 4, seed: int = 0) -> CSRGraph:
    """Barabási–Albert preferential attachment (vectorized approximation).

    Classic BA grows one vertex at a time; we use the standard repeated-node
    trick: targets are sampled from the edge-endpoint multiset so far, which
    reproduces the power-law tail without the O(n·m) python loop.
    """
    rng = np.random.default_rng(seed)
    src_list = []
    dst_list = []
    # Seed clique among the first m_attach + 1 vertices.
    seed_nodes = np.arange(m_attach + 1)
    iu, ju = np.triu_indices(m_attach + 1, k=1)
    src_list.append(seed_nodes[iu])
    dst_list.append(seed_nodes[ju])
    endpoint_pool = np.concatenate([seed_nodes[iu], seed_nodes[ju]])
    for v in range(m_attach + 1, n):
        targets = endpoint_pool[rng.integers(0, endpoint_pool.size, m_attach)]
        targets = np.unique(targets)
        src = np.full(targets.size, v, dtype=np.int64)
        src_list.append(src)
        dst_list.append(targets)
        endpoint_pool = np.concatenate([endpoint_pool, src, targets])
    edges = np.stack([np.concatenate(src_list), np.concatenate(dst_list)], 1)
    return from_edges(n, edges, name=f"ba{n}")


def erdos(n: int, avg_degree: float = 8.0, seed: int = 0) -> CSRGraph:
    """Erdős–Rényi G(n, m) with m = n * avg_degree / 2 undirected edges."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    edges = rng.integers(0, n, size=(int(m * 1.15) + 8, 2))
    return from_edges(n, edges[:m], name=f"er{n}")


def road(side: int, shortcut_frac: float = 0.05, seed: int = 0) -> CSRGraph:
    """2D grid with a few diagonal shortcuts: uniform degree ~4, few triangles.

    Mirrors the roadNet-* regime where the paper's coarse and fine versions
    tie (there is no imbalance to fix).
    """
    rng = np.random.default_rng(seed)
    n = side * side
    idx = np.arange(n).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1)
    diag = np.stack([idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()], 1)
    keep = rng.random(diag.shape[0]) < shortcut_frac
    edges = np.concatenate([right, down, diag[keep]], 0)
    return from_edges(n, edges, name=f"road{side}x{side}")


def clustered(
    n_communities: int,
    community_size: int,
    p_in: float = 0.5,
    p_out_edges: int = 2,
    seed: int = 0,
) -> CSRGraph:
    """Planted partition: dense communities (many triangles) + sparse bridges."""
    rng = np.random.default_rng(seed)
    n = n_communities * community_size
    src_list, dst_list = [], []
    iu, ju = np.triu_indices(community_size, k=1)
    for cidx in range(n_communities):
        base = cidx * community_size
        keep = rng.random(iu.size) < p_in
        src_list.append(base + iu[keep])
        dst_list.append(base + ju[keep])
    bridges = rng.integers(0, n, size=(n_communities * p_out_edges * 8, 2))
    src_list.append(bridges[:, 0])
    dst_list.append(bridges[:, 1])
    edges = np.stack([np.concatenate(src_list), np.concatenate(dst_list)], 1)
    return from_edges(n, edges, name=f"clustered{n_communities}x{community_size}")


# ---------------------------------------------------------------------- #
# Benchmark suite — spans the paper's Table I regimes at laptop scale.
# ---------------------------------------------------------------------- #
SUITE_SPECS = (
    # (name, factory)  — ordered by edge count like the paper's plots.
    ("er-small", lambda: erdos(2_000, 6.0, seed=1)),
    ("ba-small", lambda: barabasi(3_000, 4, seed=2)),
    ("clustered-small", lambda: clustered(24, 48, 0.45, seed=3)),
    ("rmat-14", lambda: rmat(14, 4, seed=4)),
    ("road-128", lambda: road(128, 0.06, seed=5)),
    ("ba-mid", lambda: barabasi(20_000, 6, seed=6)),
    ("er-mid", lambda: erdos(30_000, 8.0, seed=7)),
    ("rmat-16", lambda: rmat(16, 8, seed=8)),
    ("clustered-mid", lambda: clustered(80, 64, 0.4, seed=9)),
    ("road-512", lambda: road(512, 0.05, seed=10)),
)


def suite(names: tuple[str, ...] | None = None) -> list[CSRGraph]:
    """Materialize the benchmark suite (optionally a named subset)."""
    out = []
    for name, factory in SUITE_SPECS:
        if names is None or name in names:
            g = factory()
            out.append(CSRGraph(g.n, g.rowptr, g.colidx, name=name))
    return out
