"""CSR graph containers for the Eager K-truss framework.

Host-side construction is numpy; device-side views are JAX pytrees with
fully static shapes.

Conventions (paper-faithful, see DESIGN.md §2/§4):

* Vertices are stored **1-based** inside the CSR: vertex id ``0`` is the
  universal sentinel used for padded lanes *and* pruned edges.  This is the
  zero-terminated-CSR trick of Blanco et al. adapted to static shapes: the
  paper appends a literal ``0`` after every row so pruned/terminated entries
  need no extra bookkeeping; on TPU the same sentinel doubles as the padding
  value, so padded lanes and pruned edges are one code path.
* ``colidx`` is sorted ascending within each row (required by the sorted
  intersection in the fine-grained algorithm).
* The canonical adjacency is **upper-triangular** (``src < dst`` after the
  1-based shift), exactly as Algorithm 2/3 of the paper require.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from ..errors import InvalidGraphError

__all__ = [
    "CSRGraph",
    "DeviceCSR",
    "build_upper_csr",
    "from_edges",
    "validate_csr",
]


def validate_csr(
    n: int, rowptr: np.ndarray, colidx: np.ndarray, *, name: str = "graph"
) -> None:
    """Check the CSR invariants every algorithm downstream assumes.

    Raises :class:`repro.errors.InvalidGraphError` naming the *first*
    violating 1-based row (and the broken invariant's ``kind``) — today a
    malformed input would otherwise fail deep inside packing or the
    device peel with an opaque shape/index error that implicates the
    wrong layer.  Checked invariants:

    * ``rowptr`` has ``n + 1`` entries, starts at 0, is nondecreasing,
      and ends at ``nnz``;
    * every column id lies in ``[1, n]`` (1-based; 0 is the pad/prune
      sentinel and must never appear host-side);
    * no self-loops (``colidx[e] == row(e)``);
    * columns strictly ascend within each row (sorted, no duplicates —
      required by the sorted intersections of the fine-grained kernels).

    Symmetrized CSRs (``undirected_csr``) satisfy all of these too, so
    the check runs at every construction; upper-triangularity itself is
    a builder contract (``from_edges``), not re-checked here.
    """

    def bad(message, *, row=None, kind=None):
        raise InvalidGraphError(
            f"graph {name!r}: {message}", row=row, kind=kind, graph=name
        )

    rowptr = np.asarray(rowptr)
    colidx = np.asarray(colidx)
    nnz = int(colidx.shape[0])
    if rowptr.ndim != 1 or rowptr.shape[0] != n + 1:
        bad(
            f"rowptr must have n+1={n + 1} entries, got shape {rowptr.shape}",
            kind="rowptr_len",
        )
    if n >= 0 and rowptr.shape[0] and int(rowptr[0]) != 0:
        bad(f"rowptr[0] must be 0, got {int(rowptr[0])}", row=1, kind="rowptr_start")
    diffs = np.diff(rowptr)
    dec = np.nonzero(diffs < 0)[0]
    if dec.size:
        row = int(dec[0]) + 1
        bad(f"rowptr decreases at row {row}", row=row, kind="rowptr_unsorted")
    if int(rowptr[-1]) != nnz:
        bad(
            f"rowptr[-1]={int(rowptr[-1])} does not match nnz={nnz}",
            row=n,
            kind="rowptr_mismatch",
        )
    if not nnz:
        return

    def row_of(e: int) -> int:  # smallest v with rowptr[v] > e is e's 1-based row
        return int(np.searchsorted(rowptr, e, side="right"))

    out_of_range = np.nonzero((colidx < 1) | (colidx > n))[0]
    if out_of_range.size:
        e = int(out_of_range[0])
        bad(
            f"colidx[{e}]={int(colidx[e])} outside [1, {n}] at row {row_of(e)}",
            row=row_of(e),
            kind="col_range",
        )
    rows = np.searchsorted(rowptr, np.arange(nnz), side="right").astype(np.int64)
    loops = np.nonzero(colidx == rows)[0]
    if loops.size:
        e = int(loops[0])
        bad(
            f"self-loop ({row_of(e)}, {int(colidx[e])}) at row {row_of(e)}",
            row=row_of(e),
            kind="self_loop",
        )
    if nnz > 1:
        d = np.diff(colidx.astype(np.int64))
        same_row = rows[1:] == rows[:-1]
        unsorted = np.nonzero(same_row & (d < 0))[0]
        if unsorted.size:
            e = int(unsorted[0]) + 1
            bad(
                f"columns not ascending within row {row_of(e)} "
                f"(colidx[{e - 1}]={int(colidx[e - 1])} > colidx[{e}]={int(colidx[e])})",
                row=row_of(e),
                kind="unsorted_row",
            )
        dupes = np.nonzero(same_row & (d == 0))[0]
        if dupes.size:
            e = int(dupes[0]) + 1
            bad(
                f"duplicate column {int(colidx[e])} within row {row_of(e)}",
                row=row_of(e),
                kind="duplicate",
            )


class DeviceCSR(NamedTuple):
    """Static-shape device view of an upper-triangular CSR graph.

    All arrays are jnp/np int32.  Shapes are static so the same jitted
    K-truss executable is reused across graphs padded to the same budget.

    Attributes:
      rowptr:   (n + 1,) exclusive prefix sum of row lengths.
      colidx:   (nnz_pad,) 1-based neighbor ids, ascending per row; 0 = pad.
      edge_row: (nnz_pad,) 1-based row (source) id per nonzero; 0 = pad.
      edge_pos: (nnz_pad,) position of the nonzero within its row.
      deg:      (n + 1,) out-degree per 1-based vertex (deg[0] == 0).
    """

    rowptr: np.ndarray
    colidx: np.ndarray
    edge_row: np.ndarray
    edge_pos: np.ndarray
    deg: np.ndarray

    @property
    def n(self) -> int:
        return int(self.rowptr.shape[0] - 1)

    @property
    def nnz_pad(self) -> int:
        return int(self.colidx.shape[0])


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Host-side upper-triangular CSR graph (numpy, exact nnz).

    ``rowptr`` has length ``n + 1`` and is indexed by 1-based vertex id with
    ``rowptr[0] == rowptr[1] == 0`` only when vertex 1 has no out-neighbors;
    i.e. row ``v`` (1-based) spans ``colidx[rowptr[v - 1]:rowptr[v]]``.

    Note: to keep indexing uniform we store rowptr for the *1-based* id
    space: entry ``v`` of ``deg`` is the out-degree of vertex ``v`` and
    ``deg[0] == 0`` for the sentinel.
    """

    n: int
    rowptr: np.ndarray  # (n + 1,) int64 -> cast to int32 on device
    colidx: np.ndarray  # (nnz,) int32, 1-based, ascending per row
    name: str = "graph"
    # Construction-time invariant check (validate_csr): malformed input
    # fails HERE with a typed InvalidGraphError naming the violating row,
    # not deep inside packing with an opaque shape error.  ``False`` is
    # for tests/tools that need to materialize a known-bad graph.
    validate: dataclasses.InitVar[bool] = True

    def __post_init__(self, validate: bool):
        if validate:
            validate_csr(self.n, self.rowptr, self.colidx, name=self.name)

    # ------------------------------------------------------------------ #
    # Basic properties
    # ------------------------------------------------------------------ #
    @property
    def nnz(self) -> int:
        return int(self.colidx.shape[0])

    @property
    def num_edges(self) -> int:
        return self.nnz

    def degrees(self) -> np.ndarray:
        """Out-degree per 1-based vertex id; index 0 is the sentinel (=0)."""
        deg = np.zeros(self.n + 1, dtype=np.int64)
        deg[1:] = np.diff(self.rowptr)
        return deg

    def max_degree(self) -> int:
        return int(self.degrees().max(initial=0))

    # ------------------------------------------------------------------ #
    # Derived structures
    # ------------------------------------------------------------------ #
    def row_of_edge(self) -> np.ndarray:
        """(nnz,) 1-based source vertex of each nonzero.

        rowptr is over 1-based rows: row v spans [rowptr[v-1], rowptr[v]).
        Vectorized as: mark every row start, then a cumulative count gives
        the (1-based) row id at each nonzero.
        """
        marks = np.zeros(self.nnz + 1, dtype=np.int32)
        np.add.at(marks, self.rowptr[:-1], 1)
        return np.cumsum(marks[:-1]).astype(np.int32)  # vertex ids 1..n

    def pos_in_row(self) -> np.ndarray:
        """(nnz,) position of each nonzero within its row (0-based)."""
        rows = self.row_of_edge()
        return (np.arange(self.nnz, dtype=np.int64) - self.rowptr[rows - 1]).astype(
            np.int32
        )

    def undirected_csr(self) -> "CSRGraph":
        """Symmetrized (full) adjacency as CSR, same 1-based id space."""
        rows = self.row_of_edge()
        src = np.concatenate([rows, self.colidx])
        dst = np.concatenate([self.colidx, rows])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        rowptr = np.zeros(self.n + 1, dtype=np.int64)
        counts = np.bincount(src, minlength=self.n + 1)[1:]
        rowptr[1:] = np.cumsum(counts)
        return CSRGraph(self.n, rowptr, dst.astype(np.int32), name=self.name + "+sym")

    def padded_rows(self, width: int | None = None) -> np.ndarray:
        """(n + 1, W) matrix of neighbor ids per 1-based vertex, 0-padded.

        Row 0 (sentinel vertex) is all zeros so that gathers indexed by the
        sentinel are harmless — the paper's zero-termination generalized.
        """
        w = int(width if width is not None else self.max_degree())
        out = np.zeros((self.n + 1, w), dtype=np.int32)
        deg = self.degrees()
        for v in range(1, self.n + 1):
            d = int(deg[v])
            if d:
                out[v, :d] = self.colidx[self.rowptr[v - 1] : self.rowptr[v - 1] + d]
        return out

    # ------------------------------------------------------------------ #
    # Device view
    # ------------------------------------------------------------------ #
    def device_csr(self, nnz_pad: int | None = None) -> DeviceCSR:
        """Static-shape arrays for the JAX algorithms (0-sentinel padded)."""
        nnz_pad = int(nnz_pad if nnz_pad is not None else self.nnz)
        if nnz_pad < self.nnz:
            raise ValueError(f"nnz_pad={nnz_pad} < nnz={self.nnz}")
        pad = nnz_pad - self.nnz

        def _pad(a: np.ndarray) -> np.ndarray:
            return np.pad(a.astype(np.int32), (0, pad))

        return DeviceCSR(
            rowptr=self.rowptr.astype(np.int32),
            colidx=_pad(self.colidx),
            edge_row=_pad(self.row_of_edge()),
            edge_pos=_pad(self.pos_in_row()),
            deg=self.degrees().astype(np.int32),
        )

    def dense_upper(self) -> np.ndarray:
        """(n + 1, n + 1) dense 0/1 upper-triangular adjacency (row/col 0 empty)."""
        a = np.zeros((self.n + 1, self.n + 1), dtype=np.float32)
        a[self.row_of_edge(), self.colidx] = 1.0
        return a

    def edge_list(self) -> np.ndarray:
        """(nnz, 2) array of 1-based (src, dst) pairs, src < dst."""
        return np.stack([self.row_of_edge(), self.colidx], axis=1)


# ---------------------------------------------------------------------- #
# Builders
# ---------------------------------------------------------------------- #
def from_edges(n: int, edges: np.ndarray, name: str = "graph") -> CSRGraph:
    """Build an upper-triangular, deduplicated, sorted CSR from raw edges.

    Args:
      n: number of vertices (0-based input ids in ``[0, n)``).
      edges: (m, 2) array of undirected edges, any order/duplication; self
        loops are dropped.  Ids are shifted to 1-based internally.
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.size == 0:
        return CSRGraph(n, np.zeros(n + 1, dtype=np.int64), np.zeros(0, np.int32), name)
    u = np.minimum(edges[:, 0], edges[:, 1])
    v = np.maximum(edges[:, 0], edges[:, 1])
    keep = u != v
    u, v = u[keep] + 1, v[keep] + 1  # 1-based, u < v (upper triangular)
    key = u * (n + 1) + v
    key = np.unique(key)
    u = (key // (n + 1)).astype(np.int64)
    v = (key % (n + 1)).astype(np.int32)
    rowptr = np.zeros(n + 1, dtype=np.int64)
    counts = np.bincount(u, minlength=n + 1)[1:]
    rowptr[1:] = np.cumsum(counts)
    return CSRGraph(n, rowptr, v, name=name)


def build_upper_csr(adj_dense: np.ndarray, name: str = "graph") -> CSRGraph:
    """Build from a dense 0/1 adjacency (0-based, symmetric or triangular)."""
    adj = np.asarray(adj_dense)
    n = adj.shape[0]
    iu, ju = np.nonzero(np.triu(adj + adj.T, k=1))
    return from_edges(n, np.stack([iu, ju], axis=1), name=name)
