"""Graph substrate: CSR containers, generators, imbalance statistics."""

from .csr import CSRGraph, DeviceCSR, build_upper_csr, from_edges, validate_csr
from .generators import barabasi, clustered, erdos, rmat, road, suite, SUITE_SPECS
from .pack import (
    PackedGraph,
    PackedProblem,
    pack_graphs,
    pack_problems,
    stack_problems,
)
from .stats import ImbalanceStats, coarse_task_work, fine_task_work, imbalance_stats

__all__ = [
    "CSRGraph",
    "DeviceCSR",
    "build_upper_csr",
    "from_edges",
    "validate_csr",
    "PackedGraph",
    "PackedProblem",
    "pack_graphs",
    "pack_problems",
    "stack_problems",
    "barabasi",
    "clustered",
    "erdos",
    "rmat",
    "road",
    "suite",
    "SUITE_SPECS",
    "ImbalanceStats",
    "coarse_task_work",
    "fine_task_work",
    "imbalance_stats",
]
