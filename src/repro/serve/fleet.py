"""Fleet: replica process lifecycle + warm handoff of streaming sessions.

The :class:`Fleet` owns N replica worker processes (each running
``python -m repro.serve.replica``) and the :class:`~repro.serve.router.Router`
in front of them.  Its jobs:

* **spawn** — write each replica's :class:`ReplicaConfig` JSON, launch
  the process, and wait for the atomic ``port_file`` handshake (the
  replica publishes its port only *after* dummy-compute warmup, so a
  replica is routable exactly when its compile cache is warm);
* **monitor** — :meth:`monitor_once` reaps dead processes, polls health
  through the router (which quarantines unresponsive replicas), and
  services the ``replica_kill`` fault site so the chaos storm can kill
  replicas deterministically (``REPRO_FAULTS="replica_kill:times=1"``);
* **warm handoff** — every replica shares one ``checkpoint_root``, and
  streaming sessions auto-checkpoint at update boundaries (PR 7).  When
  a stream's owner dies, :meth:`recover_stream` restores it on a
  survivor from the newest checkpoint — the restored stream continues
  bit-identically, and the replica's idempotent seq replay keeps a
  retried update exactly-once across the handoff;
* **restart** — a killed/crashed replica is respawned (bounded by
  ``max_restarts``) and reinstated into routing; its persistent compile
  cache (when configured) makes the comeback warm.

Everything is local-process by design (the wire protocol is the only
coupling), so the integration tests exercise real process death, not a
simulation of it.
"""

from __future__ import annotations

import contextlib
import os
import subprocess
import sys
import threading
import time

from ..errors import DeviceError, QueryFailedError
from ..resilience.faults import FaultPlan, inject, use_plan
from .replica import ReplicaConfig
from .router import ReplicaHandle, Router
from .wire import encode_graph

__all__ = ["ManagedReplica", "Fleet"]

_SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


class ManagedReplica:
    """One replica process under fleet management."""

    def __init__(self, config: ReplicaConfig, workdir: str):
        self.config = config
        self.workdir = workdir
        self.process: subprocess.Popen | None = None
        self.handle: ReplicaHandle | None = None
        self.restarts = 0
        self.stopped = False  # deliberately shut down (don't restart)

    @property
    def name(self) -> str:
        return self.config.name

    def alive(self) -> bool:
        return self.process is not None and self.process.poll() is None

    def log_tail(self, lines: int = 20) -> str:
        path = os.path.join(self.workdir, "log.txt")
        try:
            with open(path, errors="replace") as f:
                return "".join(f.readlines()[-lines:])
        except OSError:
            return "<no log>"


class Fleet:
    """Spawn, monitor, and restart a fleet of replica workers.

    ``size`` replicas share one ``checkpoint_root`` (warm handoff needs a
    common view of the checkpoints) and, when ``cache_dir`` is set, one
    persistent compile cache (a restarted replica's first compile per
    bucket is a disk hit).  ``warmup`` specs are distributed round-robin
    so the fleet collectively pre-compiles every expected bucket without
    every replica paying every compile; pass ``warmup_all=True`` to give
    every replica the full list instead.

    Use as a context manager, or call :meth:`start` / :meth:`shutdown`.
    """

    def __init__(
        self,
        size: int = 3,
        *,
        workdir: str,
        max_batch: int = 4,
        chunk: int = 256,
        backend: str | None = None,
        cache_dir: str | None = None,
        checkpoint_every: int = 1,
        max_live: int = 64,
        warmup: tuple = (),
        warmup_all: bool = False,
        spill_depth: int = 4,
        shed_depth: int = 32,
        max_restarts: int = 2,
        auto_restart: bool = True,
        faults: FaultPlan | None = None,
        python: str | None = None,
    ):
        if size < 1:
            raise ValueError("a fleet needs at least one replica")
        self.workdir = os.path.abspath(workdir)
        self.checkpoint_root = os.path.join(self.workdir, "checkpoints")
        self.max_restarts = int(max_restarts)
        self.auto_restart = bool(auto_restart)
        self.faults = faults if faults is not None else FaultPlan.from_env()
        self.python = python or sys.executable
        self.router: Router | None = None
        self._spill_depth = int(spill_depth)
        self._shed_depth = int(shed_depth)
        self._lock = threading.RLock()
        self._stream_owner: dict[str, str] = {}
        self._replicas: dict[str, ManagedReplica] = {}
        warmup = tuple(warmup)
        for i in range(size):
            name = f"replica-{i}"
            rdir = os.path.join(self.workdir, name)
            per_warm = (
                warmup if warmup_all else tuple(warmup[i::size])
            )
            cfg = ReplicaConfig(
                name=name,
                port_file=os.path.join(rdir, "port"),
                max_batch=max_batch,
                chunk=chunk,
                backend=backend,
                cache_dir=cache_dir,
                checkpoint_root=self.checkpoint_root,
                checkpoint_every=checkpoint_every,
                max_live=max_live,
                warmup=per_warm,
            )
            self._replicas[name] = ManagedReplica(cfg, rdir)

    # ------------------------------------------------------------------ #
    # Lifecycle
    # ------------------------------------------------------------------ #
    def __enter__(self) -> "Fleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def start(self, timeout_s: float = 120.0) -> "Fleet":
        """Spawn every replica, wait for all port handshakes, build the
        router.  Replicas warm up in parallel (separate processes)."""
        os.makedirs(self.checkpoint_root, exist_ok=True)
        deadline = time.monotonic() + timeout_s
        for mr in self._replicas.values():
            self._spawn(mr)
        handles = []
        for mr in self._replicas.values():
            port = self._await_port(mr, deadline)
            mr.handle = ReplicaHandle(mr.name, mr.config.host, port)
            handles.append(mr.handle)
        self.router = Router(
            handles,
            chunk=next(iter(self._replicas.values())).config.chunk,
            spill_depth=self._spill_depth,
            shed_depth=self._shed_depth,
        )
        # Seed bucket affinity from what each replica actually warmed.
        self.router.poll_health()
        return self

    def _spawn(self, mr: ManagedReplica) -> None:
        os.makedirs(mr.workdir, exist_ok=True)
        with contextlib.suppress(OSError):
            os.unlink(mr.config.port_file)
        cfg_path = os.path.join(mr.workdir, "config.json")
        with open(cfg_path, "w") as f:
            f.write(mr.config.to_json())
        env = dict(os.environ)
        env["PYTHONPATH"] = _SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
        # A replica must never inherit the fleet's chaos plan — faults
        # against replicas are the *fleet's* to inject, not theirs.
        env.pop("REPRO_FAULTS", None)
        log = open(os.path.join(mr.workdir, "log.txt"), "ab")
        try:
            mr.process = subprocess.Popen(
                [self.python, "-m", "repro.serve.replica", "--config", cfg_path],
                stdout=log,
                stderr=subprocess.STDOUT,
                env=env,
                cwd=mr.workdir,
            )
        finally:
            log.close()
        mr.stopped = False

    def _await_port(self, mr: ManagedReplica, deadline: float) -> int:
        while time.monotonic() < deadline:
            if mr.process is not None and mr.process.poll() is not None:
                raise QueryFailedError(
                    f"replica {mr.name} exited with code "
                    f"{mr.process.returncode} during startup:\n{mr.log_tail()}"
                )
            try:
                with open(mr.config.port_file) as f:
                    return int(f.read().strip())
            except (OSError, ValueError):
                time.sleep(0.05)
        raise QueryFailedError(
            f"replica {mr.name} did not publish a port in time:\n{mr.log_tail()}"
        )

    def kill(self, name: str) -> None:
        """Hard-kill one replica process (the chaos storm's hammer)."""
        mr = self._replicas[name]
        if mr.process is not None and mr.process.poll() is None:
            mr.process.kill()
            mr.process.wait(timeout=10)
        if self.router is not None:
            self._orphans_of(name, self.router.quarantine(name, reason="killed"))

    def restart(self, name: str) -> None:
        """Respawn one replica and reinstate it into routing."""
        mr = self._replicas[name]
        if mr.process is not None and mr.process.poll() is None:
            mr.process.kill()
            mr.process.wait(timeout=10)
        mr.restarts += 1
        self._spawn(mr)
        port = self._await_port(mr, time.monotonic() + 120.0)
        mr.handle = ReplicaHandle(name, mr.config.host, port)
        if self.router is not None:
            self.router.reinstate(name, mr.handle)
            self.router.metrics.inc("fleet_replica_restarts", replica=name)

    def drain(self) -> int:
        """Drain every healthy replica (finish queued work, checkpoint
        streams); returns the total resolved across the fleet."""
        assert self.router is not None, "start() first"
        total = 0
        for handle in self.router.healthy():
            with contextlib.suppress(ConnectionError, DeviceError):
                total += handle.drain()
        return total

    def shutdown(self) -> None:
        """Stop every replica (best-effort polite, then force)."""
        for mr in self._replicas.values():
            mr.stopped = True
            if mr.handle is not None:
                with contextlib.suppress(Exception):
                    mr.handle.shutdown()
                mr.handle.close()
        for mr in self._replicas.values():
            if mr.process is None:
                continue
            try:
                mr.process.wait(timeout=3)
            except subprocess.TimeoutExpired:
                mr.process.kill()
                with contextlib.suppress(subprocess.TimeoutExpired):
                    mr.process.wait(timeout=10)
        if self.router is not None:
            self.router.close()

    # ------------------------------------------------------------------ #
    # Monitoring
    # ------------------------------------------------------------------ #
    def monitor_once(self) -> dict:
        """One monitor tick: fire chaos kills, reap dead processes
        (quarantine + warm handoff + restart), poll health.  Returns the
        health reports that succeeded."""
        assert self.router is not None, "start() first"
        ctx = use_plan(self.faults) if self.faults is not None else contextlib.nullcontext()
        with ctx:
            for name, mr in self._replicas.items():
                if mr.alive() and inject("replica_kill", replica=name):
                    self.kill(name)
        for name, mr in list(self._replicas.items()):
            if mr.process is not None and mr.process.poll() is not None and not mr.stopped:
                orphans = self.router.quarantine(name, reason="process exited")
                self._orphans_of(name, orphans)
                if self.auto_restart and mr.restarts < self.max_restarts:
                    self.restart(name)
                else:
                    mr.stopped = True
        reports = self.router.poll_health()
        # Health-poll quarantines may have orphaned streams too.
        for name in self.router.replica_names:
            if self.router.is_quarantined(name):
                self._orphans_of(name, ())
        return reports

    def _orphans_of(self, name: str, reported: tuple[str, ...]) -> None:
        """Re-home every stream owned by a now-quarantined replica."""
        with self._lock:
            owned = [
                sid for sid, owner in self._stream_owner.items() if owner == name
            ]
        for sid in dict.fromkeys((*owned, *reported)):
            with contextlib.suppress(Exception):
                self.recover_stream(sid)

    # ------------------------------------------------------------------ #
    # Streams: placement, RPC with failover, warm handoff
    # ------------------------------------------------------------------ #
    def open_stream(self, graph, stream_id: str, **opts) -> dict:
        """Open a streaming session on a bucket-affine replica."""
        assert self.router is not None, "start() first"
        handle, _ = self.router.pick(self.router.bucket_of(_GraphQuery(graph)))
        try:
            reply = handle.rpc(
                {
                    "op": "open_stream",
                    "stream_id": stream_id,
                    "graph": encode_graph(graph),
                    **opts,
                }
            )
        finally:
            self.router.release(handle.name)
        with self._lock:
            self._stream_owner[stream_id] = handle.name
        return reply

    def stream_owner(self, stream_id: str) -> str | None:
        with self._lock:
            return self._stream_owner.get(stream_id)

    def recover_stream(self, stream_id: str) -> dict:
        """Warm handoff: restore ``stream_id`` from its newest checkpoint
        on the least-loaded healthy replica; returns the replica's
        committed state (seq, trussness, kmax)."""
        assert self.router is not None, "start() first"
        survivors = self.router.healthy()
        if not survivors:
            raise QueryFailedError(
                f"no healthy replica can adopt stream {stream_id!r}"
            )
        survivor = min(survivors, key=lambda h: self.router.depth(h.name))
        reply = survivor.rpc({"op": "restore_stream", "stream_id": stream_id})
        with self._lock:
            self._stream_owner[stream_id] = survivor.name
        self.router.metrics.inc("fleet_stream_handoffs", stream=stream_id)
        return reply

    def stream_rpc(self, stream_id: str, msg: dict) -> dict:
        """One stream op with failover: on a dead owner, quarantine it,
        hand the stream off warm, and retry on the new owner.  The
        replica's idempotent seq replay makes the retry exactly-once."""
        assert self.router is not None, "start() first"
        for _ in range(len(self._replicas) + 1):
            owner = self.stream_owner(stream_id)
            if owner is None or self.router.is_quarantined(owner):
                self.recover_stream(stream_id)
                owner = self.stream_owner(stream_id)
            handle = self._replicas[owner].handle
            try:
                return handle.rpc(msg)
            except (ConnectionError, DeviceError) as e:
                self.router.mark_failed(owner, reason=str(e))
                continue
        raise QueryFailedError(
            f"stream {stream_id!r} rpc failed on every replica"
        )

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        assert self.router is not None, "start() first"
        with self._lock:
            owners = dict(sorted(self._stream_owner.items()))
        return {
            **self.router.stats(),
            "replicas": {
                name: {
                    "alive": mr.alive(),
                    "restarts": mr.restarts,
                    "quarantined": self.router.is_quarantined(name),
                }
                for name, mr in self._replicas.items()
            },
            "streams": owners,
        }


class _GraphQuery:
    """Minimal duck-typed query for :meth:`Router.bucket_of` (streams
    route by graph bucket but are not TrussQueries)."""

    __slots__ = ("graph",)

    def __init__(self, graph):
        self.graph = graph
