"""Batched serving engine: jitted prefill + decode with donated caches.

``ServeEngine`` drives the same ``Model.prefill/decode`` entry points the
dry-run lowers, with:

  * donated decode states (the KV cache updates in place — no per-step
    cache copy),
  * greedy or temperature sampling,
  * EOS tracking per slot (finished slots keep decoding pad tokens —
    lockstep batching; continuous slot-refill is the documented extension),
  * tokens/s accounting for the benchmark harness.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models.registry import Model

__all__ = ["ServeEngine", "GenerationResult"]


class GenerationResult:
    def __init__(self, tokens: np.ndarray, prefill_s: float, decode_s: float):
        self.tokens = tokens
        self.prefill_s = prefill_s
        self.decode_s = decode_s

    def decode_tokens_per_s(self) -> float:
        n = self.tokens.shape[0] * self.tokens.shape[1]
        return n / max(self.decode_s, 1e-9)


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params: dict,
        *,
        max_len: int,
        eos_id: int | None = None,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)

        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, max_len=max_len)
        )

        def _decode(p, token, states, pos, key, temperature):
            logits, states = model.decode(p, token, states, pos)
            greedy = jnp.argmax(logits, axis=-1)
            sampled = jax.random.categorical(key, logits / jnp.maximum(temperature, 1e-6))
            nxt = jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)
            return nxt[:, None], states

        self._decode = jax.jit(_decode, donate_argnums=(2,))

    def generate(
        self,
        batch: dict,
        *,
        max_new_tokens: int,
        temperature: float = 0.0,
    ) -> GenerationResult:
        """batch: family-correct prefill inputs (tokens + optional embeds)."""
        t0 = time.perf_counter()
        last_logits, states = self._prefill(self.params, batch)
        jax.block_until_ready(last_logits)
        t1 = time.perf_counter()

        prompt_len = batch["tokens"].shape[1]
        prefix = (
            self.model.cfg.frontend_len
            if self.model.cfg.family == "vlm"
            else 0
        )
        cur = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(cur)]
        done = np.zeros(cur.shape[0], bool)
        for i in range(max_new_tokens - 1):
            self.key, sub = jax.random.split(self.key)
            cur, states = self._decode(
                self.params,
                cur,
                states,
                prefix + prompt_len + i,
                sub,
                jnp.float32(temperature),
            )
            tok = np.asarray(cur)
            out.append(tok)
            if self.eos_id is not None:
                done |= tok[:, 0] == self.eos_id
                if done.all():
                    break
        t2 = time.perf_counter()
        return GenerationResult(
            np.concatenate(out, axis=1), prefill_s=t1 - t0, decode_s=t2 - t1
        )
