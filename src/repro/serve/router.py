"""Bucket-affinity router: the fleet's load balancer.

The paper's problem — one slow lane starves the warp — reappears across
replicas: naive round-robin sends a bucket's traffic to replicas that
never compiled it (cold XLA compile on the request path) and keeps
feeding a replica already pinned on a slow bucket.  The router fixes both
the way the planner fixes slot imbalance, with observed stats instead of
static structure:

* **bucket affinity** — each shape bucket has a *home* replica (the one
  that already compiled it, learned from health reports or first
  assignment); same-bucket traffic goes home, so executables compile once
  per bucket per fleet instead of once per replica.
* **EDF spillover** — when the home replica's in-flight depth crosses
  ``spill_depth``, traffic spills to the least-loaded healthy replica.
  :meth:`route_many` routes earliest-deadline-first, so when capacity is
  scarce the urgent queries grab the spare replicas (the batch former's
  EDF rule, one level up).
* **load shedding** — when *every* healthy replica is at ``shed_depth``
  the router sheds at the door through the existing typed path: a
  :class:`~repro.errors.TrussTimeoutError` with ``shed=True``, counted as
  ``router_queries_shed``.
* **quarantine** — a replica that fails a health poll (or errors on RPC)
  is quarantined: removed from routing, its bucket homes redistributed to
  survivors that have the bucket warm, its streams reported to the
  :class:`~repro.serve.fleet.Fleet` for warm handoff.

Metrics: the router owns a registry; each replica gets a child registry
chained to it, so per-replica series stay isolated while the router's
aggregate sees everything (the same parent-chaining ``repro.obs`` uses
for sessions).  Remote counters from health reports are mirrored in via
:meth:`~repro.obs.MetricsRegistry.ingest`.
"""

from __future__ import annotations

import socket
import threading

from ..api.cache import bucket_for, bucket_str
from ..errors import DeviceError, QueryFailedError, TrussTimeoutError
from ..obs import MetricsRegistry, get_registry
from ..obs import clock as obs_clock
from ..resilience.faults import inject
from .replica import HealthReport
from .wire import raise_remote_error, recv_msg, send_msg

__all__ = ["ReplicaHandle", "RoutedQuery", "Router"]


class ReplicaHandle:
    """Client side of one replica's RPC socket (thread-safe, one frame in
    flight per connection; concurrent callers serialize on the lock)."""

    def __init__(self, name: str, host: str, port: int, *, timeout_s: float = 60.0):
        self.name = name
        self.host = host
        self.port = int(port)
        self.timeout_s = float(timeout_s)
        # io-lock: held regions deliberately do socket IO — one frame in
        # flight per connection is the serialization this lock provides.
        self._lock = threading.Lock()  # trusslint: io-lock
        self._sock: socket.socket | None = None  # guarded-by: _lock

    def connect(self) -> None:
        with self._lock:
            self._connect_locked()

    def _connect_locked(self) -> None:  # requires-lock: _lock
        if self._sock is not None:
            return
        s = socket.create_connection((self.host, self.port), timeout=self.timeout_s)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = s

    def close(self) -> None:
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                finally:
                    self._sock = None

    def rpc(self, msg: dict, *, timeout_s: float | None = None) -> dict:
        """One request/response frame; remote errors re-raise typed.

        Connection-level failures surface as ``ConnectionError`` (the
        router's quarantine signal); the ``network`` fault site lets the
        chaos storm fire them deterministically.
        """
        inject("network", replica=self.name, op=msg.get("op"))
        with self._lock:
            try:
                self._connect_locked()
                assert self._sock is not None
                self._sock.settimeout(
                    timeout_s if timeout_s is not None else self.timeout_s
                )
                send_msg(self._sock, msg)
                reply = recv_msg(self._sock)
            except (ConnectionError, socket.timeout, OSError) as e:
                # A dead connection is not retryable on this socket; drop
                # it so a later attempt reconnects (post-restart).
                if self._sock is not None:
                    try:
                        self._sock.close()
                    finally:
                        self._sock = None
                raise ConnectionError(
                    f"replica {self.name} rpc {msg.get('op')!r} failed: {e}"
                ) from e
        if reply is None:
            self.close()
            raise ConnectionError(
                f"replica {self.name} closed during {msg.get('op')!r}"
            )
        if "error" in reply:
            raise_remote_error(reply)
        return reply

    # Typed convenience wrappers ---------------------------------------- #
    def ping(self) -> bool:
        return bool(self.rpc({"op": "ping"}).get("ok"))

    def submit(self, qmsg: dict) -> int:
        return int(self.rpc({"op": "submit", "query": qmsg})["qid"])

    def result(self, qid: int, *, timeout_s: float | None = None) -> dict:
        # The socket wait must outlive the query's own budget, so the
        # replica's typed TrussTimeoutError wins over a raw socket timeout.
        sock_timeout = None if timeout_s is None else timeout_s + self.timeout_s
        return self.rpc(
            {"op": "result", "qid": qid, "timeout": timeout_s},
            timeout_s=sock_timeout,
        )["result"]

    def health(self) -> HealthReport:
        return HealthReport.from_dict(self.rpc({"op": "health"})["health"])

    def drain(self) -> int:
        return int(self.rpc({"op": "drain"}, timeout_s=None)["drained"])

    def shutdown(self) -> None:
        self.rpc({"op": "shutdown"})


class RoutedQuery:
    """One routed submission: which replica, which bucket, which qid."""

    __slots__ = ("replica", "qid", "bucket", "affine")

    def __init__(self, replica: ReplicaHandle, qid: int, bucket: str, affine: bool):
        self.replica = replica
        self.qid = qid
        self.bucket = bucket
        self.affine = affine  # did it land on the bucket's home replica


class Router:
    """N-replica front door: affinity routing + spillover + shed + quarantine."""

    def __init__(
        self,
        replicas: list[ReplicaHandle],
        *,
        chunk: int = 256,
        spill_depth: int = 4,
        shed_depth: int = 32,
        max_health_fails: int = 1,
        metrics: MetricsRegistry | None = None,
    ):
        if not replicas:
            raise ValueError("router needs at least one replica")
        self.chunk = int(chunk)
        self.spill_depth = int(spill_depth)
        self.shed_depth = int(shed_depth)
        self.max_health_fails = int(max_health_fails)
        self.metrics = MetricsRegistry(
            parent=metrics if metrics is not None else get_registry()
        )
        self._lock = threading.RLock()
        self._replicas: dict[str, ReplicaHandle] = {}  # guarded-by: _lock
        self._replica_metrics: dict[str, MetricsRegistry] = {}  # guarded-by: _lock
        self._affinity: dict[str, str] = {}  # bucket label -> replica name; guarded-by: _lock
        self._quarantined: set[str] = set()  # guarded-by: _lock
        self._inflight: dict[str, int] = {}  # guarded-by: _lock
        self._health_fails: dict[str, int] = {}  # guarded-by: _lock
        self._last_health: dict[str, HealthReport] = {}  # guarded-by: _lock
        with self._lock:
            for r in replicas:
                self._register(r)

    def _register(self, handle: ReplicaHandle) -> None:  # requires-lock: _lock
        self._replicas[handle.name] = handle
        # Chained per-replica registry: replica-scoped series roll up into
        # the router's aggregate exactly like session registries roll up
        # into the process-global one.
        self._replica_metrics[handle.name] = MetricsRegistry(parent=self.metrics)
        self._inflight.setdefault(handle.name, 0)
        self._health_fails[handle.name] = 0

    # ------------------------------------------------------------------ #
    # Introspection
    # ------------------------------------------------------------------ #
    @property
    def replica_names(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(self._replicas)

    def healthy(self) -> list[ReplicaHandle]:
        with self._lock:
            return [
                h for n, h in self._replicas.items() if n not in self._quarantined
            ]

    def is_quarantined(self, name: str) -> bool:
        with self._lock:
            return name in self._quarantined

    def last_health(self, name: str) -> HealthReport | None:
        with self._lock:
            return self._last_health.get(name)

    def depth(self, name: str) -> int:
        with self._lock:
            return self._inflight.get(name, 0)

    def stats(self) -> dict:
        m = self.metrics
        hits = int(m.value("router_affinity_hits"))
        spills = int(m.value("router_spillovers"))
        cold = int(m.value("router_affinity_cold"))
        routed = hits + spills + cold
        with self._lock:
            quarantined = sorted(self._quarantined)
            affinity = dict(sorted(self._affinity.items()))
        return {
            "routed": routed,
            "affinity_hits": hits,
            "spillovers": spills,
            "cold_assignments": cold,
            "affinity_hit_rate": round(hits / routed, 4) if routed else 0.0,
            "queries_shed": int(m.value("router_queries_shed")),
            "replicas_quarantined": int(m.value("router_replicas_quarantined")),
            "quarantined": quarantined,
            "affinity": affinity,
        }

    # ------------------------------------------------------------------ #
    # Routing
    # ------------------------------------------------------------------ #
    def bucket_of(self, query) -> str:
        return bucket_str(bucket_for(query.graph, chunk=self.chunk))

    def _least_loaded(self, exclude: set[str] = frozenset()) -> str | None:  # requires-lock: _lock
        candidates = [
            (self._inflight.get(n, 0), i, n)
            for i, n in enumerate(self._replicas)
            if n not in self._quarantined and n not in exclude
        ]
        return min(candidates)[2] if candidates else None

    def _warm_owner(self, bucket: str) -> str | None:  # requires-lock: _lock
        """A healthy replica whose last health report shows ``bucket``
        already compiled (affinity learned from observed state)."""
        for name, report in self._last_health.items():
            if name in self._quarantined:
                continue
            if bucket in report.compiled_buckets:
                return name
        return None

    def pick(self, bucket: str) -> tuple[ReplicaHandle, bool]:
        """Choose a replica for one ``bucket``-keyed query.

        Returns ``(handle, affine)`` where ``affine`` says the query
        landed on the bucket's home replica.  Raises
        :class:`TrussTimeoutError` (``shed=True``) when every healthy
        replica is at ``shed_depth``, and :class:`QueryFailedError` when
        none is healthy at all.
        """
        with self._lock:
            if len(self._quarantined) >= len(self._replicas):
                raise QueryFailedError("no healthy replicas in the fleet")
            floor = min(
                self._inflight.get(n, 0)
                for n in self._replicas
                if n not in self._quarantined
            )
            if floor >= self.shed_depth:
                self.metrics.inc("router_queries_shed")
                raise TrussTimeoutError(
                    f"fleet saturated (every healthy replica at depth >= "
                    f"{self.shed_depth}); query shed",
                    queue_depth=floor,
                    shed=True,
                )
            home = self._affinity.get(bucket)
            if home is not None and home in self._quarantined:
                home = None
            if home is None:
                # Cold bucket: adopt a replica that already compiled it
                # (post-restart / learned from health), else least-loaded.
                home = self._warm_owner(bucket) or self._least_loaded()
                self._affinity[bucket] = home
                self.metrics.inc("router_affinity_cold")
                self._inflight[home] += 1
                return self._replicas[home], False
            if self._inflight.get(home, 0) >= self.spill_depth:
                spill = self._least_loaded(exclude={home})
                if spill is not None and self._inflight[spill] < self._inflight[home]:
                    self.metrics.inc("router_spillovers")
                    self._replica_metrics[spill].inc(
                        "router_replica_spill_in", replica=spill
                    )
                    self._inflight[spill] += 1
                    return self._replicas[spill], False
            self.metrics.inc("router_affinity_hits")
            self._inflight[home] += 1
            return self._replicas[home], True

    def release(self, name: str) -> None:
        """One routed query resolved (or failed): free its depth slot."""
        with self._lock:
            self._inflight[name] = max(0, self._inflight.get(name, 0) - 1)

    def submit(self, query, qmsg: dict) -> RoutedQuery:
        """Route and submit one encoded query; replica failures quarantine
        and re-route until a healthy replica accepts (or none is left)."""
        bucket = self.bucket_of(query)
        while True:
            handle, affine = self.pick(bucket)
            try:
                qid = handle.submit(qmsg)
            except (ConnectionError, DeviceError) as e:
                self.release(handle.name)
                self.mark_failed(handle.name, reason=str(e))
                continue
            except TrussTimeoutError:
                # The replica shed at its own door (admission control) —
                # its health poll will rebalance; propagate the shed.
                self.release(handle.name)
                raise
            return RoutedQuery(handle, qid, bucket, affine)

    def route_many(self, queries: list) -> list[int]:
        """EDF routing order for a batch: earliest absolute deadline
        first, submission order among undeadlined queries.  Returns the
        order's indices — the caller submits in that order so urgent
        queries grab spare capacity first."""
        now = obs_clock.now()

        def urgency(iq):
            i, q = iq
            d = q.deadline_s
            return (now + d if d is not None else float("inf"), i)

        return [i for i, _ in sorted(enumerate(queries), key=urgency)]

    # ------------------------------------------------------------------ #
    # Health and quarantine
    # ------------------------------------------------------------------ #
    def poll_health(self) -> dict[str, HealthReport]:
        """Poll every non-quarantined replica; failures count toward
        quarantine.  Returns the reports that succeeded."""
        reports: dict[str, HealthReport] = {}
        with self._lock:
            targets = [
                (n, h)
                for n, h in self._replicas.items()
                if n not in self._quarantined
            ]
        # The RPCs themselves run unlocked — a slow replica's health poll
        # must not stall routing decisions on the lock.
        for name, handle in targets:
            try:
                report = handle.health()
            except (ConnectionError, DeviceError) as e:
                self.mark_failed(name, reason=str(e))
                continue
            reports[name] = report
            with self._lock:
                self._health_fails[name] = 0
                self._last_health[name] = report
                rm = self._replica_metrics[name]
            rm.set_gauge("replica_queue_depth", report.queue_depth, replica=name)
            rm.set_gauge("replica_live_queries", report.live_queries, replica=name)
            rm.set_gauge(
                "replica_compiled_buckets",
                len(report.compiled_buckets),
                replica=name,
            )
            # Mirror the replica's own counters (shed/failed/retries, ...)
            # into its chained registry so the router-level aggregate has
            # the whole fleet's accounting in one snapshot.
            rm.ingest(
                {
                    "replica_requests_served": report.requests_served,
                    "replica_queries_shed": report.queries_shed,
                    "replica_queries_failed": report.queries_failed,
                    "replica_queries_quarantined": report.queries_quarantined,
                    "replica_retries": report.retries,
                },
                replica=name,
            )
        return reports

    def mark_failed(self, name: str, *, reason: str = "") -> bool:
        """Record one health/RPC failure; quarantine past the threshold.
        Returns whether the replica is now quarantined."""
        with self._lock:
            if name in self._quarantined:
                return True
            self._health_fails[name] = self._health_fails.get(name, 0) + 1
            if self._health_fails[name] < self.max_health_fails:
                return False
        self.quarantine(name, reason=reason)
        return True

    def quarantine(self, name: str, *, reason: str = "") -> tuple[str, ...]:
        """Remove ``name`` from routing and redistribute its bucket homes.

        Returns the stream ids the replica owned per its last health
        report — the fleet restores those on survivors (warm handoff).
        """
        with self._lock:
            if name in self._quarantined:
                return ()
            self._quarantined.add(name)
            self.metrics.inc("router_replicas_quarantined")
            self._replica_metrics[name].inc(
                "router_quarantines", replica=name, reason=reason[:80] or "health"
            )
            self._inflight[name] = 0
            orphaned = [b for b, owner in self._affinity.items() if owner == name]
            for bucket in orphaned:
                heir = self._warm_owner(bucket) or self._least_loaded()
                if heir is None:
                    del self._affinity[bucket]
                else:
                    self._affinity[bucket] = heir
                    self.metrics.inc("router_affinity_redistributed")
            report = self._last_health.get(name)
            handle = self._replicas[name]
        # Socket teardown outside the routing lock: close() can block on
        # a dying peer, and pick()/poll_health() must not wait behind it.
        handle.close()
        return tuple(report.streams) if report is not None else ()

    def reinstate(self, name: str, handle: ReplicaHandle | None = None) -> None:
        """Bring a (restarted) replica back into routing."""
        with self._lock:
            if handle is not None:
                handle.name = name
                self._replicas[name] = handle
                self._replica_metrics.setdefault(
                    name, MetricsRegistry(parent=self.metrics)
                )
            self._quarantined.discard(name)
            self._health_fails[name] = 0
            self._inflight[name] = 0
            self._last_health.pop(name, None)

    def close(self) -> None:
        with self._lock:
            handles = list(self._replicas.values())
        for handle in handles:
            handle.close()
