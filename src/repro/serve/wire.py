"""Length-prefixed JSON-over-socket wire format for the serving tier.

One frame = a 4-byte big-endian payload length followed by a UTF-8 JSON
object.  JSON keeps the protocol inspectable (``tcpdump``/test fixtures
read it directly) and dependency-free; numpy arrays ride inside it as
``{"__nd__": dtype, "shape": [...], "data": <base64>}`` envelopes, so a
query's CSR arrays round-trip bit-exactly — the fleet's bit-identical
contract starts at the wire.

The envelope layer is deliberately dumb: :func:`send_msg` /
:func:`recv_msg` move dicts, and the codec pairs (``encode_query`` /
``decode_query``, ``encode_result`` / ``decode_result``, ...) map the
``repro.api`` value types onto them.  Errors cross the wire as
``{"error": {"type": ..., "message": ...}}`` and are re-raised typed on
the client side (:func:`raise_remote_error`) so ``except
TrussTimeoutError`` works identically against a fleet and a local
session.
"""

from __future__ import annotations

import base64
import json
import socket
import struct

import numpy as np

from .. import errors as repro_errors
from ..core.truss import KTrussResult, TrussDecomposition
from ..graphs.csr import CSRGraph

__all__ = [
    "MAX_FRAME_BYTES",
    "WireError",
    "send_msg",
    "recv_msg",
    "encode_array",
    "decode_array",
    "encode_graph",
    "decode_graph",
    "encode_query",
    "decode_query",
    "encode_result",
    "decode_result",
    "encode_error",
    "raise_remote_error",
]

# One frame must hold a packed query's CSR arrays; 256 MiB bounds a
# malicious/corrupt length prefix without constraining real graphs.
MAX_FRAME_BYTES = 256 * 1024 * 1024
_LEN = struct.Struct(">I")


class WireError(ConnectionError):
    """Framing/decoding failure on one connection (connection is dead)."""


# ---------------------------------------------------------------------- #
# Framing
# ---------------------------------------------------------------------- #
def send_msg(sock: socket.socket, obj: dict) -> None:
    """Write one frame: 4-byte big-endian length + JSON payload."""
    payload = json.dumps(obj, separators=(",", ":")).encode()
    if len(payload) > MAX_FRAME_BYTES:
        raise WireError(f"frame of {len(payload)} bytes exceeds {MAX_FRAME_BYTES}")
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise WireError(f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket) -> dict | None:
    """Read one frame; ``None`` on clean EOF at a frame boundary."""
    try:
        head = sock.recv(_LEN.size)
    except (ConnectionResetError, BrokenPipeError) as e:
        raise WireError(f"connection lost: {e}") from e
    if not head:
        return None  # peer closed between frames
    if len(head) < _LEN.size:
        head += _recv_exact(sock, _LEN.size - len(head))
    (length,) = _LEN.unpack(head)
    if length > MAX_FRAME_BYTES:
        raise WireError(f"frame length {length} exceeds {MAX_FRAME_BYTES}")
    try:
        return json.loads(_recv_exact(sock, length).decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"frame is not valid JSON: {e}") from e


# ---------------------------------------------------------------------- #
# Arrays and graphs
# ---------------------------------------------------------------------- #
def encode_array(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {
        "__nd__": a.dtype.str,
        "shape": list(a.shape),
        "data": base64.b64encode(a.tobytes()).decode(),
    }


def decode_array(d: dict) -> np.ndarray:
    raw = base64.b64decode(d["data"])
    return np.frombuffer(raw, dtype=np.dtype(d["__nd__"])).reshape(d["shape"]).copy()


def encode_graph(g: CSRGraph) -> dict:
    return {
        "n": g.n,
        "rowptr": encode_array(np.asarray(g.rowptr, np.int64)),
        "colidx": encode_array(np.asarray(g.colidx, np.int32)),
        "name": g.name,
    }


def decode_graph(d: dict) -> CSRGraph:
    # Ordinary construction re-validates every CSR invariant, so a peer
    # sending a malformed graph gets a typed InvalidGraphError back
    # instead of poisoning the replica's batch.
    return CSRGraph(
        int(d["n"]),
        decode_array(d["rowptr"]),
        decode_array(d["colidx"]),
        name=str(d.get("name", "graph")),
    )


# ---------------------------------------------------------------------- #
# Queries and results
# ---------------------------------------------------------------------- #
def encode_query(query) -> dict:
    d = {
        "graph": encode_graph(query.graph),
        "workload": query.workload,
        "k": query.k,
        "deadline_s": query.deadline_s,
        "backend": str(query.backend) if query.backend is not None else None,
    }
    if query.frontier is not None:
        d["frontier"] = encode_array(np.asarray(query.frontier, bool))
        d["frozen_truss"] = encode_array(np.asarray(query.frozen_truss, np.int32))
    return d


def decode_query(d: dict):
    from ..api.query import TrussQuery  # lazy: serve must import without api

    kwargs = {}
    if "frontier" in d:
        kwargs["frontier"] = decode_array(d["frontier"])
        kwargs["frozen_truss"] = decode_array(d["frozen_truss"])
    return TrussQuery(
        graph=decode_graph(d["graph"]),
        workload=str(d["workload"]),
        k=int(d["k"]),
        deadline_s=d.get("deadline_s"),
        backend=d.get("backend"),
        **kwargs,
    )


def encode_result(result) -> dict:
    """Map a planner result onto its wire shape (tagged by ``kind``)."""
    if isinstance(result, KTrussResult):
        return {
            "kind": "ktruss",
            "k": result.k,
            "alive": encode_array(result.alive),
            "support": encode_array(result.support),
            "iterations": result.iterations,
            "edges_remaining": result.edges_remaining,
        }
    if isinstance(result, TrussDecomposition):
        return {
            "kind": "decompose",
            "trussness": encode_array(result.trussness),
            "kmax": result.kmax,
            "levels": result.levels,
        }
    if isinstance(result, (int, np.integer)):
        return {"kind": "kmax", "value": int(result)}
    if isinstance(result, np.ndarray):  # stream_update: full trussness
        return {"kind": "trussness", "trussness": encode_array(result)}
    raise TypeError(f"cannot encode result of type {type(result).__name__}")


def decode_result(d: dict):
    kind = d["kind"]
    if kind == "ktruss":
        return KTrussResult(
            k=int(d["k"]),
            alive=decode_array(d["alive"]),
            support=decode_array(d["support"]),
            iterations=int(d["iterations"]),
            edges_remaining=int(d["edges_remaining"]),
        )
    if kind == "decompose":
        return TrussDecomposition(
            trussness=decode_array(d["trussness"]),
            kmax=int(d["kmax"]),
            levels=int(d["levels"]),
        )
    if kind == "kmax":
        return int(d["value"])
    if kind == "trussness":
        return decode_array(d["trussness"])
    raise WireError(f"unknown result kind {kind!r}")


# ---------------------------------------------------------------------- #
# Errors
# ---------------------------------------------------------------------- #
# Context attributes that ride along with an error frame.  JSON scalars
# only, and every name is a keyword its owning class accepts — so e.g. a
# replica's shed crosses the wire as TrussTimeoutError(shed=True), not
# just a message that *says* shed.
_ERROR_CONTEXT = (
    "site",
    "injected",
    "slot",
    "shed",
    "queue_depth",
    "waited_s",
    "request_id",
    "oom",
    "path",
    "row",
    "kind",
    "attempts",
)

# Scalar constructor params deliberately NOT carried across the wire.
# The R6 lint (repro.analysis.rules_wire) requires every scalar-annotated
# error-class param to be whitelisted above or excluded here, with a
# reason:
#   query_id — TrussTimeoutError forwards request_id as query_id to its
#     base; carrying both would pass query_id twice (a ctor TypeError
#     that degrades the whole context to a bare message).
#   graph — a member graph's *name*; the row/kind fields already
#     attribute the failure, and names can be arbitrarily large.
_ERROR_CONTEXT_EXCLUDED = (
    "query_id",
    "graph",
)


def encode_error(e: BaseException) -> dict:
    rec: dict = {"type": type(e).__name__, "message": str(e)}
    ctx = {
        key: v
        for key in _ERROR_CONTEXT
        if isinstance(v := getattr(e, key, None), (bool, int, float, str))
    }
    if ctx:
        rec["context"] = ctx
    return {"error": rec}


def raise_remote_error(d: dict) -> None:
    """Re-raise a remote ``{"error": ...}`` record as its typed class.

    Error classes are resolved by name against :mod:`repro.errors` only
    (never arbitrary import), so a hostile peer can at worst pick which
    *truss* error to raise.  Unknown names degrade to ``RuntimeError``.
    """
    rec = d["error"]
    cls = getattr(repro_errors, rec.get("type", ""), None)
    msg = f"[remote] {rec.get('message', '')}"
    if isinstance(cls, type) and issubclass(cls, BaseException):
        ctx = rec.get("context", {})
        try:
            raise cls(msg, **ctx)
        except TypeError:  # typed ctor rejects the carried kwargs
            pass
        try:
            raise cls(msg)
        except TypeError:  # typed ctor needs kwargs we don't carry
            raise RuntimeError(f"{rec.get('type')}: {msg}") from None
    raise RuntimeError(f"{rec.get('type', 'RemoteError')}: {msg}")
