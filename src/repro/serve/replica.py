"""Replica worker: one process, one thread-safe ``Session``, one socket.

A replica is the fleet's unit of capacity and of failure.  It wraps one
:class:`repro.api.Session` behind the length-prefixed JSON RPC of
:mod:`repro.serve.wire` (submit / result / health / drain / stream ops),
one handler thread per connection — which is exactly why ``Session`` is
thread-safe (PR 9): many router connections drive one batch former.

Design notes carried over from saxml-style model servers:

* **dummy-compute warmup on load** — the replica runs one throwaway
  ``decompose`` per configured warm graph spec *before* opening its
  port, so the first real request in those shape buckets hits a warm
  compile cache instead of paying a cold XLA compile;
* **admission by queue depth** — ``max_live`` bounds unresolved queries;
  past it, submits are refused with a typed ``TrussTimeoutError``
  (``shed=True``) and counted in ``queries_shed``, giving the router a
  backpressure signal instead of an unbounded queue;
* **drain before death** — ``drain`` stops admission, finishes queued
  work, and checkpoints every streaming session, so planned restarts
  hand off warm.

Each :class:`HealthReport` carries the routing signals the ISSUE names:
per-bucket compile-cache hits (bucket affinity's raw material), the
shed/failed/retry counters from the resilience layer, queue depth, and
the observed ``peel_batch_imbalance`` roll-up from ``repro.obs``.

Run standalone with ``python -m repro.serve.replica --config cfg.json``
(the :class:`repro.serve.fleet.Fleet` does this for you); the chosen
port is written atomically to ``config.port_file``.
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import os
import socket
import threading
from typing import Any

import numpy as np

from ..errors import TrussError, TrussTimeoutError
from ..obs.peel_stats import imbalance_summary
from .wire import (
    WireError,
    decode_graph,
    decode_query,
    encode_error,
    encode_result,
    recv_msg,
    send_msg,
)

__all__ = ["ReplicaConfig", "HealthReport", "health_report", "Replica", "main"]

# Warm graph specs resolve against these generators only (the config file
# crosses a process boundary — never eval arbitrary callables from it).
_WARMUP_KINDS = ("erdos", "rmat", "barabasi", "road", "clustered")


@dataclasses.dataclass(frozen=True)
class ReplicaConfig:
    """Everything a replica process needs, JSON-serializable.

    ``warmup`` is a list of ``{"kind": <generator>, ...kwargs}`` specs —
    one throwaway decompose per spec runs before the port opens.
    ``max_live`` is the admission bound (unresolved queries) past which
    submits shed.  ``checkpoint_root`` holds one subdirectory per
    streaming session (``<root>/<stream_id>/``) — on shared storage it is
    what makes warm handoff to a survivor possible.
    """

    name: str = "replica"
    host: str = "127.0.0.1"
    port: int = 0  # 0 = OS-assigned; written to port_file
    port_file: str | None = None
    max_batch: int = 4
    chunk: int = 256
    backend: str | None = None
    cache_dir: str | None = None
    checkpoint_root: str | None = None
    checkpoint_every: int = 1
    max_live: int = 64
    warmup: tuple = ()

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["warmup"] = list(self.warmup)
        return json.dumps(d)

    @classmethod
    def from_json(cls, text: str) -> "ReplicaConfig":
        d = json.loads(text)
        d["warmup"] = tuple(d.get("warmup", ()))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """One replica's health/load snapshot (the router's routing signal)."""

    name: str
    queue_depth: int
    live_queries: int  # unresolved (queued or in flight)
    requests_served: int
    queries_shed: int
    queries_failed: int
    queries_quarantined: int
    retries: int
    warmup_queries: int
    draining: bool
    streams: tuple[str, ...]  # stream ids this replica owns
    compiled_buckets: tuple[str, ...]  # bucket labels with a warm executable
    cache_bucket_hits: dict  # bucket label -> compile-cache hits
    imbalance: tuple  # repro.obs.imbalance_summary rows (dicts)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["streams"] = list(self.streams)
        d["compiled_buckets"] = list(self.compiled_buckets)
        d["imbalance"] = [dict(r) for r in self.imbalance]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "HealthReport":
        d = dict(d)
        d["streams"] = tuple(d.get("streams", ()))
        d["compiled_buckets"] = tuple(d.get("compiled_buckets", ()))
        d["imbalance"] = tuple(d.get("imbalance", ()))
        return cls(**d)


def health_report(
    session,
    *,
    name: str = "replica",
    live_queries: int = 0,
    warmup_queries: int = 0,
    draining: bool = False,
    streams: tuple[str, ...] = (),
) -> HealthReport:
    """Build a :class:`HealthReport` from one ``Session``'s counters.

    Pure read of the session's metrics registry — the shed/quarantine
    accounting a report carries is exactly ``session.stats()``'s, so the
    roundtrip test can assert them equal.
    """
    snap = session.obs.metrics.snapshot()["counters"]
    prefix = "cache_bucket_hits{bucket="
    bucket_hits = {
        k[len(prefix):-1]: int(v)
        for k, v in snap.items()
        if k.startswith(prefix)
    }
    return HealthReport(
        name=name,
        queue_depth=session.queue_depth(),
        live_queries=int(live_queries),
        requests_served=session.requests_served,
        queries_shed=session.queries_shed,
        queries_failed=session.queries_failed,
        queries_quarantined=session.queries_quarantined,
        retries=session.retries,
        warmup_queries=int(warmup_queries),
        draining=bool(draining),
        streams=tuple(streams),
        compiled_buckets=tuple(session.cache.buckets()),
        cache_bucket_hits=bucket_hits,
        imbalance=tuple(imbalance_summary(session.obs.metrics)),
    )


def _warm_graph(spec: dict):
    from .. import graphs

    spec = dict(spec)
    kind = spec.pop("kind", None)
    if kind not in _WARMUP_KINDS:
        raise ValueError(
            f"unknown warmup generator {kind!r}; expected one of {_WARMUP_KINDS}"
        )
    return getattr(graphs, kind)(**spec)


class Replica:
    """The serving loop: accept connections, drive one shared Session."""

    def __init__(self, config: ReplicaConfig, *, session=None):
        from ..api.session import Session  # lazy: jax import is heavy

        self.config = config
        self.session = session or Session(
            max_batch=config.max_batch,
            chunk=config.chunk,
            backend=config.backend,
            cache_dir=config.cache_dir,
        )
        self.warmup_queries = 0
        self._live_lock = threading.Lock()
        self._live = 0  # unresolved queries (admission control); guarded-by: _live_lock
        self._futures_lock = threading.Lock()
        self._futures: dict[int, Any] = {}  # guarded-by: _futures_lock
        # _stream_lock guards map membership AND the per-stream sequence
        # numbers; the per-stream locks in _stream_locks serialize the
        # device work of one stream's updates without blocking the rest.
        self._stream_lock = threading.Lock()
        self._streams: dict[str, Any] = {}  # guarded-by: _stream_lock
        self._stream_seq: dict[str, int] = {}  # guarded-by: _stream_lock
        self._stream_locks: dict[str, threading.Lock] = {}  # guarded-by: _stream_lock
        self._draining = False  # monotonic latch; racy reads only delay the cutover
        self._stop = threading.Event()
        self._sock: socket.socket | None = None

    # ------------------------------------------------------------------ #
    # Lifecycle
    # ------------------------------------------------------------------ #
    def warm(self) -> int:
        """Dummy-compute warmup: one throwaway decompose per warm spec, so
        the matching buckets' executables are compiled before traffic."""
        from ..api.query import TrussQuery

        for spec in self.config.warmup:
            g = _warm_graph(dict(spec))
            self.session.submit(TrussQuery.decompose(g)).result(timeout=None)
            self.warmup_queries += 1
        return self.warmup_queries

    def bind(self) -> int:
        """Open the listening socket (after warmup) and publish the port."""
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((self.config.host, self.config.port))
        self._sock.listen(64)
        port = self._sock.getsockname()[1]
        if self.config.port_file:
            tmp = self.config.port_file + ".tmp"
            with open(tmp, "w") as f:
                f.write(str(port))
            os.replace(tmp, self.config.port_file)
        return port

    def serve_forever(self) -> None:
        assert self._sock is not None, "bind() first"
        self._sock.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._serve_conn, args=(conn,), daemon=True)
            t.start()
        with contextlib.suppress(OSError):
            self._sock.close()

    def stop(self) -> None:
        self._stop.set()

    # ------------------------------------------------------------------ #
    # Per-connection handler
    # ------------------------------------------------------------------ #
    def _serve_conn(self, conn: socket.socket) -> None:
        with contextlib.suppress(WireError, OSError), conn:
            while not self._stop.is_set():
                msg = recv_msg(conn)
                if msg is None:
                    return
                try:
                    reply = self._handle(msg)
                except TrussError as e:
                    reply = encode_error(e)
                except Exception as e:  # a handler bug must not kill the loop
                    reply = encode_error(e)
                send_msg(conn, reply)

    def _handle(self, msg: dict) -> dict:
        op = msg.get("op")
        if op == "ping":
            return {"ok": True}
        if op == "submit":
            return self._op_submit(msg)
        if op == "result":
            return self._op_result(msg)
        if op == "health":
            return {"health": self.health().to_dict()}
        if op == "drain":
            return {"drained": self.drain()}
        if op == "open_stream":
            return self._op_open_stream(msg)
        if op == "restore_stream":
            return self._op_restore_stream(msg)
        if op == "stream_update":
            return self._op_stream_update(msg)
        if op == "shutdown":
            self.stop()
            return {"ok": True}
        raise ValueError(f"unknown op {op!r}")

    # -- queries -------------------------------------------------------- #
    def _op_submit(self, msg: dict) -> dict:
        if self._draining:
            raise TrussTimeoutError(
                f"replica {self.config.name} is draining", shed=True
            )
        with self._live_lock:
            if self._live >= self.config.max_live:
                # Admission control: past max_live the replica sheds at
                # the door — the router reads queries_shed and backs off.
                self.session.obs.metrics.inc("queries_shed")
                raise TrussTimeoutError(
                    f"replica {self.config.name} at max_live="
                    f"{self.config.max_live}; query shed",
                    queue_depth=self.session.queue_depth(),
                    shed=True,
                )
            self._live += 1
        try:
            fut = self.session.submit(decode_query(msg["query"]))
        except BaseException:
            with self._live_lock:
                self._live -= 1
            raise
        with self._futures_lock:
            self._futures[fut.request.id] = fut
        return {"qid": fut.request.id}

    def _op_result(self, msg: dict) -> dict:
        qid = int(msg["qid"])
        with self._futures_lock:
            fut = self._futures.pop(qid, None)
        if fut is None:
            raise KeyError(f"unknown or already-collected qid {qid}")
        try:
            result = fut.result(timeout=msg.get("timeout"))
        except BaseException:
            with self._live_lock:
                self._live -= 1
            raise
        with self._live_lock:
            self._live -= 1
        return {"result": encode_result(result)}

    # -- streams -------------------------------------------------------- #
    def _stream_dir(self, stream_id: str) -> str:
        root = self.config.checkpoint_root
        if root is None:
            raise ValueError(
                "streaming needs a checkpoint_root (warm handoff has "
                "nowhere to write)"
            )
        safe = "".join(c if c.isalnum() or c in "-_." else "_" for c in stream_id)
        return os.path.join(root, safe)

    def _stream_state(self, stream) -> dict:
        t = np.asarray(stream.trussness, np.int32)
        return {
            "trussness": encode_result(t)["trussness"],
            "kmax": int(stream.kmax),
        }

    def _op_open_stream(self, msg: dict) -> dict:
        sid = str(msg["stream_id"])
        g = decode_graph(msg["graph"])
        d = self._stream_dir(sid)
        os.makedirs(d, exist_ok=True)
        stream = self.session.open_stream(g)
        stream.checkpoint_dir = d
        stream.checkpoint_every = int(
            msg.get("checkpoint_every", self.config.checkpoint_every)
        )
        # Checkpoint the initial state so a crash before the first update
        # still hands off warm.
        stream._auto_checkpoint()
        with self._stream_lock:
            self._streams[sid] = stream
            self._stream_seq[sid] = 0
            self._stream_locks[sid] = threading.Lock()
        return {"stream_id": sid, "seq": 0, **self._stream_state(stream)}

    def _op_restore_stream(self, msg: dict) -> dict:
        from ..resilience.checkpoint import latest_checkpoint
        from ..stream.session import StreamingTrussSession

        sid = str(msg["stream_id"])
        d = self._stream_dir(sid)
        path = latest_checkpoint(d)
        if path is None:
            raise FileNotFoundError(f"no checkpoint for stream {sid!r} in {d}")
        stream = StreamingTrussSession.restore(
            path,
            session=self.session,
            checkpoint_dir=d,
            checkpoint_every=int(
                msg.get("checkpoint_every", self.config.checkpoint_every)
            ),
        )
        with self._stream_lock:
            self._streams[sid] = stream
            seq = self._stream_seq[sid] = stream.updates_total
            self._stream_locks[sid] = threading.Lock()
        return {
            "stream_id": sid,
            "seq": seq,
            **self._stream_state(stream),
        }

    def _op_stream_update(self, msg: dict) -> dict:
        from .wire import decode_array
        from ..stream.delta import EdgeBatch

        sid = str(msg["stream_id"])
        seq = int(msg["seq"])
        with self._stream_lock:
            stream = self._streams.get(sid)
            lock = self._stream_locks.get(sid)
        if stream is None:
            raise KeyError(f"replica does not own stream {sid!r}")
        # Per-stream lock: updates on one stream serialize (deltas are
        # relative to the committed graph) without blocking health polls
        # or other streams behind a device dispatch.  The sequence map
        # itself stays under _stream_lock — one guard per attribute, not
        # one per path (the R3 lint checks exactly this).
        with lock:
            with self._stream_lock:
                applied = self._stream_seq[sid]
            if seq <= applied:
                # Idempotent replay: the update committed (and was
                # checkpointed) but the ack was lost — re-acking the
                # committed state keeps retries exactly-once.
                return {
                    "stream_id": sid,
                    "seq": applied,
                    "replayed": True,
                    **self._stream_state(stream),
                }
            if seq != applied + 1:
                raise ValueError(
                    f"stream {sid!r} expects seq {applied + 1}, got {seq}"
                )
            batch = EdgeBatch(
                decode_array(msg["inserts"]).reshape(-1, 2),
                decode_array(msg["deletes"]).reshape(-1, 2),
            )
            res = stream.update(batch)
            with self._stream_lock:
                self._stream_seq[sid] = seq
            return {
                "stream_id": sid,
                "seq": seq,
                "frontier_size": res.frontier_size,
                "dispatches": res.dispatches,
                **self._stream_state(stream),
            }

    # -- health / drain -------------------------------------------------- #
    def health(self) -> HealthReport:
        with self._stream_lock:
            streams = tuple(sorted(self._streams))
        with self._live_lock:
            live = self._live
        return health_report(
            self.session,
            name=self.config.name,
            live_queries=live,
            warmup_queries=self.warmup_queries,
            draining=self._draining,
            streams=streams,
        )

    def drain(self) -> int:
        """Stop admission, run everything queued, checkpoint every stream."""
        self._draining = True
        n = self.session.drain()
        with self._stream_lock:
            streams = list(self._streams.values())
        for stream in streams:
            if stream.checkpoint_dir is not None:
                stream._auto_checkpoint()
        return n


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description="repro.serve replica worker")
    parser.add_argument("--config", required=True, help="ReplicaConfig JSON file")
    args = parser.parse_args(argv)
    with open(args.config) as f:
        config = ReplicaConfig.from_json(f.read())
    replica = Replica(config)
    replica.warm()
    replica.bind()
    replica.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
