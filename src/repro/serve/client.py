"""FleetClient: the ``solve()``/``Session`` API over a replica fleet.

Existing entry points migrate by swapping the constructor — everything
else reads the same::

    from repro.api import TrussQuery, solve          # single process
    results = solve(queries)

    from repro.serve import Fleet, FleetClient       # fleet
    with Fleet(3, workdir=".fleet") as fleet:
        client = FleetClient(fleet)
        results = client.solve(queries)              # same results,
                                                     # bit for bit

``submit`` returns a :class:`FleetFuture` (mirror of
:class:`repro.api.TrussFuture`: ``result(timeout=...)`` raising the same
typed errors — a replica's shed crosses the wire as the same
:class:`~repro.errors.TrussTimeoutError` with ``shed=True``).
``open_stream`` returns a :class:`FleetStream` whose ``update`` survives
replica death: the fleet hands the stream off warm and the client's
sequence numbers make the retried update exactly-once.

The bit-identical contract holds because every replica runs the same
deterministic planner/peel as a local ``Session`` — routing changes
*where* a query runs, never *what* it computes.
"""

from __future__ import annotations

import itertools
import threading
from typing import Any

import numpy as np

from ..errors import DeviceError, QueryFailedError
from .fleet import Fleet
from .wire import decode_array, decode_result, encode_array, encode_query

__all__ = ["FleetFuture", "FleetStream", "FleetClient"]

_stream_ids = itertools.count()


class FleetFuture:
    """Handle to one query submitted through the fleet (mirror of
    :class:`repro.api.TrussFuture`)."""

    def __init__(self, client: "FleetClient", query, qmsg: dict, routed):
        self._client = client
        self.query = query
        self._qmsg = qmsg
        self._routed = routed
        self._result: Any = None
        self._error: BaseException | None = None
        self._done = False

    @property
    def replica(self) -> str:
        """Name of the replica currently holding this query."""
        return self._routed.replica.name

    @property
    def affine(self) -> bool:
        """Did routing land on the query's bucket-home replica."""
        return self._routed.affine

    def done(self) -> bool:
        return self._done

    def result(self, timeout: float | None = None) -> Any:
        """Block on the remote result; typed errors re-raise locally.

        A replica that dies mid-query gets quarantined and the query is
        transparently resubmitted to a survivor (queries are pure — a
        re-run is bit-identical, not at-most-once)."""
        if self._done:
            if self._error is not None:
                raise self._error
            return self._result
        router = self._client.router
        while True:
            routed = self._routed
            try:
                encoded = routed.replica.result(routed.qid, timeout_s=timeout)
            except (ConnectionError, DeviceError) as e:
                router.release(routed.replica.name)
                router.mark_failed(routed.replica.name, reason=str(e))
                router.metrics.inc("router_query_retries")
                # Resubmit elsewhere; pick/submit handle quarantine/shed.
                self._routed = router.submit(self.query, self._qmsg)
                continue
            except BaseException as e:
                router.release(routed.replica.name)
                self._error = e
                self._done = True
                raise
            router.release(routed.replica.name)
            self._result = decode_result(encoded)
            self._done = True
            return self._result


class FleetStream:
    """Client half of a replica-hosted streaming truss session.

    Mirrors :class:`repro.stream.StreamingTrussSession`'s read surface
    (``trussness``, ``kmax``, ``update``) while the maintained state
    lives on a replica.  ``update`` carries a client-side sequence
    number; after a crash + warm handoff, a retried update is recognized
    (``seq <= committed``) and re-acked instead of re-applied."""

    def __init__(self, client: "FleetClient", stream_id: str, state: dict):
        self._client = client
        self.stream_id = stream_id
        self._apply_state(state)

    def _apply_state(self, state: dict) -> None:
        self.seq = int(state["seq"])
        self.trussness = decode_array(state["trussness"])
        self.kmax = int(state["kmax"])

    @property
    def owner(self) -> str | None:
        """Name of the replica currently hosting this stream."""
        return self._client.fleet.stream_owner(self.stream_id)

    def update(self, batch) -> dict:
        """Apply one :class:`~repro.stream.delta.EdgeBatch` exactly once
        (survives replica death mid-update); returns the replica's commit
        record and refreshes ``trussness``/``kmax``/``seq``."""
        msg = {
            "op": "stream_update",
            "stream_id": self.stream_id,
            "seq": self.seq + 1,
            "inserts": encode_array(np.asarray(batch.inserts, np.int64)),
            "deletes": encode_array(np.asarray(batch.deletes, np.int64)),
        }
        reply = self._client.fleet.stream_rpc(self.stream_id, msg)
        self._apply_state(reply)
        return reply


class FleetClient:
    """``solve()``/``Session``-shaped front door over a :class:`Fleet`."""

    def __init__(self, fleet: Fleet):
        if fleet.router is None:
            raise QueryFailedError("fleet is not started (call start())")
        self.fleet = fleet
        self.router = fleet.router
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ #
    def submit(self, query) -> FleetFuture:
        """Route one declarative query to a replica; returns a future."""
        qmsg = encode_query(query)
        routed = self.router.submit(query, qmsg)
        return FleetFuture(self, query, qmsg, routed)

    def solve(self, queries) -> Any:
        """Route and resolve a query set; results in submission order.

        Submission happens in EDF order (urgent queries claim spare
        capacity first — the router's spillover rule), results come back
        in the caller's order, exactly like :func:`repro.api.solve`."""
        from ..api.query import TrussQuery  # lazy: jax-heavy import chain

        single = isinstance(queries, TrussQuery)
        qs = [queries] if single else list(queries)
        futs: list[FleetFuture | None] = [None] * len(qs)
        for i in self.router.route_many(qs):
            futs[i] = self.submit(qs[i])
        results = [f.result() for f in futs]
        return results[0] if single else results

    def open_stream(self, graph, *, stream_id: str | None = None, **opts) -> FleetStream:
        """Open a streaming truss session hosted on the fleet."""
        if stream_id is None:
            with self._lock:
                stream_id = f"stream-{next(_stream_ids)}"
        state = self.fleet.open_stream(graph, stream_id, **opts)
        return FleetStream(self, stream_id, state)

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """Fleet-level serving stats (router + replicas + streams)."""
        return self.fleet.stats()

    def drain(self) -> int:
        return self.fleet.drain()
