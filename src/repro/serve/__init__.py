"""Serving substrate: batched prefill/decode engine."""

from .engine import GenerationResult, ServeEngine

__all__ = ["GenerationResult", "ServeEngine"]
