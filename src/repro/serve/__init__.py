"""repro.serve — the multi-replica serving tier over :mod:`repro.api`.

The paper's load-imbalance problem, one level up: across *replicas*, one
process pinned on a slow bucket starves the fleet unless work is routed
by observed state.  Four pieces:

* :mod:`.wire`    — length-prefixed JSON-over-socket protocol (queries,
                    results, and typed errors cross processes bit-exactly);
* :mod:`.replica` — worker process wrapping one thread-safe ``Session``:
                    dummy-compute warmup before the port opens,
                    ``max_live`` admission (shed at the door), periodic
                    :class:`HealthReport`\\ s, drain-before-death;
* :mod:`.router`  — bucket-affinity routing (same-bucket traffic goes to
                    the replica that already compiled it), EDF spillover
                    past a depth threshold, load shedding through the
                    typed :class:`~repro.errors.TrussTimeoutError` path,
                    quarantine + redistribution on health failure;
* :mod:`.fleet` / :mod:`.client` — process lifecycle (spawn / monitor /
                    chaos-kill / restart) and the ``solve()``-shaped
                    :class:`FleetClient`, with warm handoff of streaming
                    sessions via PR 7's checkpoint/restore.

Quickstart::

    from repro.serve import Fleet, FleetClient

    with Fleet(3, workdir=".fleet") as fleet:
        client = FleetClient(fleet)
        results = client.solve(queries)   # bit-identical to solve(queries)
"""

from .client import FleetClient, FleetFuture, FleetStream
from .fleet import Fleet, ManagedReplica
from .replica import HealthReport, Replica, ReplicaConfig, health_report
from .router import ReplicaHandle, Router
from .wire import (
    MAX_FRAME_BYTES,
    WireError,
    decode_query,
    decode_result,
    encode_query,
    encode_result,
    raise_remote_error,
    recv_msg,
    send_msg,
)

__all__ = [
    # client
    "FleetClient",
    "FleetFuture",
    "FleetStream",
    # fleet
    "Fleet",
    "ManagedReplica",
    # replica
    "Replica",
    "ReplicaConfig",
    "HealthReport",
    "health_report",
    # router
    "Router",
    "ReplicaHandle",
    # wire
    "MAX_FRAME_BYTES",
    "WireError",
    "send_msg",
    "recv_msg",
    "encode_query",
    "decode_query",
    "encode_result",
    "decode_result",
    "raise_remote_error",
]
