"""Typed errors of the declarative query API.

The taxonomy itself lives in :mod:`repro.errors` (a dependency-free
module so low layers — ``graphs.csr`` validation, ``exec.peel`` — can
raise typed errors without import cycles); this module is its public
face on ``repro.api``.  See :class:`repro.errors.TrussError` for the
context contract every subclass carries (bucket / backend / slot /
query_id / injected) and :mod:`repro.resilience` for the policy layer
keyed on it.
"""

from __future__ import annotations

from ..errors import (
    CheckpointError,
    CompileError,
    DeviceError,
    InvalidGraphError,
    QueryFailedError,
    TrussError,
    TrussTimeoutError,
)

__all__ = [
    "TrussError",
    "InvalidGraphError",
    "CompileError",
    "DeviceError",
    "QueryFailedError",
    "TrussTimeoutError",
    "CheckpointError",
]
