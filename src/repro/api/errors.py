"""Typed errors of the declarative query API."""

from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from .cache import Bucket

__all__ = ["TrussTimeoutError"]


class TrussTimeoutError(TimeoutError):
    """``TrussFuture.result(timeout=...)`` expired before the query resolved.

    Carries enough context to act on — which shape bucket the request was
    waiting in and how deep the session's queue was at expiry — instead of
    a bare ``TimeoutError`` that forces callers to re-derive both.
    """

    def __init__(
        self,
        message: str,
        *,
        bucket: "Bucket | None" = None,
        queue_depth: int = 0,
        request_id: int | None = None,
        waited_s: float = 0.0,
    ):
        super().__init__(message)
        self.bucket = bucket
        self.queue_depth = int(queue_depth)
        self.request_id = request_id
        self.waited_s = float(waited_s)
