"""Shape-bucket canonicalization + compile cache for the query API.

XLA (and Pallas) executables are specialized to static shapes, so a naive
server recompiles the fixed-point program for every distinct graph — tens
of milliseconds to seconds per request.  Canonicalizing every incoming
graph to power-of-two ``(n_pad, nnz_pad, window)`` buckets collapses the
shape space: one executable per bucket serves every request (and every
micro-batch) that lands in it.  GraphBLAST makes the same bet — reusable
kernels behind a stable API beat per-input specialization.

The compiled artifact is a *problem-polymorphic* on-device peel: the
executor takes the ``FineProblem`` pytree as an argument, so any
same-bucket problem — including a block-diagonal batch of them — reuses
the program.  Thresholds are per-slot state advanced inside the compiled
loop, which lets one dispatch run different k values *and* mixed
ktruss/kmax/decompose/stream workloads to completion for every member of
a packed batch (``repro.exec.peel``).  Cache keys are
``(bucket, slots, variant)``: the slot count scales the packed shapes and
the variant captures everything else that specializes the executable —
the registry backend key, dataflow mode, and mesh placement.
"""

from __future__ import annotations

import threading
from typing import Callable, Hashable, NamedTuple

import numpy as np

from ..errors import CompileError, TrussError
from ..graphs.csr import CSRGraph
from ..obs import MetricsRegistry

__all__ = [
    "Bucket",
    "bucket_for",
    "bucket_str",
    "build_peel",
    "CacheStats",
    "CompileCache",
    "enable_persistent_cache",
]


def enable_persistent_cache(cache_dir: str) -> None:
    """Point XLA's persistent compilation cache at ``cache_dir``.

    The in-process :class:`CompileCache` dedupes executables per
    ``(bucket, slots, variant)`` key but dies with the process; wiring
    JAX's persistent cache underneath means a restarted server's *first*
    compile per bucket is a disk hit instead of a cold XLA compile
    (skipped warmup).  Process-wide by necessity — the JAX cache is
    global — and idempotent; opt in via ``Session(cache_dir=...)``.

    The entry-size/compile-time floors are dropped to 0 so even the small
    CPU-test executables round-trip (JAX's defaults skip sub-second
    compiles, which would make a warm restart silently cold).

    The fused megakernel's autotune store rides along: winning per-bucket
    kernel configs persist to ``<cache_dir>/autotune.json`` and are
    replayed on warm start (``repro.kernels.autotune.lookup`` — the
    planner consults it whenever it builds a fused executor).
    """
    import os

    import jax

    jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)

    from ..kernels import autotune

    autotune.set_store(os.path.join(str(cache_dir), "autotune.json"))


class Bucket(NamedTuple):
    """Canonical power-of-two shape class of one graph slot.

    A graph in this bucket is packed to ``n_pad`` vertices, ``nnz_pad``
    directed nonzeros (twice that undirected) and intersected with windows
    of width ``window``.  Batches of B same-bucket graphs use the scaled
    shapes ``(B * n_pad, B * nnz_pad)``; the executor cache key is
    ``(bucket, slots, variant)``.
    """

    n_pad: int
    nnz_pad: int
    window: int


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def bucket_for(g: CSRGraph, *, chunk: int = 256, min_window: int = 8) -> Bucket:
    """Canonical shape bucket of one graph.

    The window is sized to the max *undirected* degree so one bucket is
    valid for every backend (eager needs out-degree, owner/pallas need
    the symmetric degree).
    """
    deg = g.degrees()
    indeg = np.bincount(g.colidx, minlength=g.n + 1)
    und_max = int((deg + indeg).max(initial=0))
    return Bucket(
        n_pad=_next_pow2(max(g.n, 1)),
        nnz_pad=_next_pow2(max(g.nnz, chunk)),
        window=_next_pow2(max(min_window, und_max)),
    )


def bucket_str(bucket: Bucket) -> str:
    """Canonical string label of one bucket (``n{..}-nnz{..}-w{..}``).

    The one spelling shared by metrics labels, planner stats rows, and the
    serving tier's affinity keys — the router matches these against a
    replica's ``compiled_buckets``, so every producer must agree."""
    return f"n{bucket.n_pad}-nnz{bucket.nnz_pad}-w{bucket.window}"


def build_peel(
    *,
    mode: str = "eager",
    backend: str = "xla",
    window: int,
    chunk: int = 256,
    max_iters: int | None = None,
    mesh=None,
):
    """Compile-cachable on-device peel for one shape bucket.

    Legacy bucket-config adapter over the exec layer (the registry's
    :meth:`repro.api.BackendSpec.make_executor` is the first-class path);
    kept so existing ``repro.service`` imports keep working.
    """
    from ..exec.peel import PeelExecutor

    return PeelExecutor(
        mode=mode,
        backend=backend,
        window=window,
        chunk=chunk,
        max_iters=max_iters,
        mesh=mesh,
    )


class CacheStats:
    """Compile-cache hit/miss counters — a view over the metrics registry.

    The counters live in a :class:`repro.obs.MetricsRegistry`
    (``cache_compiles`` / ``cache_hits``), so they show up in
    ``obs.metrics_snapshot()`` and the Prometheus exposition alongside
    every other instrument; ``compiles`` / ``hits`` / ``hit_rate`` keep
    their historical read surface, and :meth:`snapshot` (alias
    :meth:`row`) keeps the historical key set.
    """

    def __init__(self, metrics: "MetricsRegistry | None" = None):
        if metrics is None:
            metrics = MetricsRegistry()  # standalone cache: private series
        self.metrics = metrics

    def record_compile(self, bucket: "Bucket | None" = None) -> None:
        self.metrics.inc("cache_compiles")
        if bucket is not None:
            self.metrics.inc("cache_bucket_compiles", bucket=bucket_str(bucket))

    def record_hit(self, bucket: "Bucket | None" = None) -> None:
        self.metrics.inc("cache_hits")
        if bucket is not None:
            self.metrics.inc("cache_bucket_hits", bucket=bucket_str(bucket))

    @property
    def compiles(self) -> int:
        return int(self.metrics.value("cache_compiles"))

    @property
    def hits(self) -> int:
        return int(self.metrics.value("cache_hits"))

    @property
    def requests(self) -> int:
        return self.compiles + self.hits

    @property
    def hit_rate(self) -> float:
        return self.hits / self.requests if self.requests else 0.0

    def row(self) -> dict:
        return {
            "compiles": self.compiles,
            "hits": self.hits,
            "hit_rate": round(self.hit_rate, 4),
        }

    # The key-locked export name (tests/test_obs.py snapshots this).
    snapshot = row


class CompileCache:
    """Executor store keyed by ``(bucket, slots, variant)`` with hit/miss
    counters.

    Each key maps to one peel executor built by ``builder(key)``; a key's
    executable only ever sees one argument-shape signature (the
    bucket-canonical one), so ``compiles`` counts actual XLA compilations,
    not just builder calls.  ``variant`` folds in whatever else
    specializes the program — the backend key, dataflow mode, and mesh
    placement.  ``metrics`` routes the hit/miss counters into the owning
    session's registry (default: a private one).
    """

    def __init__(
        self,
        builder: Callable[[tuple[Bucket, int, Hashable]], Callable],
        *,
        metrics: "MetricsRegistry | None" = None,
    ):
        self._builder = builder
        self._exes: dict[tuple[Bucket, int, Hashable], Callable] = {}
        self._lock = threading.Lock()
        self.stats = CacheStats(metrics)

    def get(
        self, bucket: Bucket, slots: int, variant: Hashable = "contig"
    ) -> tuple[Callable, bool]:
        """Return (executor, was_hit) for one bucket/slots/variant key."""
        key = (bucket, int(slots), variant)
        with self._lock:
            exe = self._exes.get(key)
            if exe is not None:
                self.stats.record_hit(bucket)
                return exe, True
            try:
                exe = self._exes[key] = self._builder(key)
            except TrussError:
                raise  # already typed (e.g. an injected CompileError)
            except Exception as e:
                # A failed build is a CompileError no matter which layer
                # threw — the resilience runner keys its fallback on it.
                raise CompileError(
                    f"building executor for bucket={bucket} slots={slots} "
                    f"variant={variant} failed: {e}",
                    bucket=bucket,
                    cause=e,
                ) from e
            self.stats.record_compile(bucket)
            return exe, False

    def buckets(self) -> tuple[str, ...]:
        """Labels of every bucket holding at least one compiled executable
        (sorted) — a replica's ``compiled_buckets`` health field, and the
        raw material of the router's bucket affinity."""
        with self._lock:
            seen = {bucket_str(b) for (b, _slots, _variant) in self._exes}
        return tuple(sorted(seen))

    def __len__(self) -> int:
        return len(self._exes)
