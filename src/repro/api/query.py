"""`TrussQuery`: the one declarative description of any K-truss workload.

A query names *what* to compute — ``ktruss(k)`` membership, ``kmax``, a
full ``decompose``, or a frontier-bounded ``stream_update`` — plus
optional placement, deadline, backend, and stats knobs.  It never says
*how*: lowering onto a formulation/kernel/layout backend is the
:class:`repro.api.Planner`'s job, so every entry point (``solve()``,
``Session``, and the legacy ``KTrussEngine`` / ``TrussService`` /
``StreamingTrussSession`` adapters) shares one execution path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from ..graphs.csr import CSRGraph
from .registry import BackendKey

__all__ = ["WORKLOADS", "PLACEMENTS", "TrussQuery"]

WORKLOADS = ("ktruss", "kmax", "decompose", "stream_update")

# auto: let the session place (sharded iff it has a mesh); replicated /
# sharded force the choice and fail loudly when the session cannot honor it.
PLACEMENTS = ("auto", "replicated", "sharded")


@dataclasses.dataclass(frozen=True)
class TrussQuery:
    """One declarative K-truss request over one graph.

    Fields:
      graph: the upper-triangular CSR instance to query.
      workload: one of :data:`WORKLOADS`.
      k: target k for ``ktruss``; starting k for every other workload.
      frontier / frozen_truss: ``stream_update`` only — which edges are
        free to re-peel and the known trussness the complement is frozen
        at (see ``repro.exec.build_peel``'s frozen lanes).
      backend: force a registry backend (``BackendKey`` or
        ``"formulation/kernel/layout"`` string); ``None`` defers to the
        planner's auto rule (imbalance-statistic keyed).
      placement: one of :data:`PLACEMENTS`.
      deadline_s: soft scheduling deadline (seconds from submit).  The
        session's batch former serves the earliest-deadline group first,
        and ``TrussFuture.result()`` uses it as its default timeout.
      collect_stats: populate per-request :class:`repro.api.RequestStats`.
    """

    graph: CSRGraph
    workload: str = "ktruss"
    k: int = 3
    frontier: Optional[np.ndarray] = None
    frozen_truss: Optional[np.ndarray] = None
    backend: Union[BackendKey, str, None] = None
    placement: str = "auto"
    deadline_s: Optional[float] = None
    collect_stats: bool = True

    def __post_init__(self):
        if self.workload not in WORKLOADS:
            raise ValueError(
                f"unknown workload {self.workload!r}; expected one of {WORKLOADS}"
            )
        if self.placement not in PLACEMENTS:
            raise ValueError(
                f"unknown placement {self.placement!r}; expected one of {PLACEMENTS}"
            )
        if self.k < 3:
            raise ValueError(f"k must be >= 3, got {self.k}")
        if self.deadline_s is not None and self.deadline_s < 0:
            raise ValueError(f"deadline_s must be >= 0, got {self.deadline_s}")
        if self.workload == "stream_update":
            if self.frontier is None or self.frozen_truss is None:
                raise ValueError("stream_update requires frontier= and frozen_truss=")
            frontier = np.asarray(self.frontier, bool)
            frozen = np.asarray(self.frozen_truss, np.int32)
            nnz = self.graph.nnz
            if frontier.shape != (nnz,) or frozen.shape != (nnz,):
                raise ValueError(
                    f"frontier/frozen_truss must cover all {nnz} edges, got "
                    f"{frontier.shape} / {frozen.shape}"
                )
            object.__setattr__(self, "frontier", frontier)
            object.__setattr__(self, "frozen_truss", frozen)
        elif self.frontier is not None or self.frozen_truss is not None:
            raise ValueError(
                f"frontier/frozen_truss are stream_update-only fields "
                f"(workload is {self.workload!r})"
            )

    # ------------------------------------------------------------------ #
    # Constructors — one per workload, so call sites read declaratively.
    # ------------------------------------------------------------------ #
    @classmethod
    def ktruss(cls, graph: CSRGraph, k: int, **opts) -> "TrussQuery":
        """Membership mask + supports of the k-truss."""
        return cls(graph=graph, workload="ktruss", k=int(k), **opts)

    @classmethod
    def kmax(cls, graph: CSRGraph, k_start: int = 3, **opts) -> "TrussQuery":
        """Largest k with a non-empty truss (0 if even k_start's is empty)."""
        return cls(graph=graph, workload="kmax", k=int(k_start), **opts)

    @classmethod
    def decompose(cls, graph: CSRGraph, k_start: int = 3, **opts) -> "TrussQuery":
        """Full truss decomposition: trussness of every edge."""
        return cls(graph=graph, workload="decompose", k=int(k_start), **opts)

    @classmethod
    def stream_update(
        cls,
        graph: CSRGraph,
        *,
        frontier: np.ndarray,
        frozen_truss: np.ndarray,
        **opts,
    ) -> "TrussQuery":
        """Frontier-bounded re-peel: the streaming maintenance kernel."""
        return cls(
            graph=graph,
            workload="stream_update",
            frontier=frontier,
            frozen_truss=frozen_truss,
            **opts,
        )
