"""Backend registry: formulation × kernel × layout, chosen per shape bucket.

The paper's subject is a *formulation* choice — coarse row tasks
(Algorithm 2) vs. fine nonzero tasks (Algorithm 3) of Eager K-truss — and
its result is that the right choice is input-dependent: fine wins under
load imbalance (heavy-tailed degree distributions), while the row
formulation is competitive on balanced graphs.  This module makes that
choice a first-class, swappable backend axis instead of a constructor
flag smeared across entry points:

* ``formulation`` — ``coarse`` (row tasks) | ``fine`` (nonzero tasks);
* ``kernel``      — ``xla`` (fused scatter/gather ops) | ``pallas``
                    (hand-written TPU kernels, interpret-mode on CPU) |
                    ``fused`` (persistent Pallas peel megakernel: one
                    launch per truss level, autotuned per bucket);
* ``layout``      — ``contig`` (prefix-sum packed lanes) | ``aligned``
                    (slot-aligned lanes, shardable across a mesh; the
                    only layout whose slot-banded lane geometry the fused
                    megakernel can tile).

Every registered backend is *semantically identical* — bit-identical
``trussness`` on any graph (parity-tested in ``tests/test_api.py``) — so
the :func:`choose_backend` auto rule is purely a performance policy keyed
on the paper's imbalance statistics (``repro.graphs.stats``), and a
benchmark sweep over backends is a one-flag axis.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Union

from ..graphs.stats import ImbalanceStats

__all__ = [
    "FORMULATIONS",
    "KERNELS",
    "LAYOUTS",
    "BackendKey",
    "BackendSpec",
    "register_backend",
    "get_backend",
    "available_backends",
    "choose_backend",
    "default_kernel",
    "fallback_backends",
]

FORMULATIONS = ("coarse", "fine")
KERNELS = ("xla", "pallas", "fused")
LAYOUTS = ("contig", "aligned")


class BackendKey(NamedTuple):
    """One point of the backend grid; the registry and compile-cache key."""

    formulation: str  # coarse | fine
    kernel: str  # xla | pallas
    layout: str  # contig | aligned

    def __str__(self) -> str:  # "fine/xla/aligned" — the CLI/bench spelling
        return "/".join(self)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """A registered backend: its key plus how to build its executor.

    ``mode`` is the update dataflow the support kernel uses (``eager``
    scatter vs ``owner`` collision-free); it is an implementation detail
    of the spec, not a registry axis — the Pallas kernels are owner-form
    by construction (TPU grid cells cannot atomically collide).
    """

    key: BackendKey
    mode: str = "eager"
    description: str = ""

    def make_executor(
        self,
        *,
        window: int,
        chunk: int = 256,
        row_chunk: int = 32,
        max_iters: int | None = None,
        mesh=None,
        mode: str | None = None,
        fused_config=None,
    ):
        """Build this backend's :class:`repro.exec.PeelExecutor` for one
        shape bucket.  ``mode`` overrides the spec's dataflow (the legacy
        ``TrussService(mode=...)`` knob); ``fused_config`` is the
        ``kernel="fused"`` tuning point (``repro.kernels.autotune``),
        ignored by the other kernels."""
        from ..exec.peel import PeelExecutor  # lazy: registry stays import-light

        return PeelExecutor(
            granularity=self.key.formulation,
            mode=mode or self.mode,
            backend=self.key.kernel,
            window=window,
            chunk=chunk,
            row_chunk=row_chunk,
            max_iters=max_iters,
            mesh=mesh,
            fused_config=fused_config,
        )


_REGISTRY: dict[BackendKey, BackendSpec] = {}


def register_backend(spec: BackendSpec, *, overwrite: bool = False) -> BackendSpec:
    """Add ``spec`` to the registry (axes validated; duplicates rejected)."""
    key = spec.key
    if key.formulation not in FORMULATIONS:
        raise ValueError(f"unknown formulation {key.formulation!r} ({FORMULATIONS})")
    if key.kernel not in KERNELS:
        raise ValueError(f"unknown kernel {key.kernel!r} ({KERNELS})")
    if key.layout not in LAYOUTS:
        raise ValueError(f"unknown layout {key.layout!r} ({LAYOUTS})")
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"backend {key} already registered")
    _REGISTRY[key] = spec
    return spec


def get_backend(key: Union[BackendKey, str, tuple]) -> BackendSpec:
    """Resolve a key, 3-tuple, or ``"formulation/kernel/layout"`` string."""
    if isinstance(key, str):
        parts = tuple(key.split("/"))
        if len(parts) != 3:
            raise ValueError(
                f"backend string must be 'formulation/kernel/layout', got {key!r}"
            )
        key = BackendKey(*parts)
    elif not isinstance(key, BackendKey):
        key = BackendKey(*key)
    spec = _REGISTRY.get(key)
    if spec is None:
        raise KeyError(
            f"no backend registered for {key}; available: "
            f"{[str(k) for k in available_backends()]}"
        )
    return spec


def available_backends() -> tuple[BackendKey, ...]:
    """Every registered key, in a stable order (the parity-test axis)."""
    return tuple(sorted(_REGISTRY))


def default_kernel() -> str:
    """Pallas on TPU, XLA everywhere else."""
    import jax

    return "pallas" if jax.default_backend() == "tpu" else "xla"


def choose_backend(
    stats: ImbalanceStats,
    *,
    kernel: str | None = None,
    layout: str = "aligned",
) -> BackendKey:
    """The auto rule: pick a formulation from the paper's imbalance stats.

    The coarse (row-task) formulation pads every row task to the longest
    one, so its cost inflates by ``1 / coarse_lane_efficiency``; the fine
    (nonzero-task) formulation splits rows into per-edge tasks and is
    insensitive to the degree tail (paper §III-A).  Coarse therefore only
    wins on near-balanced graphs where its fewer, fatter tasks amortize
    task overhead:

      coarse  iff  coarse_lane_efficiency >= 0.4 and coarse_imbalance <= 2.5

    (the road-network regime, where the paper measures fine/coarse ≈ 1×),
    otherwise fine.  The Pallas and fused kernels
    implement the fine formulation only, so ``kernel="pallas"`` or
    ``"fused"`` forces ``fine``.  On the hand-kernel path
    (``kernel="pallas"``, the TPU default) a *heavily* imbalanced bucket
    (``coarse_imbalance > 8``) is upgraded to the fused megakernel when
    its aligned variant is registered: a heavy degree tail means long
    peel tails with mostly-dead lanes, which is exactly the regime the
    fused kernel's dead-tile skipping pays in (its per-bucket autotuned
    configs come from ``repro.kernels.autotune``).  Every backend returns
    identical results, so a wrong guess costs time, never correctness.
    """
    kernel = kernel or default_kernel()
    balanced = stats.coarse_lane_efficiency >= 0.4 and stats.coarse_imbalance <= 2.5
    formulation = "coarse" if (balanced and kernel not in ("pallas", "fused")) else "fine"
    if (
        kernel == "pallas"
        and layout == "aligned"
        and stats.coarse_imbalance > 8.0
        and BackendKey("fine", "fused", layout) in _REGISTRY
    ):
        kernel = "fused"
    key = BackendKey(formulation, kernel, layout)
    if key not in _REGISTRY:
        raise KeyError(f"auto-chosen backend {key} is not registered")
    return key


def fallback_backends(key: Union[BackendKey, str, tuple]) -> tuple[BackendKey, ...]:
    """The degradation chain below ``key``, most-capable first.

    Every registered backend is bit-identical (the parity contract), so
    falling down this chain on a compile/kernel fault trades performance
    for availability, never correctness.  The chain steps down one axis
    at a time and **preserves the layout** (a mesh session requires
    ``aligned``; re-packing stays shape-compatible):

    1. ``fused -> pallas`` — same formulation, same layout: the
       megakernel that fails to build still has the unfused per-step
       Pallas twin;
    2. ``pallas -> xla`` — same formulation, same layout: a hand-written
       kernel that fails to build still has the XLA-ops twin;
    3. ``fine -> coarse`` on ``xla`` — the row-task formulation as the
       last resort (slower under imbalance, but always compilable).

    Only registered keys are returned, and never ``key`` itself.
    """
    key = get_backend(key).key
    chain: list[BackendKey] = []
    if key.kernel == "fused":
        chain.append(BackendKey(key.formulation, "pallas", key.layout))
    if key.kernel in ("pallas", "fused"):
        chain.append(BackendKey(key.formulation, "xla", key.layout))
    if key.formulation == "fine":
        chain.append(BackendKey("coarse", "xla", key.layout))
    return tuple(k for k in chain if k != key and k in _REGISTRY)


def _register_defaults() -> None:
    for layout in LAYOUTS:
        register_backend(
            BackendSpec(
                key=BackendKey("coarse", "xla", layout),
                mode="eager",
                description="row tasks (Alg. 2) on XLA ops",
            )
        )
        register_backend(
            BackendSpec(
                key=BackendKey("fine", "xla", layout),
                mode="eager",
                description="nonzero tasks (Alg. 3) on XLA scatter-adds",
            )
        )
        register_backend(
            BackendSpec(
                key=BackendKey("fine", "pallas", layout),
                mode="owner",
                description="nonzero tasks, collision-free Pallas TPU kernel",
            )
        )
    # The fused megakernel tiles the aligned layout's slot-banded lane
    # geometry; there is no contig variant (a contig pack interleaves
    # members' lanes, which its per-slot reductions cannot reshape).
    register_backend(
        BackendSpec(
            key=BackendKey("fine", "fused", "aligned"),
            mode="owner",
            description=(
                "persistent fused Pallas peel megakernel: support + prune + "
                "level bookkeeping in one launch per level, autotuned per "
                "bucket (repro.kernels.autotune)"
            ),
        )
    )


_register_defaults()
