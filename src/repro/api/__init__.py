"""repro.api — the one front door to every K-truss workload.

Declare *what* you want as :class:`TrussQuery` values; ``solve()`` (one
shot) or :class:`Session` (long-lived serving, micro-batching, futures)
lowers them through the :class:`Planner` onto interchangeable backends —
``formulation={coarse,fine} × kernel={xla,pallas} × layout={contig,
aligned}`` — registered in :mod:`repro.api.registry` and chosen per shape
bucket by an auto rule keyed on the paper's load-imbalance statistics::

    from repro.api import TrussQuery, solve

    dec = solve(TrussQuery.decompose(g))             # trussness per edge
    results = solve([TrussQuery.ktruss(g1, k=4),      # batched: one
                     TrussQuery.kmax(g2)])            # dispatch per bucket

The legacy entry points (``KTrussEngine``, ``TrussService``,
``StreamingTrussSession``) are thin adapters over this module.
"""

from ..core.truss import KTrussResult, TrussDecomposition
from .cache import (
    Bucket,
    CompileCache,
    bucket_for,
    build_peel,
    enable_persistent_cache,
)
from .errors import (
    CheckpointError,
    CompileError,
    DeviceError,
    InvalidGraphError,
    QueryFailedError,
    TrussError,
    TrussTimeoutError,
)
from .planner import Plan, PlannedBatch, Planner, QueryState, RequestStats
from .query import PLACEMENTS, WORKLOADS, TrussQuery
from .registry import (
    FORMULATIONS,
    KERNELS,
    LAYOUTS,
    BackendKey,
    BackendSpec,
    available_backends,
    choose_backend,
    default_kernel,
    fallback_backends,
    get_backend,
    register_backend,
)
from .session import QueryQueue, Session, TrussFuture, solve

__all__ = [
    # query surface
    "TrussQuery",
    "WORKLOADS",
    "PLACEMENTS",
    "solve",
    "Session",
    "TrussFuture",
    # failure taxonomy (repro.errors re-export)
    "TrussError",
    "InvalidGraphError",
    "CompileError",
    "DeviceError",
    "QueryFailedError",
    "TrussTimeoutError",
    "CheckpointError",
    # planner / lowering
    "Planner",
    "Plan",
    "PlannedBatch",
    "QueryState",
    "QueryQueue",
    "RequestStats",
    # backend registry
    "BackendKey",
    "BackendSpec",
    "FORMULATIONS",
    "KERNELS",
    "LAYOUTS",
    "register_backend",
    "get_backend",
    "available_backends",
    "choose_backend",
    "default_kernel",
    "fallback_backends",
    # shape buckets + compile cache
    "Bucket",
    "bucket_for",
    "build_peel",
    "CompileCache",
    "enable_persistent_cache",
    # result types
    "KTrussResult",
    "TrussDecomposition",
]
