"""Planner: lower declarative ``TrussQuery`` sets onto the device peel.

This is the ONE pack/cache/dispatch path every entry point shares — the
glue that used to be triplicated across ``service/service.py`` (batched
serving), ``core/truss.py`` (single-graph engine) and
``stream/session.py`` (streaming re-peels).  Lowering one batch:

1. **assign** — each query is canonicalized to a shape :class:`Bucket`
   and a registry :class:`BackendKey` (forced per query or per planner,
   else the imbalance-statistic auto rule of ``repro.api.registry``);
2. **pack**  — same-``(bucket, backend)`` queries are packed
   block-diagonally (``repro.graphs.pack``) in the backend's layout;
3. **dispatch** — the bucket's cached :class:`repro.exec.PeelExecutor`
   peels every member to completion in ONE device call (per-slot
   thresholds advance inside the compiled loop; ktruss members retire at
   their first fixed point, kmax/decompose peel to exhaustion, stream
   members re-peel only their frontier against frozen lanes);
4. **unpack** — each member's edge range is read back into its workload's
   result type.

The planner is deliberately stateless about queues and futures — that is
:class:`repro.api.Session`'s job — so ``solve()`` and the legacy
adapters can drive the same lowering from different control flows.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Optional

import numpy as np

from ..core.truss import KTrussResult, TrussDecomposition
from ..errors import DeviceError, TrussError
from ..graphs.pack import pack_problems
from ..graphs.stats import imbalance_stats
from ..obs import current_tracer, record_peel_batch
from ..obs import clock as obs_clock
from ..resilience.faults import inject
from .cache import Bucket, CompileCache, bucket_for
from .query import TrussQuery
from .registry import BackendKey, choose_backend, default_kernel, get_backend

__all__ = ["RequestStats", "QueryState", "PlannedBatch", "Plan", "Planner"]

_ids = itertools.count()


@dataclasses.dataclass
class RequestStats:
    """Per-query observability (exposed on the future)."""

    queue_time_s: float = 0.0  # submit -> batch formation
    pack_time_s: float = 0.0  # host-side block-diagonal packing (shared)
    device_time_s: float = 0.0  # the batch's single peel dispatch (shared)
    plan_time_s: float = 0.0  # bucket + backend assignment for THIS query
    compile_hit: bool = False  # did the batch reuse a cached executable
    bucket: Optional[Bucket] = None
    backend: Optional[BackendKey] = None
    batch_size: int = 0  # real members in the packed batch
    rounds: int = 0  # fixed-point levels THIS member peeled
    iterations: int = 0  # prune iterations while THIS member was live


@dataclasses.dataclass
class QueryState:
    """A submitted query with its planner assignment (queue entry)."""

    query: TrussQuery
    bucket: Bucket
    backend: BackendKey
    submitted_at: float = dataclasses.field(default_factory=obs_clock.now)
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    stats: RequestStats = dataclasses.field(default_factory=RequestStats)

    @property
    def group(self) -> tuple[Bucket, BackendKey]:
        """Batchable-together key: same bucket AND same backend."""
        return (self.bucket, self.backend)

    def time_remaining(self) -> float | None:
        """Seconds left of this query's deadline budget (``None`` = no
        deadline).  The ONE place deadline arithmetic happens — on the
        observability clock, so tests can fake time instead of sleeping."""
        return obs_clock.remaining(self.submitted_at, self.query.deadline_s)

    # Legacy aliases (the old service Request shape) ------------------- #
    @property
    def graph(self):
        return self.query.graph

    @property
    def workload(self) -> str:
        return self.query.workload

    @property
    def k(self) -> int:
        return self.query.k


@dataclasses.dataclass
class PlannedBatch:
    """One packed dispatch: same-(bucket, backend) queries on ``slots`` slots."""

    bucket: Bucket
    backend: BackendKey
    queries: list[QueryState]
    slots: int


@dataclasses.dataclass
class Plan:
    """A lowered query set (``Planner.plan``): batches in dispatch order."""

    batches: list[PlannedBatch]
    plan_time_s: float = 0.0

    @property
    def num_queries(self) -> int:
        return sum(len(b.queries) for b in self.batches)

    @property
    def num_dispatches(self) -> int:
        return len(self.batches)


class Planner:
    """Lowers queries onto ``(bucket, backend)`` batches and executes them."""

    def __init__(
        self,
        *,
        max_batch: int = 8,
        chunk: int = 256,
        kernel: str | None = None,
        layout: str | None = None,
        backend: BackendKey | str | None = None,
        mode: str | None = None,
        max_iters: int | None = None,
        mesh=None,
    ):
        if chunk & (chunk - 1):
            raise ValueError(f"chunk={chunk} must be a power of two")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self.chunk = int(chunk)
        self.kernel = kernel or default_kernel()
        self.mode = mode
        # None = the peel's provable iteration bound; an explicit cap that
        # fires raises instead of returning truncated results.
        self.max_iters = None if max_iters is None else int(max_iters)
        self.mesh = mesh
        if mesh is not None:
            if layout is not None and layout != "aligned":
                raise ValueError(
                    "mesh sharding needs layout='aligned' (slot blocks are "
                    "the shard boundaries)"
                )
            layout = "aligned"
            self._mesh_key = (
                tuple(mesh.axis_names),
                tuple(dict(mesh.shape).values()),
            )
        else:
            self._mesh_key = None
        self.layout = layout or "aligned"
        # Forced backend for every query (None = per-query auto rule).
        self.backend = get_backend(backend).key if backend is not None else None
        if (
            mesh is not None
            and self.backend is not None
            and self.backend.layout != "aligned"
        ):
            raise ValueError(
                f"backend {self.backend} has layout={self.backend.layout!r}, "
                "but mesh sharding needs layout='aligned'"
            )
        if (
            mesh is not None
            and self.backend is not None
            and self.backend.kernel == "fused"
        ):
            raise ValueError(
                f"backend {self.backend} keeps peel state kernel-resident "
                "and cannot shard across a mesh; use fine/pallas/aligned"
            )
        # Observability + shared caches.  Concurrent submitters (the
        # serving tier's connection threads) all assign through one
        # planner, so everything mutable below is lock-guarded.
        self._stats_lock = threading.Lock()
        self._slot_ids: dict[tuple[int, int], Any] = {}  # guarded-by: _stats_lock
        self.queries_planned = 0  # guarded-by: _stats_lock
        self.plan_time_s = 0.0  # guarded-by: _stats_lock
        self.backend_choices: dict[tuple[Bucket, BackendKey], int] = {}  # guarded-by: _stats_lock

    # ------------------------------------------------------------------ #
    # Assignment: query -> (bucket, backend)
    # ------------------------------------------------------------------ #
    def assign(self, query: TrussQuery) -> QueryState:
        """Canonicalize one query: shape bucket + registry backend."""
        t0 = obs_clock.now()
        with current_tracer().span("plan", workload=query.workload) as span:
            bucket = bucket_for(query.graph, chunk=self.chunk)
            if query.placement == "sharded" and self.mesh is None:
                raise ValueError("placement='sharded' needs a session mesh")
            if query.placement == "replicated" and self.mesh is not None:
                raise ValueError(
                    "placement='replicated' conflicts with the session mesh "
                    "(placement is per-session; open a mesh-less session)"
                )
            key = query.backend if query.backend is not None else self.backend
            if key is None:
                key = choose_backend(
                    imbalance_stats(query.graph),
                    kernel=self.kernel,
                    layout=self.layout,
                )
                if self.mesh is not None and key.kernel == "fused":
                    # The auto rule upgraded to the kernel-resident
                    # megakernel, but a mesh session must shard: step
                    # down to the unfused Pallas twin (bit-identical).
                    key = BackendKey(key.formulation, "pallas", key.layout)
            else:
                key = get_backend(key).key
            if self.mesh is not None and key.layout != "aligned":
                # The aligned layout is what makes slot boundaries shard
                # boundaries; a contig backend on a mesh would split member
                # graphs across devices.
                raise ValueError(
                    f"backend {key} has layout={key.layout!r}, but mesh "
                    "sharding needs layout='aligned'"
                )
            if self.mesh is not None and key.kernel == "fused":
                raise ValueError(
                    f"backend {key} keeps peel state kernel-resident and "
                    "cannot shard across a mesh; use fine/pallas/aligned"
                )
            span.attrs["backend"] = str(key)
        dt = obs_clock.now() - t0
        with self._stats_lock:
            self.queries_planned += 1
            self.plan_time_s += dt
            self.backend_choices[(bucket, key)] = (
                self.backend_choices.get((bucket, key), 0) + 1
            )
        state = QueryState(query=query, bucket=bucket, backend=key)
        state.stats.plan_time_s = dt
        state.stats.bucket = bucket
        state.stats.backend = key
        return state

    def plan(self, states: list[QueryState]) -> Plan:
        """Group assigned queries into dispatchable batches (FIFO within a
        ``(bucket, backend)`` group, at most ``max_batch`` members each)."""
        t0 = obs_clock.now()
        batches: list[PlannedBatch] = []
        by_group: dict[tuple, list[QueryState]] = {}
        order: list[tuple] = []
        for st in states:
            if st.group not in by_group:
                by_group[st.group] = []
                order.append(st.group)
            by_group[st.group].append(st)
        for group in order:
            members = by_group[group]
            for at in range(0, len(members), self.max_batch):
                chunk_members = members[at : at + self.max_batch]
                batches.append(
                    PlannedBatch(
                        bucket=group[0],
                        backend=group[1],
                        queries=chunk_members,
                        slots=self.max_batch,
                    )
                )
        dt = obs_clock.now() - t0
        with self._stats_lock:
            self.plan_time_s += dt  # batching is planning work too
        return Plan(batches=batches, plan_time_s=dt)

    # ------------------------------------------------------------------ #
    # Lowering: batch -> one device dispatch -> per-query results
    # ------------------------------------------------------------------ #
    def cache_variant(
        self,
        backend: BackendKey,
        bucket: Bucket | None = None,
        slots: int | None = None,
    ):
        """What beyond (bucket, slots) specializes the executable.

        Every planner attribute ``build_executor`` closes over MUST be
        folded in here (``mesh`` rides as its hashable ``_mesh_key``) —
        a closed-over scalar missing from this tuple is a recompile
        hazard: two configs would share one cache row and the second
        would silently reuse the first's executable.  The R2 lint
        (``repro.analysis.rules_recompile``) enforces the invariant.

        Fused backends additionally fold the bucket's autotuned kernel
        config (``repro.kernels.autotune.lookup``) into the key, so a
        newly tuned block/schedule compiles its own executable instead
        of silently reusing a stale one."""
        fused_sig = None
        if backend.kernel == "fused" and bucket is not None:
            cfg = self.fused_config_for(bucket, slots or self.max_batch)
            fused_sig = cfg.signature()
        return (
            backend,
            self.mode,
            self._mesh_key,
            self.chunk,
            self.max_iters,
            fused_sig,
        )

    def fused_config_for(self, bucket: Bucket, slots: int):
        """The fused tuning point for one (bucket, slots): the persisted
        autotune winner when one exists, the stock default otherwise —
        always clamped so the block divides the bucket's slot width."""
        from ..kernels import autotune

        return autotune.lookup(bucket, slots).clamp(bucket.nnz_pad)

    def build_executor(self, key: tuple[Bucket, int, Any]):
        """Compile-cache builder: one peel executor per cache key.

        ``chunk``/``max_iters`` are read from the key, not ``self`` —
        every non-static input that specializes the executable must
        arrive through the variant tuple (see :meth:`cache_variant`).
        ``self.mesh`` is the one closed-over object (unhashable), keyed
        by its ``_mesh_key`` fold."""
        bucket, _slots, (backend, mode, _mesh_key, chunk, max_iters, fused_sig) = key
        fused_config = None
        if fused_sig is not None:
            from ..kernels.autotune import FusedConfig

            fused_config = FusedConfig.from_signature(fused_sig)
        return get_backend(backend).make_executor(
            window=bucket.window,
            chunk=chunk,
            max_iters=max_iters,
            mesh=self.mesh,
            mode=mode,
            fused_config=fused_config,
        )

    def _slot_ids_for(self, batch: PlannedBatch, edge_ranges) -> np.ndarray:
        nnzp_total = batch.slots * batch.bucket.nnz_pad
        if batch.backend.layout == "aligned":
            # Lane blocks are slot blocks: one cached id vector per shape.
            cache_key = (batch.slots, batch.bucket.nnz_pad)
            with self._stats_lock:
                ids = self._slot_ids.get(cache_key)
            if ids is None:
                import jax.numpy as jnp

                ids = jnp.asarray(
                    np.repeat(
                        np.arange(batch.slots, dtype=np.int32),
                        batch.bucket.nnz_pad,
                    )
                )
                with self._stats_lock:
                    # Two threads may build the same vector concurrently;
                    # first writer wins so every batch shares one device
                    # array per shape.
                    ids = self._slot_ids.setdefault(cache_key, ids)
            return ids
        # Contig layout: members are prefix-packed, so slot ownership
        # depends on this batch's member sizes.  Pad-tail lanes are dead
        # (never alive, never frozen) — parking them on slot 0 is inert.
        ids = np.zeros(nnzp_total, np.int32)
        for i, (a, b) in enumerate(edge_ranges):
            ids[a:b] = i
        return ids

    def execute(self, batch: PlannedBatch, cache: CompileCache) -> list[Any]:
        """Run one planned batch — ONE device dispatch — and unpack results.

        Returns one result per query, in batch order: ``KTrussResult``
        (ktruss), ``int`` (kmax), ``TrussDecomposition`` (decompose), or
        the member's full ``(nnz,)`` trussness (stream_update).
        """
        bucket, backend, queries = batch.bucket, batch.backend, batch.queries
        tracer = current_tracer()
        qids = tuple(st.id for st in queries)
        # Fault sites (repro.resilience.faults): no-ops without an active
        # FaultPlan; under one, these are where the chaos suite makes the
        # dispatch fail in every taxonomy-distinct way.
        for i, st in enumerate(queries):
            inject(
                "poison",
                slot=i,
                query=st.id,
                queries=qids,
                bucket=bucket,
                backend=str(backend),
            )
        t0 = obs_clock.now()
        with tracer.span(
            "pack", members=len(queries), slots=batch.slots, layout=backend.layout
        ):
            packed = pack_problems(
                [st.query.graph for st in queries],
                slot_n=bucket.n_pad,
                slot_nnz=bucket.nnz_pad,
                slots=batch.slots,
                chunk=self.chunk,
                layout=backend.layout,
            )
        pack_dt = obs_clock.now() - t0
        with tracer.span("compile", backend=str(backend)) as span:
            inject("compile", bucket=bucket, backend=str(backend), queries=qids)
            exe, hit = cache.get(
                bucket, batch.slots, self.cache_variant(backend, bucket, batch.slots)
            )
            span.attrs["hit"] = hit
        for st in queries:
            st.stats.pack_time_s = pack_dt
            st.stats.compile_hit = hit

        slot_ids = self._slot_ids_for(batch, packed.edge_ranges)
        k0 = np.full(batch.slots, 3, np.int32)
        single_level = np.zeros(batch.slots, bool)
        for i, st in enumerate(queries):
            k0[i] = st.query.k
            single_level[i] = st.query.workload == "ktruss"

        # Streaming members peel only their affected frontier; the rest of
        # their lanes are frozen at the session's maintained trussness.
        # Ordinary members stay on the executor's defaults (fully alive,
        # nothing frozen) — zeros here reproduce those defaults exactly.
        alive0 = frozen = frozen_truss = None
        if any(st.query.workload == "stream_update" for st in queries):
            import jax.numpy as jnp

            # The default alive mask ("every real lane") is a pure
            # function of the pack's host-side edge ranges: pad lanes sit
            # outside every member's range (colidx == 0 there, see
            # graphs.pack).  Building it from edge_ranges avoids a
            # device->host colidx readback on the request path, which
            # would serialize packing with the previous dispatch.
            nnzp = int(packed.problem.colidx.shape[0])
            alive_np = np.zeros(nnzp, bool)
            for a, b in packed.edge_ranges:
                alive_np[a:b] = True
            frozen_np = np.zeros(nnzp, bool)
            ft_np = np.zeros(nnzp, np.int32)
            for st, (a, b) in zip(queries, packed.edge_ranges):
                if st.query.workload != "stream_update":
                    continue
                alive_np[a:b] = st.query.frontier
                frozen_np[a:b] = ~st.query.frontier
                ft_np[a:b] = st.query.frozen_truss
            alive0 = jnp.asarray(alive_np)
            frozen = jnp.asarray(frozen_np)
            frozen_truss = jnp.asarray(ft_np)

        # peel() synchronizes internally (its iteration-cap check reads back
        # the done flags), so dt covers the whole dispatch.
        inject("clock_skew", bucket=bucket, backend=str(backend), queries=qids)
        inject("device_oom", bucket=bucket, backend=str(backend), queries=qids)
        inject("dispatch", bucket=bucket, backend=str(backend), queries=qids)
        t0 = obs_clock.now()
        try:
            st_dev = exe.peel(
                packed.problem,
                slot_ids=slot_ids,
                k0=k0,
                single_level=single_level,
                alive0=alive0,
                frozen=frozen,
                frozen_truss=frozen_truss,
            )
        except TrussError:
            raise  # already typed (iteration cap, injected faults)
        except Exception as e:
            # Raw XLA/Pallas failures become typed device faults so the
            # resilience layer can retry/fall back on them.
            raise DeviceError(
                f"peel dispatch failed on backend {backend}: {e}",
                bucket=bucket,
                backend=backend,
                cause=e,
            ) from e
        dt = obs_clock.now() - t0

        with tracer.span("unpack", members=len(queries)):
            alive = np.asarray(st_dev.alive)
            support = np.asarray(st_dev.support)
            trussness = np.asarray(st_dev.trussness)
            kmax = np.asarray(st_dev.kmax)
            levels = np.asarray(st_dev.levels)
            iters = np.asarray(st_dev.iters)
            edges_alive = np.asarray(st_dev.edges_alive)

            results: list[Any] = []
            for i, (st, (a, b)) in enumerate(zip(queries, packed.edge_ranges)):
                st.stats.device_time_s = dt  # the batch's single dispatch
                st.stats.rounds = int(levels[i])
                st.stats.iterations = int(iters[i])
                workload = st.query.workload
                if workload == "ktruss":
                    member_alive = alive[a:b].copy()
                    results.append(
                        KTrussResult(
                            k=st.query.k,
                            alive=member_alive,
                            support=support[a:b].copy(),
                            iterations=int(iters[i]),
                            edges_remaining=int(member_alive.sum()),
                        )
                    )
                elif workload == "kmax":
                    results.append(int(kmax[i]))
                elif workload == "stream_update":
                    # Full member trussness: frontier lanes re-peeled, frozen
                    # lanes passed through by the peel (see exec.build_peel).
                    results.append(trussness[a:b].copy())
                else:
                    t = trussness[a:b].copy()
                    results.append(
                        TrussDecomposition(
                            trussness=t,
                            kmax=int(t.max(initial=0)) if t.size else 0,
                            levels=int(levels[i]),
                        )
                    )

        # The paper's load-imbalance statistic, observed at runtime: the
        # per-slot iteration spread of THIS dispatch, recorded per
        # (bucket, backend) so the auto rule can be calibrated from data.
        record_peel_batch(
            bucket=bucket,
            backend=backend,
            levels=levels,
            iters=iters,
            edges_alive=edges_alive,
            batch_size=len(queries),
            device_time_s=dt,
        )
        return results

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """Planning observability: overhead per query + chosen backends."""
        from .cache import bucket_str

        with self._stats_lock:
            queries_planned = self.queries_planned
            plan_time_s = self.plan_time_s
            choices = dict(self.backend_choices)
        per_query_us = (
            1e6 * plan_time_s / queries_planned if queries_planned else 0.0
        )
        return {
            "queries_planned": queries_planned,
            "plan_time_s": round(plan_time_s, 6),
            "plan_us_per_query": round(per_query_us, 2),
            # One row per (bucket, backend) choice — the same bucket can
            # legitimately map to several backends under the auto rule.
            "backends": [
                {
                    "bucket": bucket_str(b),
                    "backend": str(k),
                    "queries": n,
                }
                for (b, k), n in sorted(choices.items(), key=lambda kv: -kv[1])
            ],
        }
