"""Session + solve(): the one front door over the planner/backend registry.

``solve(queries)`` is the one-shot form: assign, batch, dispatch, return
results in submission order.  :class:`Session` is the serving form: a
long-lived queue + compile cache where queries from many callers coalesce
into shared dispatches (micro-batching), futures resolve on ``flush()``
or transparently on ``result()`` (which drives only the owning query's
``(bucket, backend)`` group), and streaming sessions ride the same queue.

Everything the old ``KTrussEngine`` / ``TrussService`` /
``StreamingTrussSession`` trio did separately is an adapter over this
module now; the lowering itself lives in :class:`repro.api.Planner`.
"""

from __future__ import annotations

import contextlib
import threading
from collections import deque
from typing import Any

import numpy as np

from ..graphs.csr import CSRGraph
from ..obs import MetricsRegistry, Observability
from ..obs import clock as obs_clock
from ..resilience.faults import FaultPlan, use_plan
from ..resilience.retry import RetryPolicy
from ..resilience.runner import ResilientRunner
from .cache import CompileCache, bucket_for, enable_persistent_cache
from .errors import TrussTimeoutError
from .planner import PlannedBatch, Planner, QueryState
from .query import TrussQuery
from .registry import BackendKey

__all__ = ["QueryQueue", "TrussFuture", "Session", "solve"]

_UNSET = object()  # result(): "no timeout given" vs. explicit None


class QueryQueue:
    """Arrival-ordered queue with same-group, deadline-aware batch formation.

    A batch is formed by taking one pending query's ``(bucket, backend)``
    group and draining up to ``max_batch`` same-group queries (FIFO within
    the group, so no query starves behind an endless stream of other
    groups).  With no explicit group the *most urgent* pending query picks
    it: earliest absolute deadline first, arrival order among undeadlined
    queries — LLM-serving-style deadline awareness at the batch former.
    """

    def __init__(self, *, max_batch: int = 8):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self._pending: deque[QueryState] = deque()

    def __len__(self) -> int:
        return len(self._pending)

    def enqueue(self, state: QueryState) -> None:
        self._pending.append(state)

    def drain(self) -> list[QueryState]:
        """Remove and return every pending query (arrival order)."""
        states = list(self._pending)
        self._pending.clear()
        return states

    @staticmethod
    def _urgency(state: QueryState) -> tuple[float, int]:
        d = state.query.deadline_s
        absolute = state.submitted_at + d if d is not None else float("inf")
        return (absolute, state.id)

    def discard(self, state: QueryState) -> bool:
        """Remove one specific pending query (shed-on-timeout's reclaim).

        Matches by **identity**, not equality — ``QueryState`` is a
        dataclass over numpy-bearing queries, so ``==`` is both wrong
        (distinct queries can compare equal) and broken (ambiguous array
        truth).  Returns whether the query was still pending.
        """
        n = len(self._pending)
        self._pending = deque(st for st in self._pending if st is not state)
        return len(self._pending) != n

    def next_batch(self, group=None) -> list[QueryState]:
        """Drain up to ``max_batch`` queries sharing one group."""
        if not self._pending:
            return []
        if group is None:
            group = min(self._pending, key=self._urgency).group
        batch: list[QueryState] = []
        keep: deque[QueryState] = deque()
        while self._pending:
            st = self._pending.popleft()
            if st.group == group and len(batch) < self.max_batch:
                batch.append(st)
            else:
                keep.append(st)
        self._pending = keep
        now = obs_clock.now()
        for st in batch:
            st.stats.queue_time_s = now - st.submitted_at
            st.stats.batch_size = len(batch)
        return batch


class TrussFuture:
    """Handle to a submitted query; resolves when its batch runs."""

    def __init__(self, session: "Session", state: QueryState):
        self._session = session
        self._state = state
        self._result: Any = None
        self._error: BaseException | None = None
        self._done = False

    @property
    def request(self) -> QueryState:
        return self._state

    @property
    def query(self) -> TrussQuery:
        return self._state.query

    @property
    def stats(self):
        return self._state.stats

    def done(self) -> bool:
        return self._done

    def result(self, timeout: float | None = _UNSET) -> Any:
        """Resolve this query, driving only its own ``(bucket, backend)``
        group — other groups' queued work stays queued for their own
        flush/poll.

        ``timeout`` bounds the time spent driving the queue (checked
        between batch dispatches — one in-flight dispatch is never
        interrupted); ``timeout=0`` is non-blocking.  Left unset it
        defaults to the query's remaining ``deadline_s`` budget (if any)
        — :meth:`QueryState.time_remaining`, the one deadline rule on the
        observability clock; an explicit ``timeout=None`` waits until
        resolved.  On expiry raises :class:`TrussTimeoutError` carrying
        the bucket and the queue depth at expiry; under the session's
        default ``shed_on_timeout=True`` the query is also marked dead —
        its queue slot is reclaimed for batch-mates (no leak) and later
        ``result()`` calls re-raise the same error instead of
        re-dispatching abandoned work.
        """
        if timeout is _UNSET:
            timeout = self._state.time_remaining()
        t0 = obs_clock.now()
        session = self._session
        while not self._done:
            waited = obs_clock.now() - t0
            if timeout is not None and waited >= timeout:
                session._record_deadline_miss(self._state, waited)
                shed = session.shed_on_timeout
                depth = session.queue_depth()
                err = TrussTimeoutError(
                    f"query {self._state.id} ({self._state.query.workload}) "
                    f"unresolved after {waited:.3f}s (timeout={timeout}s); "
                    f"bucket={self._state.bucket}, "
                    f"queue_depth={depth}"
                    + ("; query shed" if shed else ""),
                    bucket=self._state.bucket,
                    queue_depth=depth,
                    request_id=self._state.id,
                    waited_s=waited,
                    shed=shed,
                )
                if shed:
                    session._shed(self._state, err)
                raise err
            batch = session._form_batch(group=self._state.group)
            if batch:
                session._run_batch(session._planned(batch))
                continue
            with session._cv:
                if self._done:
                    break
                if self._state.id in session._inflight:
                    # Another thread's dispatch owns this query's batch;
                    # wait for its resolution.  The wait is bounded so the
                    # deadline check above still runs on the obs clock.
                    session._cv.wait(timeout=0.05)
                    continue
                raise RuntimeError(
                    f"query {self._state.id} is unresolved but not queued"
                )
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result: Any) -> None:
        self._result = result
        self._done = True

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._done = True


class Session:
    """Long-lived query session: one queue, one planner, one compile cache.

    Config (all optional):
      backend: force one registry backend for every query
        (``BackendKey`` / ``"fine/xla/aligned"``); ``None`` = per-query
        auto rule on the paper's imbalance statistics.
      kernel / layout: defaults for the auto rule
        (kernel ``None`` = pallas on TPU, xla elsewhere).
      mode: override the backend's update dataflow (``eager``/``owner``).
      max_batch: packed slots per dispatch (batches pad to this, so the
        executable is independent of batch fullness).
      chunk: task-chunk width (power of two).
      max_iters: explicit peel iteration cap (None = provable bound).
      mesh: shard packed slot blocks across devices
        (``repro.distributed.slot_mesh``); forces the aligned layout.
      cache_dir: persist compiled executables across processes.
      trace: span tracing — ``True`` records in memory, a path string
        records AND auto-exports Chrome trace JSON there after
        ``solve()``/``flush()``; ``None`` (default) consults the
        ``REPRO_TRACE=path`` env var; ``False`` forces off (a shared
        no-op tracer: near-zero overhead).
      metrics: route this session's metrics into an existing
        :class:`repro.obs.MetricsRegistry` (default: a private registry
        chained to the process-global one).
      faults: a :class:`repro.resilience.FaultPlan` injected at the
        planner's fault sites for this session's dispatches (``None``
        consults the ``REPRO_FAULTS`` env var; production leaves both
        unset — the hooks are no-ops).
      retry: the :class:`repro.resilience.RetryPolicy` governing
        retry/backoff, registry fallback, and batch bisection (default
        policy: 3 attempts, exponential backoff, fallback + bisect on).
      shed_on_timeout: when a ``result(timeout=...)`` expires, mark the
        query dead and reclaim its queue slot (default).  ``False``
        restores the legacy leak-prone behavior where a timed-out query
        stays queued and a later ``result()`` may still resolve it.
    """

    def __init__(
        self,
        *,
        backend: BackendKey | str | None = None,
        kernel: str | None = None,
        layout: str | None = None,
        mode: str | None = None,
        max_batch: int = 8,
        chunk: int = 256,
        max_iters: int | None = None,
        mesh=None,
        cache_dir: str | None = None,
        trace: bool | str | None = None,
        metrics: MetricsRegistry | None = None,
        faults: FaultPlan | None = None,
        retry: RetryPolicy | None = None,
        shed_on_timeout: bool = True,
    ):
        if cache_dir is not None:
            enable_persistent_cache(cache_dir)
        if mesh is not None:
            mesh_size = int(np.prod(list(dict(mesh.shape).values())))
            if max_batch % mesh_size:
                raise ValueError(
                    f"max_batch={max_batch} must divide evenly over the "
                    f"mesh's {mesh_size} devices (slots shard whole)"
                )
        self.obs = Observability(trace=trace, metrics=metrics)
        self.planner = Planner(
            max_batch=max_batch,
            chunk=chunk,
            kernel=kernel,
            layout=layout,
            backend=backend,
            mode=mode,
            max_iters=max_iters,
            mesh=mesh,
        )
        self.cache = CompileCache(
            self.planner.build_executor, metrics=self.obs.metrics
        )
        # Thread safety: the RPC serving tier drives one Session from many
        # connection threads, so the batch former, the futures map and the
        # in-flight set share one condition variable.  Batch *dispatches*
        # deliberately run outside the lock (device time dominates; only
        # queue/future state needs exclusion).
        self._cv = threading.Condition()
        self.queue = QueryQueue(max_batch=max_batch)  # guarded-by: _cv
        self._futures: dict[int, TrussFuture] = {}  # guarded-by: _cv
        self._inflight: set[int] = set()  # guarded-by: _cv
        self.faults = faults if faults is not None else FaultPlan.from_env()
        self.retry = retry or RetryPolicy()
        self.shed_on_timeout = bool(shed_on_timeout)
        self.runner = ResilientRunner(
            self._dispatch_once, policy=self.retry, metrics=self.obs.metrics
        )

    # Convenience mirrors of the planner's config ----------------------- #
    @property
    def max_batch(self) -> int:
        return self.planner.max_batch

    @property
    def chunk(self) -> int:
        return self.planner.chunk

    @property
    def mesh(self):
        return self.planner.mesh

    # Serving counters — views over the session's metrics registry ------ #
    @property
    def requests_served(self) -> int:
        return int(self.obs.metrics.value("requests_served"))

    @property
    def batches_run(self) -> int:
        return int(self.obs.metrics.value("batches_run"))

    @property
    def device_dispatches(self) -> int:
        return int(self.obs.metrics.value("dispatches"))

    @property
    def device_time_s(self) -> float:
        return self.obs.metrics.value("device_seconds_total")

    @property
    def deadline_misses(self) -> int:
        return int(self.obs.metrics.value("deadline_misses"))

    def _counter_total(self, name: str) -> int:
        """Sum a counter across every label series (e.g. retries{backend=})."""
        prefix = name + "{"
        return int(
            sum(
                v
                for k, v in self.obs.metrics.snapshot()["counters"].items()
                if k == name or k.startswith(prefix)
            )
        )

    # Resilience counters (repro.resilience.runner / faults) ------------ #
    @property
    def retries(self) -> int:
        return self._counter_total("retries")

    @property
    def backend_fallbacks(self) -> int:
        return self._counter_total("backend_fallbacks")

    @property
    def queries_quarantined(self) -> int:
        return int(self.obs.metrics.value("queries_quarantined"))

    @property
    def batch_bisects(self) -> int:
        return int(self.obs.metrics.value("batch_bisects"))

    @property
    def queries_failed(self) -> int:
        return int(self.obs.metrics.value("queries_failed"))

    @property
    def queries_shed(self) -> int:
        return int(self.obs.metrics.value("queries_shed"))

    @property
    def faults_injected(self) -> int:
        return self._counter_total("faults_injected")

    # ------------------------------------------------------------------ #
    # Submission
    # ------------------------------------------------------------------ #
    def submit(self, query: TrussQuery) -> TrussFuture:
        """Assign (bucket + backend) and enqueue one declarative query."""
        with self.obs.activate():
            state = self.planner.assign(query)
        fut = TrussFuture(self, state)
        with self._cv:
            self._futures[state.id] = fut
            self.queue.enqueue(state)
            depth = len(self.queue)
        self.obs.metrics.set_gauge("queue_depth", depth)
        return fut

    def solve(self, queries) -> list[Any]:
        """Submit ``queries``, lower everything queued through one
        declarative :meth:`Planner.plan`, dispatch batch by batch, and
        return results in submission order.

        (The serving path — ``flush``/``poll``/``result()`` — forms
        batches from the queue instead, which is what makes it
        deadline-aware; ``solve()`` waits for everything anyway.)
        """
        queries = list(queries)
        with self.obs.activate(), self.obs.tracer.span("solve", queries=len(queries)):
            futs = [self.submit(q) for q in queries]
            with self._cv:
                states = self.queue.drain()
                self._inflight.update(st.id for st in states)
            now = obs_clock.now()
            plan = self.planner.plan(states)
            for batch in plan.batches:
                for st in batch.queries:
                    st.stats.queue_time_s = now - st.submitted_at
                    st.stats.batch_size = len(batch.queries)
                self._run_batch(batch)
            results = [f.result() for f in futs]
        self.obs.export_trace()  # no-op unless a trace path is configured
        return results

    def open_stream(
        self,
        g: CSRGraph,
        trussness: np.ndarray | None = None,
        *,
        cache_triangles: bool = True,
    ):
        """Open a :class:`repro.stream.StreamingTrussSession` on this session.

        Runs the initial full decompose through the ordinary batched path
        unless ``trussness`` is supplied; subsequent ``update()`` batches
        are frontier-bounded ``stream_update`` queries on this queue.
        """
        from ..stream.session import StreamingTrussSession  # lazy: no cycle

        return StreamingTrussSession(
            self, g, trussness=trussness, cache_triangles=cache_triangles
        )

    def executor_for(self, g: CSRGraph):
        """The compiled peel executor a query on ``g`` lowers onto, built
        on first use.  Needs a session-pinned backend (auto-rule sessions
        choose per query).  This is the legacy engine's hook to the
        executor's ``dispatches`` counter (the one-dispatch contract)."""
        if self.planner.backend is None:
            raise ValueError(
                "executor_for needs a session-pinned backend= (the auto "
                "rule chooses per query)"
            )
        bucket = bucket_for(g, chunk=self.planner.chunk)
        exe, _ = self.cache.get(
            bucket,
            self.planner.max_batch,
            self.planner.cache_variant(
                self.planner.backend, bucket, self.planner.max_batch
            ),
        )
        return exe

    # ------------------------------------------------------------------ #
    # Execution
    # ------------------------------------------------------------------ #
    def poll(self) -> int:
        """Run at most one micro-batch; returns how many queries resolved."""
        batch = self._form_batch()
        if not batch:
            return 0
        return self._run_batch(self._planned(batch))

    def queue_depth(self) -> int:
        """Pending-query count, read under the session lock."""
        with self._cv:
            return len(self.queue)

    def flush(self) -> int:
        """Drain the queue; returns how many queries resolved."""
        n = 0
        while self.queue_depth():
            n += self.poll()
        self.obs.export_trace()  # no-op unless a trace path is configured
        return n

    def drain(self, timeout_s: float | None = None) -> int:
        """Serve everything pending to completion — the serving tier's
        pre-shutdown hook.  Flushes the queue, then waits out batches in
        flight on other threads (up to ``timeout_s``; ``None`` = until
        they resolve).  Returns how many queries this call resolved."""
        n = self.flush()
        deadline = (
            obs_clock.now() + timeout_s if timeout_s is not None else None
        )
        with self._cv:
            while self._inflight:
                if deadline is not None and obs_clock.now() >= deadline:
                    break
                self._cv.wait(timeout=0.05)
        return n

    def _form_batch(self, group=None) -> list[QueryState]:
        """Atomically dequeue one micro-batch and mark it in flight."""
        with self._cv:
            batch = self.queue.next_batch(group=group)
            self._inflight.update(st.id for st in batch)
        return batch

    def _planned(self, batch: list[QueryState]) -> PlannedBatch:
        """Wrap a queue-formed (single-group) batch for the planner."""
        return PlannedBatch(
            bucket=batch[0].bucket,
            backend=batch[0].backend,
            queries=batch,
            slots=self.planner.max_batch,
        )

    def _dispatch_once(self, planned: PlannedBatch) -> list[Any]:
        """One attempt at one packed dispatch (the runner's retry unit).

        Activates the session's obs sinks and fault plan around the
        planner, and counts the per-dispatch serving metrics only on
        success — a retried dispatch is one dispatch, not two.
        """
        ctx = contextlib.ExitStack()
        ctx.enter_context(self.obs.activate())
        if self.faults is not None:
            ctx.enter_context(use_plan(self.faults))
        with ctx:
            results = self.planner.execute(planned, self.cache)
        # execute() stamps the dispatch's own duration on every member;
        # host-side packing is accounted separately (stats.pack_time_s).
        batch = planned.queries
        m = self.obs.metrics
        m.inc("device_seconds_total", batch[0].stats.device_time_s)
        m.inc("dispatches")
        m.inc("batches_run")
        m.inc("requests_served", len(batch))
        m.observe(
            "batch_occupancy",
            len(batch) / planned.slots,
            buckets=(0.125, 0.25, 0.5, 0.75, 1.0),
        )
        return results

    def _shed(self, state: QueryState, err: BaseException) -> None:
        """Mark a timed-out query dead: reclaim its queue slot, fail its
        future, count the shed.  The batch former never sees it again."""
        with self._cv:
            self.queue.discard(state)
            fut = self._futures.pop(state.id, None)
            self._inflight.discard(state.id)
            if fut is not None:
                fut._fail(err)
            depth = len(self.queue)
            self._cv.notify_all()
        self.obs.metrics.inc("queries_shed")
        self.obs.metrics.set_gauge("queue_depth", depth)

    def _run_batch(self, planned: PlannedBatch) -> int:
        batch = planned.queries
        # The batch was already dequeued, so its futures must always end
        # up resolved or failed — stranded-unresolvable is the one
        # forbidden outcome.  The runner turns member/device/compile
        # faults into per-query outcomes (quarantine, retry, fallback,
        # bisect); anything non-taxonomy still fails everyone and
        # propagates (a genuine bug should stay loud).
        try:
            outcomes = self.runner.run(planned)
        except Exception as e:
            with self._cv:
                for st in batch:
                    fut = self._futures.pop(st.id, None)
                    self._inflight.discard(st.id)
                    if fut is not None:
                        fut._fail(e)
                self._cv.notify_all()
            raise
        m = self.obs.metrics
        with self._cv:
            for out in outcomes:
                fut = self._futures.pop(out.state.id, None)
                self._inflight.discard(out.state.id)
                if fut is None:
                    continue  # shed mid-flight: its future already failed
                if out.ok:
                    fut._resolve(out.result)
                else:
                    m.inc("queries_failed")
                    fut._fail(out.error)
            depth = len(self.queue)
            self._cv.notify_all()
        m.set_gauge("queue_depth", depth)
        return len(batch)

    def _record_deadline_miss(self, state: QueryState, waited_s: float) -> None:
        self.obs.metrics.inc("deadline_misses")
        self.obs.tracer.instant(
            "deadline-miss",
            query=state.id,
            workload=state.query.workload,
            waited_s=round(waited_s, 6),
        )

    # ------------------------------------------------------------------ #
    # Observability
    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """Serving counters — a view over ``self.obs.metrics`` (the keys
        are locked by ``tests/test_obs.py``; extend, don't rename)."""
        return {
            "requests_served": self.requests_served,
            "batches_run": self.batches_run,
            "device_dispatches": self.device_dispatches,
            "deadline_misses": self.deadline_misses,
            "pending": self.queue_depth(),
            "device_time_s": round(self.device_time_s, 6),
            "retries": self.retries,
            "backend_fallbacks": self.backend_fallbacks,
            "queries_quarantined": self.queries_quarantined,
            "batch_bisects": self.batch_bisects,
            "queries_failed": self.queries_failed,
            "queries_shed": self.queries_shed,
            "faults_injected": self.faults_injected,
            **{f"cache_{k}": v for k, v in self.cache.stats.row().items()},
            **{f"planner_{k}": v for k, v in self.planner.stats().items()},
        }

    def metrics_snapshot(self) -> dict:
        """JSON snapshot of this session's metrics registry."""
        return self.obs.metrics_snapshot()

    def prometheus_text(self) -> str:
        """Prometheus text exposition of this session's metrics."""
        return self.obs.prometheus_text()

    def export_trace(self, path: str | None = None) -> str | None:
        """Write the session's Chrome trace JSON (see ``Session(trace=)``)."""
        return self.obs.export_trace(path)


def solve(queries, **session_kwargs) -> Any:
    """One-shot front door: lower and run a set of declarative queries.

    ``queries`` is a :class:`TrussQuery` or an iterable of them; results
    come back in submission order (a lone query returns its lone result).
    Session knobs (``backend=``, ``mesh=``, ``max_batch=``,
    ``trace="trace.json"``, ...) pass through — see :class:`Session`;
    with a ``trace`` path the Chrome trace JSON is written before
    returning.
    """
    single = isinstance(queries, TrussQuery)
    qs = [queries] if single else list(queries)
    results = Session(**session_kwargs).solve(qs)
    return results[0] if single else results
