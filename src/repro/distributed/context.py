"""Trace-time sharding context: how model code learns about the mesh.

Model definitions stay mesh-agnostic; distributed layers (MoE expert
parallelism) consult this context at *trace* time.  The launcher enters
``sharding_context(mesh)`` around jit/lower, and ``moe_apply`` picks the
shard_map EP path iff a context with a model axis is active.

Why a context and not a parameter: the mesh is orthogonal to the model's
math and threading it through every ``apply`` signature couples all layers
to distribution concerns; this is the pattern MaxText uses via its global
mesh, made explicit and scoped.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator

from jax.sharding import Mesh

__all__ = ["ShardCtx", "sharding_context", "current_shard_ctx"]

_ACTIVE: list["ShardCtx"] = []


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    model_axis: str = "model"
    dp_axes: tuple[str, ...] = ("data",)
    fsdp_axes: tuple[str, ...] = ("data",)

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]


@contextlib.contextmanager
def sharding_context(mesh: Mesh | None) -> Iterator[ShardCtx | None]:
    """Activate a sharding context (None = explicit single-device scope)."""
    if mesh is None:
        yield None
        return
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    ctx = ShardCtx(mesh=mesh, model_axis="model", dp_axes=dp, fsdp_axes=dp)
    _ACTIVE.append(ctx)
    try:
        yield ctx
    finally:
        _ACTIVE.pop()


def current_shard_ctx() -> ShardCtx | None:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain_cache(x):
    """Pin a (B, S, KV, dh) cache block's sharding inside the layer scan.

    The decode layer-scan's stacked cache outputs (ys) otherwise lose the
    model-axis sharding chosen by state_specs and materialize dp-only
    (kimi decode_32k: 61 × 470 MB = 28.7 GB/device — EXPERIMENTS §Perf).
    Mirrors the state_specs KV candidates with divisibility fallbacks.
    """
    ctx = current_shard_ctx()
    if ctx is None or x.ndim != 4:
        return x
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = ctx.dp_axes
    mdl = ctx.model_axis
    dp_size = int(np.prod([ctx.mesh.shape[a] for a in dp])) if dp else 1
    m_size = ctx.mesh.shape[mdl]
    b, s, kv, dh = x.shape
    batch = dp if (dp and b % dp_size == 0) else None
    if kv % m_size == 0:
        spec = P(batch, None, mdl, None)
    elif dh % m_size == 0:
        spec = P(batch, None, None, mdl)
    elif s % m_size == 0:
        spec = P(batch, mdl, None, None)
    else:
        spec = P(batch, None, None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def constrain_seq(x):
    """Megatron-style sequence-parallel residual: (B, S, D) sharded on S
    over the model axis.  Applied at layer-group boundaries for the giant
    MoE archs so the per-layer saved activation stacks (bf16 + the f32
    copies XLA pre-converts for the backward) shard 16× instead of
    replicating over 'model' (kimi train: 10.8 GB of stacks — §Perf).
    XLA inserts the all-gather (body entry) / reduce-scatter (body exit)
    pair this implies — the standard SP collective trade.
    """
    ctx = current_shard_ctx()
    if ctx is None or x.ndim != 3:
        return x
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = ctx.dp_axes
    m = ctx.model_axis
    dp_size = int(np.prod([ctx.mesh.shape[a] for a in dp])) if dp else 1
    if x.shape[1] % ctx.mesh.shape[m]:
        return constrain_batch(x)
    batch = dp if (dp and x.shape[0] % dp_size == 0) else None
    spec = P(batch, m, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def constrain_heads(x):
    """Pin a (B, S, H, dh) attention operand: batch on DP, heads on the
    model axis when divisible, head_dim NEVER sharded.

    Used on q/k after RoPE: the KV-cache's fallback dh-sharding otherwise
    back-propagates into the score einsum's contraction (iteration 12),
    while a plain batch-only pin would *replicate the heads* and cost
    head-parallel attention 16× redundant compute (iteration 13).
    """
    ctx = current_shard_ctx()
    if ctx is None or x.ndim != 4:
        return x
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = ctx.dp_axes
    m = ctx.model_axis
    dp_size = int(np.prod([ctx.mesh.shape[a] for a in dp])) if dp else 1
    batch = dp if (dp and x.shape[0] % dp_size == 0) else None
    heads = m if x.shape[2] % ctx.mesh.shape[m] == 0 else None
    spec = P(batch, None, heads, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def constrain_batch(x):
    """Anchor an activation's leading (batch) dim to the DP axes.

    GSPMD's einsum conflict resolution can silently *replicate* the batch
    when FSDP shards a weight's contraction dim on the same mesh axes that
    shard the batch (observed: full-batch f32 attention scores + an
    all-reduce over 'model' in the smollm dry-run — EXPERIMENTS §Perf).
    Explicit with_sharding_constraint at stream boundaries pins the batch
    sharding so the partitioner all-gathers weights (ZeRO-3 semantics)
    instead of activations.  No-op outside a sharding context or when the
    batch doesn't divide.
    """
    ctx = current_shard_ctx()
    if ctx is None or ctx.dp_axes == ():
        return x
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = ctx.dp_axes
    size = int(np.prod([ctx.mesh.shape[a] for a in dp]))
    if x.ndim == 0 or x.shape[0] % size:
        return x
    spec = P(dp, *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
