"""K-truss sharding: packed slot blocks across a device mesh.

The serving layer packs B same-bucket graphs block-diagonally, so the
packed arrays have a leading slot-block structure: edge lanes
``[i * slot_nnz, (i+1) * slot_nnz)`` belong to slot i (``layout="aligned"``
packing), and slots never interact.  Slot boundaries are therefore natural
shard boundaries — sharding every edge-dim array over a 1-D ``"slots"``
mesh axis gives each device a subset of whole member graphs, with no
cross-device triangle closing.  Vertex-dim arrays (``rowptr``, ``deg``,
``urowptr``, ``udeg``) stay replicated: they are O(n) index metadata, tiny
next to the O(nnz·window) intersection state.

Verified on CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(see ``tests/test_exec_peel.py``): sharded results are bit-identical to
unsharded — all peel state is integer/bool, so GSPMD's partitioning cannot
introduce rounding differences.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.eager_fine import FineProblem

__all__ = ["SLOT_AXIS", "slot_mesh", "peel_problem_specs", "shard_peel_args"]

SLOT_AXIS = "slots"


def slot_mesh(num_devices: int | None = None) -> Mesh:
    """1-D mesh over the ``"slots"`` axis (all local devices by default)."""
    devs = jax.devices()
    d = int(num_devices) if num_devices is not None else len(devs)
    if d > len(devs):
        raise ValueError(f"requested {d} devices, have {len(devs)}")
    return jax.make_mesh((d,), (SLOT_AXIS,))


def peel_problem_specs() -> list[P]:
    """PartitionSpec per :class:`FineProblem` field (field order).

    Edge-dim arrays shard over ``"slots"``; vertex-dim arrays replicate.
    Returned as a plain list (PartitionSpec is a tuple subclass, so a
    FineProblem of specs would be flattened *into* the specs by pytree
    maps).
    """
    edge = P(SLOT_AXIS)
    rep = P()
    return [
        rep,  # rowptr   (n+1,)
        edge,  # colidx   (nnzp,)
        edge,  # edge_row (nnzp,)
        rep,  # deg      (n+1,)
        rep,  # urowptr  (n+1,)
        edge,  # ucolidx  (unnzp,)
        edge,  # u2d      (unnzp,)
        edge,  # uedge_row(unnzp,)
        rep,  # udeg     (n+1,)
    ]


def shard_peel_args(
    mesh: Mesh,
    p: FineProblem,
    slot_ids: jax.Array,
    k0: jax.Array,
    single_level: jax.Array,
    alive0: jax.Array,
    frozen: jax.Array,
    frozen_truss: jax.Array,
):
    """Place peel inputs on ``mesh``: slot blocks sharded, metadata replicated.

    Requires the slot count (and hence every edge-dim length, which is a
    slot multiple) to divide the mesh size, so each device owns whole
    slots.
    """
    d = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    num_slots = int(k0.shape[0])
    nnzp = int(p.colidx.shape[0])
    if num_slots % d or nnzp % d:
        raise ValueError(
            f"mesh size {d} must evenly divide slots={num_slots} "
            f"(and nnz_pad={nnzp}) so each device owns whole slots"
        )

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    p = FineProblem(*(put(x, s) for x, s in zip(p, peel_problem_specs())))
    edge, slot = P(SLOT_AXIS), P(SLOT_AXIS)
    return (
        p,
        put(slot_ids, edge),
        put(k0, slot),
        put(single_level, slot),
        put(alive0, edge),
        put(frozen, edge),
        put(frozen_truss, edge),
    )
