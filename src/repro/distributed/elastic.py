"""Elastic mesh derivation + checkpoint resharding (fault tolerance).

At 1000+ nodes, failures leave you with a different device count than you
started with.  Elasticity here is two mechanisms:

  * :func:`derive_mesh` — given whatever devices survive, build the largest
    well-formed (data, model) or (pod, data, model) mesh (model axis kept
    at the configured TP width when possible; data axis absorbs the rest;
    leftover devices idle as hot spares).
  * checkpoint restore with resharding — ``repro.train.checkpoint`` stores
    host-side arrays + the spec tree; restoring onto a *different* mesh
    simply re-applies the sharding rules for the new mesh (the rules are
    divisibility-aware, so a smaller model axis re-fits automatically).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["derive_mesh", "mesh_shape_for", "spare_devices"]


def mesh_shape_for(
    n: int, *, model_width: int = 16, pod_size: int | None = 256
) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Pure planning function: (shape, axis_names) for ``n`` devices.

    Shrinks ``model_width`` by powers of two until it divides n (elastic
    downscale); uses the 3-axis pod layout when ≥ 2 full pods survive.
    """
    width = model_width
    while width > 1 and n % width:
        width //= 2
    n_cells = n // width
    if pod_size and n >= 2 * pod_size and pod_size % width == 0:
        per_pod = pod_size // width
        pods = n_cells // per_pod
        return (pods, per_pod, width), ("pod", "data", "model")
    return (n_cells, width), ("data", "model")


def derive_mesh(
    devices=None,
    *,
    model_width: int = 16,
    pod_size: int | None = 256,
) -> Mesh:
    """Largest well-formed mesh from the available devices."""
    devices = jax.devices() if devices is None else list(devices)
    shape, names = mesh_shape_for(
        len(devices), model_width=model_width, pod_size=pod_size
    )
    used = int(np.prod(shape))
    arr = np.array(devices[:used]).reshape(shape)
    return Mesh(arr, names)


def spare_devices(mesh: Mesh, devices=None) -> list:
    """Devices not included in the mesh — the hot-spare pool."""
    devices = jax.devices() if devices is None else list(devices)
    used = {d.id for d in mesh.devices.flatten()}
    return [d for d in devices if d.id not in used]
