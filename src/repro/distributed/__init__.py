"""Distributed substrate: sharding rules, elastic meshes, k-truss slot meshes."""

from .elastic import derive_mesh, mesh_shape_for, spare_devices
from .ktruss import SLOT_AXIS, peel_problem_specs, shard_peel_args, slot_mesh
from .sharding import (
    MeshAxes,
    batch_specs,
    logits_spec,
    mesh_axes,
    named,
    param_specs,
    state_specs,
)

__all__ = [
    "derive_mesh",
    "mesh_shape_for",
    "spare_devices",
    "SLOT_AXIS",
    "peel_problem_specs",
    "shard_peel_args",
    "slot_mesh",
    "MeshAxes",
    "batch_specs",
    "logits_spec",
    "mesh_axes",
    "named",
    "param_specs",
    "state_specs",
]
