"""Distributed substrate: sharding rules, elastic meshes."""

from .elastic import derive_mesh, mesh_shape_for, spare_devices
from .sharding import (
    MeshAxes,
    batch_specs,
    logits_spec,
    mesh_axes,
    named,
    param_specs,
    state_specs,
)

__all__ = [
    "derive_mesh",
    "mesh_shape_for",
    "spare_devices",
    "MeshAxes",
    "batch_specs",
    "logits_spec",
    "mesh_axes",
    "named",
    "param_specs",
    "state_specs",
]
