"""Distributed substrate: the K-truss slot mesh + packed-batch sharding."""

from .ktruss import SLOT_AXIS, peel_problem_specs, shard_peel_args, slot_mesh

__all__ = [
    "SLOT_AXIS",
    "peel_problem_specs",
    "shard_peel_args",
    "slot_mesh",
]
