"""Sharding rules: parameter/activation/state PartitionSpecs for any mesh.

Strategy (DESIGN.md §7) on mesh axes ('pod', 'data', 'model'):

  * DP   : batch over ('pod', 'data')                      — "dp"
  * FSDP : parameter d_model-ish dims over ('pod', 'data') — "fsdp"
  * TP   : heads / d_ff / expert dim over 'model'
  * EP   : MoE expert dim over 'model' (TP-style expert parallelism)
  * SP   : long-context caches over 'model' when batch = 1

Rules are *divisibility-aware with ordered fallbacks*: each parameter kind
lists candidate layouts; the first whose sharded dims divide evenly by the
mesh axes wins, otherwise the dim falls back (e.g. qwen2's 14 heads don't
split 16-way → shard head_dim instead; seamless' 256206 vocab doesn't split
→ shard d_model).  This is what lets ONE rule set drive all 10 assigned
architectures on the 16×16 and 2×16×16 production meshes.

Scanned layer stacks (params under a ``scan`` key) get a leading ``None``
axis for the group dimension automatically.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "MeshAxes",
    "mesh_axes",
    "param_specs",
    "batch_specs",
    "state_specs",
    "logits_spec",
    "named",
    "spec_tree_to_shardings",
]


class MeshAxes:
    """Resolved roles of the mesh's named axes."""

    def __init__(self, mesh: Mesh):
        names = mesh.axis_names
        self.mesh = mesh
        self.model = "model" if "model" in names else None
        dp = tuple(a for a in ("pod", "data") if a in names)
        self.dp: tuple[str, ...] | None = dp or None
        self.fsdp: tuple[str, ...] | None = dp or None
        # mesh.shape works for both Mesh and AbstractMesh (spec planning).
        self.sizes = dict(mesh.shape)

    def size(self, role) -> int:
        if role is None:
            return 1
        axes = role if isinstance(role, tuple) else (role,)
        return int(np.prod([self.sizes[a] for a in axes]))


def mesh_axes(mesh: Mesh) -> MeshAxes:
    return MeshAxes(mesh)


def _resolve(ax: MeshAxes, role):
    """Map the logical role ('fsdp'|'model'|'dp'|None) to mesh axis names."""
    if role is None:
        return None
    if role == "fsdp":
        return ax.fsdp
    if role == "dp":
        return ax.dp
    if role == "model":
        return ax.model
    raise ValueError(role)


def _fits(ax: MeshAxes, shape: Sequence[int], template) -> bool:
    for dim, role in zip(shape, template):
        axes = _resolve(ax, role)
        if axes is None:
            continue
        if dim % ax.size(axes) != 0:
            return False
    return True


def _first_fit(ax: MeshAxes, shape: Sequence[int], candidates) -> P:
    for template in candidates:
        if len(template) != len(shape):
            continue
        if _fits(ax, shape, template):
            return P(*(_resolve(ax, r) for r in template))
    return P()  # fully replicated fallback


# Parameter-kind rules: (match fn over path keys, candidate templates).
# Later entries in each candidate list are progressively less sharded.
def _param_candidates(keys: tuple[str, ...], ndim: int):
    ks = set(keys)
    last = keys[-1] if keys else ""
    joined = "/".join(keys)

    if last == "embedding":
        # Never shard the gathered (d_model) dim: SPMD's gather partitioning
        # of a last-dim-sharded table emits invalid dynamic-slices under
        # scan+jvp (observed on XLA:CPU 0.8; see DESIGN.md §7 fallbacks).
        return [("model", "fsdp"), ("fsdp", None), (None, None)]
    if "head" in ks and last == "kernel":
        return [("fsdp", "model"), ("fsdp", None), (None, None)]
    if "moe" in ks:
        if last in ("gate", "up"):
            return [("model", "fsdp", None), (None, "fsdp", "model"), (None, None, None)]
        if last == "down":
            return [("model", None, "fsdp"), (None, "model", "fsdp"), (None, None, None)]
        if "router" in ks:
            return [("fsdp", None), (None, None)]
        # shared expert falls through to the ffn rules below
    if last == "kernel" and ks & {"q", "k", "v"} and ndim == 3:
        # Shard heads or REPLICATE — never shard head_dim: a dh-sharded
        # K against a head-sharded Q turns every flash chunk's scores into
        # a partial-sum all-reduce (gemma2 prefill measured 21k all-reduces
        # = 11.6 TB/device — EXPERIMENTS §Perf iteration 8).  Replicated
        # K/V projections are small (GQA kv ≤ 16).
        return [
            ("fsdp", "model", None),
            ("fsdp", None, None),
            (None, None, None),
        ]
    if last == "bias" and ndim == 2:
        return [("model", None), (None, "model"), (None, None)]
    if last == "kernel" and "o" in ks:
        return [("model", "fsdp"), (None, "fsdp"), (None, None)]
    if last == "kernel" and ks & {"gate", "up", "in_x", "in_gate"}:
        return [("fsdp", "model"), ("fsdp", None), (None, None)]
    if last == "kernel" and "down" in ks:
        return [("model", "fsdp"), (None, "fsdp"), (None, None)]
    if last == "kernel" and ks & {"gate_a", "gate_x"}:
        return [(None, "model"), (None, None)]
    if last == "kernel" and "out" in ks:
        return [("model", "fsdp"), (None, "fsdp"), (None, None)]
    if last == "conv_w":
        return [(None, "model"), (None, None)]
    if last == "lambda":
        return [("model",), (None,)]
    # RWKV mixers: time-mix r/k/v/g are (D, D) column-parallel; channel-mix
    # k is (D, F) column-parallel and v is (F, D) row-parallel.
    if last == "kernel" and ks & {"r", "g"} and ndim == 2:
        return [("fsdp", "model"), ("fsdp", None), (None, None)]
    if last == "kernel" and "k" in ks and ndim == 2:
        return [("fsdp", "model"), ("fsdp", None), (None, None)]
    if last == "kernel" and "v" in ks and ndim == 2:
        if "ffn" in ks:  # channel-mix v: (F, D) row-parallel
            return [("model", "fsdp"), (None, "fsdp"), (None, None)]
        return [("fsdp", "model"), ("fsdp", None), (None, None)]
    if last == "lora_down" or (last == "kernel" and "lora_down" in ks):
        return [("fsdp", None), (None, None)]
    if last == "lora_up":
        return [(None, None, "model"), (None, None, None)]
    if last == "wlora_up":
        return [(None, "model"), (None, None)]
    # 1-D params (norm scales, u, w0, mu, conv_b, biases): replicate.
    return [tuple(None for _ in range(ndim))]


def _path_keys(path) -> tuple[str, ...]:
    keys = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            keys.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            keys.append(f"[{e.idx}]")
        elif isinstance(e, jax.tree_util.GetAttrKey):
            keys.append(str(e.name))
    return tuple(keys)


def param_specs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    ax = mesh_axes(mesh)

    def leaf_spec(path, leaf):
        keys = _path_keys(path)
        # int8-quantized moment leaves ({'q','scale'} under the param key,
        # optimizer.py): rule-match on the parent parameter's keys — 'q'
        # has the param's exact shape; 'scale' replaces the last dim by the
        # (small, usually indivisible) block count, so its last dim must
        # not be sharded.
        is_scale = False
        if keys and keys[-1] in ("q", "scale") and ("m" in keys or "v" in keys):
            is_scale = keys[-1] == "scale"
            keys = keys[:-1]
        shape = tuple(leaf.shape)
        scanned = "scan" in keys
        eff_shape = shape[1:] if scanned else shape
        cands = _param_candidates(keys, len(eff_shape))
        if is_scale:
            cands = [tuple(c[:-1]) + (None,) for c in cands]
        spec = _first_fit(ax, eff_shape, cands)
        if scanned:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Batch arrays: dim 0 over DP axes (falls back to replicated)."""
    ax = mesh_axes(mesh)

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        if ax.dp is not None and shape[0] % ax.size(ax.dp) == 0:
            return P(ax.dp, *(None,) * (len(shape) - 1))
        return P()

    return jax.tree.map(leaf_spec, batch)


def _state_candidates(keys: tuple[str, ...], ndim: int):
    last = keys[-1] if keys else ""
    ks = set(keys)
    if last in ("k", "v") and ndim == 4:  # KV cache (B, S, KV, dh)
        return [
            ("dp", None, "model", None),
            ("dp", None, None, "model"),
            ("dp", "model", None, None),  # SP cache: heads/dh indivisible
            ("dp", None, None, None),
            (None, "model", None, None),  # SP: batch=1 long-context cache
            (None, None, None, "model"),
            (None, None, None, None),
        ]
    if last == "s" and ndim == 4:  # RWKV state (B, H, dh, dh)
        return [
            ("dp", "model", None, None),
            (None, "model", None, None),
            (None, None, None, None),
        ]
    if last == "h" and ndim == 2:  # RG-LRU state (B, R)
        return [("dp", "model"), (None, "model"), (None, None)]
    if last == "conv" and ndim == 3:  # conv tail (B, w-1, R)
        return [("dp", None, "model"), (None, None, "model"), (None, None, None)]
    if last in ("x_prev_t", "x_prev_c") and ndim == 2:
        return [("dp", None), (None, "model"), (None, None)]
    if last == "enc_out" and ndim == 3:
        return [("dp", None, None), (None, "model", None), (None, None, None)]
    if last == "pos":
        return [()]
    return [tuple(None for _ in range(ndim))]


def state_specs(states: Any, mesh: Mesh) -> Any:
    """Decode-state pytree specs (caches, recurrent states)."""
    ax = mesh_axes(mesh)

    def leaf_spec(path, leaf):
        keys = _path_keys(path)
        shape = tuple(leaf.shape)
        scanned = "scan" in keys
        eff = shape[1:] if scanned else shape
        spec = _first_fit(ax, eff, _state_candidates(keys, len(eff)))
        if scanned:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, states)


def logits_spec(mesh: Mesh) -> P:
    ax = mesh_axes(mesh)
    return P(ax.dp) if ax.dp else P()


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def spec_tree_to_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return named(mesh, spec_tree)
