"""R2 — recompile-hazard.

The compile cache keys executors by ``(bucket, slots, cache_variant())``.
Anything the jitted builder (``build_executor``) reads off ``self`` but
does *not* fold into ``cache_variant()`` is an invisible compile-cache
dimension: two planners differing only in that attribute share a cache
slot, and every alternation recompiles — the classic silent
recompile-storm.

The static half of this rule: for every class that defines both a
``cache_variant``-style key method and a ``build_*`` builder, every
``self.<attr>`` the builder reads must also be read by the key method,
either directly or through a ``self._<attr>_key`` alias (unhashable
objects like the device mesh ride in the key as a precomputed hashable
fold).  Method calls on ``self`` are not attribute closures and are
exempt.

The runtime half lives in :mod:`repro.analysis.sentinel`: a jax
compilation-event listener asserting zero XLA compiles on the warm path,
wired into ``tests/test_api.py`` for every registry backend.
"""

from __future__ import annotations

import ast

from .engine import AnalysisContext, Finding, SourceFile

RULE = "R2"

_KEY_METHODS = {"cache_variant", "variant_key"}
_BUILDER_PREFIX = "build_"


def _self_attr_reads(fn: ast.AST) -> set[str]:
    """Names X for every ``self.X`` load inside ``fn``."""
    reads: set[str] = set()
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
        ):
            reads.add(node.attr)
    return reads


def _check_class(sf: SourceFile, cls: ast.ClassDef) -> list[Finding]:
    methods = {
        n.name: n
        for n in cls.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    key_fn = next((methods[m] for m in _KEY_METHODS if m in methods), None)
    builders = [
        fn
        for name, fn in methods.items()
        if name.startswith(_BUILDER_PREFIX) and fn is not key_fn
    ]
    if key_fn is None or not builders:
        return []

    key_reads = _self_attr_reads(key_fn)
    findings: list[Finding] = []
    for builder in builders:
        for attr in sorted(_self_attr_reads(builder)):
            if attr in methods:  # self.method(...) is not a closure
                continue
            alias = f"_{attr.lstrip('_')}_key"
            if attr in key_reads or alias in key_reads:
                continue
            # Anchor on the first read of the attribute in the builder.
            line = min(
                node.lineno
                for node in ast.walk(builder)
                if isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr == attr
            )
            findings.append(
                Finding(
                    rule=RULE,
                    path=sf.rel,
                    line=line,
                    scope=f"{cls.name}.{builder.name}",
                    message=(
                        f"builder closes over self.{attr} but "
                        f"{key_fn.name}() does not fold it (or a "
                        f"self.{alias} alias) into the compile-cache "
                        "variant key — recompile hazard"
                    ),
                    snippet=sf.line_text(line),
                )
            )
    return findings


def check(ctx: AnalysisContext) -> list[Finding]:
    findings: list[Finding] = []
    for rel in ctx.config.recompile_files:
        sf = ctx.get(rel)
        if sf is None:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(_check_class(sf, node))
    return findings
