"""Runtime recompile sentinel — the dynamic half of the R2 rule.

The static rule proves the variant key *covers* what the builder closes
over; this sentinel proves the warm path actually *hits* the cache: it
registers a :mod:`jax.monitoring` event listener and counts XLA
compilations, so a test can solve the same query mix twice and assert
the second pass compiled nothing.

This is also the first piece of the observed-cost feedback loop on the
roadmap: the same listener machinery that counts compile events here is
where observed ``device_time_s`` per (bucket, backend) will be tapped to
replace the analytic cost model's constants.
"""

from __future__ import annotations

import contextlib
from typing import Iterator

__all__ = ["COMPILE_EVENTS", "CompileLog", "count_compiles", "assert_no_compiles"]

# Events jax emits once per XLA compilation (cache-miss path).  Warm
# executions emit none of these.
COMPILE_EVENTS = (
    "/jax/compilation_cache/compile_requests_use_cache",
    "/jax/pjit/compile",  # older/newer jax spellings; either counts
)


class CompileLog:
    """Callable event listener accumulating compile events."""

    def __init__(self) -> None:
        self.events: list[str] = []

    @property
    def compiles(self) -> int:
        return len(self.events)

    def __call__(self, event: str, *args, **kwargs) -> None:
        if event in COMPILE_EVENTS:
            self.events.append(event)


def _unregister(log: CompileLog) -> None:
    from jax._src import monitoring as _monitoring

    unregister = getattr(_monitoring, "_unregister_event_listener_by_callback", None)
    if unregister is not None:
        unregister(log)
        return
    listeners = getattr(_monitoring, "_event_listeners", None)
    if isinstance(listeners, list) and log in listeners:  # pragma: no cover
        listeners.remove(log)


@contextlib.contextmanager
def count_compiles() -> Iterator[CompileLog]:
    """Context manager yielding a :class:`CompileLog` counting XLA
    compilations that happen inside the block."""
    from jax import monitoring

    log = CompileLog()
    monitoring.register_event_listener(log)
    try:
        yield log
    finally:
        _unregister(log)


@contextlib.contextmanager
def assert_no_compiles(what: str = "warm path") -> Iterator[CompileLog]:
    """Assert that the block triggers zero XLA compilations.

    Usage::

        with assert_no_compiles("second solve of identical mix"):
            session.solve(query)
    """
    with count_compiles() as log:
        yield log
    if log.compiles:
        raise AssertionError(
            f"{what}: {log.compiles} unexpected XLA compilation(s) — "
            "a compile-cache variant-key dimension is leaking (see R2 in "
            "repro.analysis)"
        )
