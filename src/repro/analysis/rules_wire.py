"""R6 — wire-schema safety.

Errors cross the wire as ``{type, message, context}`` and are re-raised
typed on the client (:func:`repro.serve.wire.raise_remote_error`).  That
only stays true while three invariants hold, and each is a drift
magnet:

* **Whitelist is live.** Every name in ``wire._ERROR_CONTEXT`` is an
  actual constructor parameter or attribute of some ``repro.errors``
  class — a stale entry silently stops carrying context.
* **Whitelist is complete.** Every scalar-annotated (``int``/``float``/
  ``str``/``bool``) constructor parameter of every error class is either
  whitelisted or listed in ``wire._ERROR_CONTEXT_EXCLUDED`` with a
  written reason — a forgotten field means typed context evaporates at
  the first socket.
* **Re-raisable by name.** Every class in ``repro.errors.__all__`` is
  constructible from a bare message (first parameter positional, every
  other parameter defaulted), because ``raise_remote_error`` degrades to
  ``cls(msg)`` when a peer sends no context.
"""

from __future__ import annotations

import ast

from .engine import AnalysisContext, Finding, SourceFile, const_str

RULE = "R6"

_SCALARS = {"int", "float", "str", "bool"}


def _tuple_of_strs(sf: SourceFile, name: str) -> tuple[set[str], int]:
    for node in ast.walk(sf.tree):
        targets: list[ast.expr] = []
        value: ast.expr | None = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        for t in targets:
            if isinstance(t, ast.Name) and t.id == name:
                vals = {
                    s
                    for elt in getattr(value, "elts", [])
                    if (s := const_str(elt)) is not None
                }
                return vals, node.lineno
    return set(), 1


def _is_scalar_annotation(ann: ast.AST | None) -> bool:
    if ann is None:
        return False
    try:
        text = ast.unparse(ann)
    except Exception:
        return False
    text = text.replace("Optional[", "").replace("]", "")
    parts = [p.strip() for p in text.split("|")]
    parts = [p for p in parts if p and p != "None"]
    return bool(parts) and all(p in _SCALARS for p in parts)


def _error_classes(sf: SourceFile) -> dict[str, ast.ClassDef]:
    exported, _ = _tuple_of_strs(sf, "__all__")
    classes: dict[str, ast.ClassDef] = {}
    for node in sf.tree.body:
        if isinstance(node, ast.ClassDef) and (
            not exported or node.name in exported
        ):
            classes[node.name] = node
    return classes


def _init_of(cls: ast.ClassDef) -> ast.FunctionDef | None:
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == "__init__":
            return node
    return None


def check(ctx: AnalysisContext) -> list[Finding]:
    wire_sf = ctx.get(ctx.config.wire_file)
    errors_sf = ctx.get(ctx.config.errors_file)
    if wire_sf is None or errors_sf is None:
        return []

    whitelist, wl_line = _tuple_of_strs(wire_sf, "_ERROR_CONTEXT")
    excluded, _ = _tuple_of_strs(wire_sf, "_ERROR_CONTEXT_EXCLUDED")
    classes = _error_classes(errors_sf)

    findings: list[Finding] = []

    # Collect params/attrs across the taxonomy.
    known_names: set[str] = set()
    scalar_params: dict[str, tuple[str, int]] = {}  # name -> (class, line)
    for cname, cls in classes.items():
        init = _init_of(cls)
        if init is None:
            continue
        params = [*init.args.posonlyargs, *init.args.args, *init.args.kwonlyargs]
        for p in params[1:]:  # drop self
            known_names.add(p.arg)
            if _is_scalar_annotation(p.annotation) and p.arg not in scalar_params:
                scalar_params[p.arg] = (cname, p.lineno)
        for node in ast.walk(init):
            if (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
            ):
                known_names.add(node.attr)

    # 1. whitelist entries must be live.
    for name in sorted(whitelist):
        if name not in known_names:
            findings.append(
                Finding(
                    rule=RULE,
                    path=wire_sf.rel,
                    line=wl_line,
                    scope="<module>",
                    message=(
                        f"_ERROR_CONTEXT entry {name!r} matches no parameter "
                        "or attribute of any repro.errors class (stale — "
                        "carries nothing)"
                    ),
                    snippet=f"context:{name}",
                )
            )

    # 2. scalar params must be whitelisted or explicitly excluded.
    for name, (cname, line) in sorted(scalar_params.items()):
        if name in whitelist or name in excluded or name == "message":
            continue
        findings.append(
            Finding(
                rule=RULE,
                path=errors_sf.rel,
                line=line,
                scope=f"{cname}.__init__",
                message=(
                    f"scalar error-context param {name!r} is neither in "
                    "wire._ERROR_CONTEXT nor wire._ERROR_CONTEXT_EXCLUDED "
                    "(context silently dropped at the wire)"
                ),
                snippet=f"param:{name}",
            )
        )

    # 3. every exported class must be message-only constructible.
    for cname, cls in sorted(classes.items()):
        init = _init_of(cls)
        if init is None:
            continue  # inherits a compliant __init__
        args = init.args
        positional = [*args.posonlyargs, *args.args][1:]  # drop self
        ok = True
        n_defaults = len(args.defaults)
        # all but the first positional (message) need defaults
        if len(positional) - n_defaults > 1:
            ok = False
        if sum(1 for d in args.kw_defaults if d is None) > 0:
            ok = False
        if not ok:
            findings.append(
                Finding(
                    rule=RULE,
                    path=errors_sf.rel,
                    line=init.lineno,
                    scope=f"{cname}.__init__",
                    message=(
                        f"{cname} is not constructible from a bare message "
                        "(raise_remote_error's degraded path would fail)"
                    ),
                    snippet=errors_sf.line_text(init.lineno),
                )
            )
    return findings
