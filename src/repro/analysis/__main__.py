"""CLI: ``python -m repro.analysis``.

Exit codes: 0 clean (or everything baselined), 2 new findings, 3 stale
baseline entries (a baselined finding was fixed — regenerate with
``--write-baseline`` to shrink the baseline).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import (
    AnalysisConfig,
    apply_baseline,
    load_baseline,
    render_text,
    report_dict,
    run,
    write_baseline,
)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-native static analysis (rules R1-R6)",
    )
    parser.add_argument(
        "--root", default=".", help="repository root (default: cwd)"
    )
    parser.add_argument(
        "--baseline",
        default="analysis/baseline.json",
        help="baseline file, relative to --root",
    )
    parser.add_argument(
        "--report", default=None, help="write a JSON report to this path"
    )
    parser.add_argument(
        "--write-baseline",
        action="store_true",
        help="accept all current findings into the baseline and exit 0",
    )
    parser.add_argument("--quiet", action="store_true")
    args = parser.parse_args(argv)

    root = Path(args.root).resolve()
    config = AnalysisConfig.default(root)
    findings = run(config)

    baseline_path = root / args.baseline
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        if not args.quiet:
            print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, baselined, stale = apply_baseline(findings, baseline)

    if args.report:
        Path(args.report).write_text(
            json.dumps(report_dict(new, baselined, stale, config), indent=2)
            + "\n"
        )
    if not args.quiet:
        print(render_text(new, baselined, stale))
    if new:
        return 2
    if stale:
        return 3
    return 0


if __name__ == "__main__":
    sys.exit(main())
