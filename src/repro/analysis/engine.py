"""Lint engine: file loading, rule driving, fingerprints, baselines.

The engine is deliberately pure-stdlib (``ast`` + ``hashlib``): the
analysis job must run in a bare CI container in well under a second,
without importing jax or the package under analysis.

Fingerprints are content-addressed, not line-addressed: a finding hashes
``rule | path | scope | normalized-snippet | occurrence-index``.  Adding
a docstring above a bad call moves its line but not its fingerprint, so
``analysis/baseline.json`` does not churn on unrelated edits.  The
occurrence index disambiguates textually identical findings within one
scope (ordered by line).
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Iterable

__all__ = [
    "AnalysisConfig",
    "AnalysisContext",
    "Finding",
    "SourceFile",
    "apply_baseline",
    "load_baseline",
    "render_text",
    "report_dict",
    "run",
    "write_baseline",
]

# A suppression may share a comment with prose ("# isolation downward;
# trusslint: disable=R5"), so only anchor on the marker itself.
_SUPPRESS_RE = re.compile(r"trusslint:\s*disable=([A-Za-z0-9_]+(?:\s*,\s*[A-Za-z0-9_]+)*)")

RULE_IDS = ("R1", "R2", "R3", "R4", "R5", "R6")


# ---------------------------------------------------------------------- #
# Findings
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one site."""

    rule: str
    path: str  # repo-relative, posix separators
    line: int
    scope: str  # dotted enclosing scope, e.g. "Planner.execute"
    message: str
    snippet: str  # normalized source line (whitespace-collapsed)
    occurrence: int = 0

    @property
    def fingerprint(self) -> str:
        basis = "|".join(
            (self.rule, self.path, self.scope, self.snippet, str(self.occurrence))
        )
        return hashlib.sha256(basis.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d


# ---------------------------------------------------------------------- #
# Source files
# ---------------------------------------------------------------------- #
class SourceFile:
    """A parsed source file plus its suppression comments."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(path))
        self.suppressed: dict[int, set[str]] = {}
        for i, text in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(text)
            if m:
                self.suppressed[i] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()
                }

    def line_text(self, lineno: int) -> str:
        if 0 < lineno <= len(self.lines):
            return " ".join(self.lines[lineno - 1].split())
        return ""

    def is_suppressed(self, rule: str, lineno: int) -> bool:
        return rule in self.suppressed.get(lineno, ())


# ---------------------------------------------------------------------- #
# Configuration
# ---------------------------------------------------------------------- #
def _iter_py(root: Path, subdir: str) -> list[Path]:
    base = root / subdir
    if not base.is_dir():
        return []
    return sorted(p for p in base.rglob("*.py") if "__pycache__" not in p.parts)


@dataclasses.dataclass
class AnalysisConfig:
    """Which files each rule looks at.

    Everything is expressed as repo-relative paths so the fixture tests
    can re-point individual rules at ``tests/analysis_fixtures/`` without
    touching the engine.
    """

    root: Path
    files: list[Path]
    # R1: files whose jit/pallas graphs seed trace-purity checking, and
    # files whose pre-``.peel`` dispatch path must not read device arrays.
    trace_files: list[str]
    dispatch_files: list[str]
    # R2: files holding builder/variant-key pairs.
    recompile_files: list[str]
    # R3: files with guarded-by annotated classes.
    lock_files: list[str]
    # R4: the fault-site declaration and the tests that must cover it.
    faults_file: str
    test_files: list[str]
    # R5: the name registry and every file whose metric calls it governs.
    names_file: str
    metric_ref_files: list[str]
    # R6: the wire codec and the error taxonomy.
    wire_file: str
    errors_file: str

    @classmethod
    def default(cls, root: Path | str = ".") -> "AnalysisConfig":
        root = Path(root).resolve()
        files: list[Path] = []
        for sub in ("src", "tests", "benchmarks", "examples"):
            files.extend(_iter_py(root, sub))
        fixtures = (root / "tests" / "analysis_fixtures").resolve()
        files = [p for p in files if fixtures not in p.parents]

        def rel(p: Path) -> str:
            return p.relative_to(root).as_posix()

        rels = [rel(p) for p in files]
        tests = [r for r in rels if r.startswith("tests/")]
        return cls(
            root=root,
            files=files,
            trace_files=[
                "src/repro/exec/peel.py",
                "src/repro/kernels/peel_fused.py",
                "src/repro/core/eager_fine.py",
            ],
            dispatch_files=[
                "src/repro/api/planner.py",
                "src/repro/exec/peel.py",
            ],
            recompile_files=[
                "src/repro/api/cache.py",
                "src/repro/api/planner.py",
            ],
            lock_files=[
                "src/repro/api/session.py",
                "src/repro/api/planner.py",
                "src/repro/serve/router.py",
                "src/repro/serve/replica.py",
            ],
            faults_file="src/repro/resilience/faults.py",
            test_files=tests,
            names_file="src/repro/obs/names.py",
            metric_ref_files=rels,
            wire_file="src/repro/serve/wire.py",
            errors_file="src/repro/errors.py",
        )


class AnalysisContext:
    """Loaded sources shared by every rule."""

    def __init__(self, config: AnalysisConfig):
        self.config = config
        self.files: dict[str, SourceFile] = {}
        self.errors: list[Finding] = []
        for path in config.files:
            rel = path.relative_to(config.root).as_posix()
            try:
                self.files[rel] = SourceFile(path, rel)
            except (SyntaxError, UnicodeDecodeError, OSError) as e:
                self.errors.append(
                    Finding(
                        rule="E0",
                        path=rel,
                        line=getattr(e, "lineno", 0) or 0,
                        scope="<module>",
                        message=f"file could not be parsed: {e}",
                        snippet="",
                    )
                )

    def get(self, rel: str) -> SourceFile | None:
        return self.files.get(rel)

    def test_sources(self) -> Iterable[SourceFile]:
        for rel in self.config.test_files:
            sf = self.get(rel)
            if sf is not None:
                yield sf


# ---------------------------------------------------------------------- #
# AST helpers shared by rules
# ---------------------------------------------------------------------- #
def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> str | None:
    return dotted_name(call.func)


def build_parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def scope_of(node: ast.AST, parents: dict[ast.AST, ast.AST]) -> str:
    names: list[str] = []
    cur: ast.AST | None = node
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            names.append(cur.name)
        cur = parents.get(cur)
    return ".".join(reversed(names)) or "<module>"


def const_str(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


# ---------------------------------------------------------------------- #
# Running
# ---------------------------------------------------------------------- #
def _assign_occurrences(findings: list[Finding]) -> list[Finding]:
    groups: dict[tuple, list[Finding]] = {}
    for f in findings:
        groups.setdefault((f.rule, f.path, f.scope, f.snippet), []).append(f)
    out: list[Finding] = []
    for members in groups.values():
        members.sort(key=lambda f: f.line)
        for i, f in enumerate(members):
            out.append(dataclasses.replace(f, occurrence=i))
    out.sort(key=lambda f: (f.path, f.line, f.rule, f.occurrence))
    return out


def run(config: AnalysisConfig, rules: list | None = None) -> list[Finding]:
    """Run the rule set and return suppression-filtered findings."""
    from . import (
        rules_faults,
        rules_locks,
        rules_metrics,
        rules_recompile,
        rules_trace,
        rules_wire,
    )

    ctx = AnalysisContext(config)
    modules = rules if rules is not None else [
        rules_trace,
        rules_recompile,
        rules_locks,
        rules_faults,
        rules_metrics,
        rules_wire,
    ]
    findings: list[Finding] = list(ctx.errors)
    for mod in modules:
        findings.extend(mod.check(ctx))

    kept = []
    seen: set[tuple] = set()
    for f in findings:
        sf = ctx.files.get(f.path)
        if sf is not None and sf.is_suppressed(f.rule, f.line):
            continue
        key = (f.rule, f.path, f.line, f.message)
        if key in seen:  # overlapping traced scopes can double-visit
            continue
        seen.add(key)
        kept.append(f)
    return _assign_occurrences(kept)


# ---------------------------------------------------------------------- #
# Baseline
# ---------------------------------------------------------------------- #
def load_baseline(path: Path | str) -> set[str]:
    path = Path(path)
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {entry["fingerprint"] for entry in data.get("findings", [])}


def write_baseline(path: Path | str, findings: list[Finding]) -> None:
    data = {
        "version": 1,
        "findings": [
            {
                "fingerprint": f.fingerprint,
                "rule": f.rule,
                "path": f.path,
                "scope": f.scope,
                "message": f.message,
            }
            for f in findings
        ],
    }
    Path(path).write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


def apply_baseline(
    findings: list[Finding], baseline: set[str]
) -> tuple[list[Finding], list[Finding], set[str]]:
    """Split findings into (new, baselined) and report stale entries."""
    new: list[Finding] = []
    old: list[Finding] = []
    live = {f.fingerprint for f in findings}
    for f in findings:
        (old if f.fingerprint in baseline else new).append(f)
    stale = baseline - live
    return new, old, stale


# ---------------------------------------------------------------------- #
# Reports
# ---------------------------------------------------------------------- #
def report_dict(
    new: list[Finding],
    baselined: list[Finding],
    stale: set[str],
    config: AnalysisConfig,
) -> dict:
    return {
        "version": 1,
        "tool": "repro.analysis",
        "files_scanned": len(config.files),
        "counts": {
            "new": len(new),
            "baselined": len(baselined),
            "stale_baseline": len(stale),
        },
        "findings": [dict(f.to_dict(), baselined=False) for f in new]
        + [dict(f.to_dict(), baselined=True) for f in baselined],
        "stale_baseline": sorted(stale),
    }


def render_text(new: list[Finding], baselined: list[Finding], stale: set[str]) -> str:
    out: list[str] = []
    for f in new:
        out.append(
            f"{f.path}:{f.line}: {f.rule} [{f.scope}] {f.message} [{f.fingerprint}]"
        )
    if baselined:
        out.append(f"({len(baselined)} baselined finding(s) suppressed)")
    for fp in sorted(stale):
        out.append(f"stale baseline entry {fp}: finding no longer present")
    if not new and not stale:
        out.append("repro.analysis: clean")
    return "\n".join(out)
