"""R1 — trace-purity.

Two sub-checks:

**Traced-function purity.** Functions that jax traces — wrapped by
``jax.jit``, passed to ``lax.while_loop``/``scan``/``cond``/``fori_loop``/
``map``/``switch`` or ``pl.pallas_call`` (through ``functools.partial``),
or ``@jax.jit``-decorated — plus everything they call locally, must not:

* call ``np.*`` on a traced array argument (host round-trip per call),
* coerce a traced value with ``int()``/``float()``/``bool()``,
* call ``.item()`` or ``.block_until_ready()`` at all,
* branch (``if``/``while``) or iterate (``for``) on a traced value.

"Traced array argument" is decided conservatively from annotations: only
parameters whose annotation mentions ``Array``/``ndarray`` count, and
accesses through ``.shape``/``.ndim``/``.dtype``/``.size``/``len()`` are
static and exempt (trace-time constant math like
``np.ceil(np.log2(w + 1))`` on shape-derived scalars is fine and common
in the pallas kernels).  ``is None`` checks are control flow on
*presence*, not value, and are exempt.

**Dispatch-path readback.** In the configured dispatch files, flag
``np.asarray``/``np.array``/``np.copy`` applied to packed device arrays
(expressions mentioning ``.problem``) *before* the first ``.peel(`` call
in the same function: a host sync on the dispatch critical path stalls
the pipeline before the kernel is even launched.  Readbacks after
dispatch are how results come home and are fine.
"""

from __future__ import annotations

import ast

from .engine import (
    AnalysisContext,
    Finding,
    SourceFile,
    build_parents,
    call_name,
    dotted_name,
    scope_of,
)

RULE = "R1"

_JIT_WRAPPERS = {"jax.jit", "jit", "pjit", "jax.pjit"}
_TRACE_CONSUMERS = {
    "jax.lax.while_loop",
    "lax.while_loop",
    "jax.lax.scan",
    "lax.scan",
    "jax.lax.cond",
    "lax.cond",
    "jax.lax.fori_loop",
    "lax.fori_loop",
    "jax.lax.map",
    "lax.map",
    "jax.lax.switch",
    "lax.switch",
    "pl.pallas_call",
    "pallas_call",
    "jax.experimental.pallas.pallas_call",
    "checkpoint",
    "jax.checkpoint",
}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding"}
_ARRAY_ANN_MARKERS = ("Array", "ndarray")
_ALWAYS_BAD_METHODS = {"item", "block_until_ready"}
_COERCIONS = {"int", "float", "bool", "complex"}
_DISPATCH_READBACKS = {"np.asarray", "np.array", "np.copy", "numpy.asarray", "numpy.array"}


class _Resolver:
    """Lexically-scoped function-name resolution.

    A bare ``peel`` inside ``build_peel`` must resolve to *that* nested
    ``peel``, never to a same-named method or a sibling builder's local —
    by-name file-wide matching seeds host driver loops as traced and
    drowns the rule in false positives.
    """

    def __init__(self, tree: ast.AST, parents: dict[ast.AST, ast.AST]):
        self.parents = parents
        # function name -> defining scope (nearest enclosing function or
        # module, skipping nothing: a ClassDef scope marks a method).
        self.defs: dict[tuple[int, str], ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope = self._enclosing_scope(node)
                self.defs[(id(scope), node.name)] = node

    def _enclosing_scope(self, node: ast.AST) -> ast.AST:
        cur = self.parents.get(node)
        while cur is not None and not isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Module)
        ):
            cur = self.parents.get(cur)
        return cur

    def resolve_name(self, name: str, at: ast.AST) -> ast.AST | None:
        """Innermost visible function named ``name`` from site ``at``."""
        scope = self._enclosing_scope(at)
        while scope is not None:
            if isinstance(scope, ast.ClassDef):
                # class bodies don't contribute to nested lexical lookup
                scope = self._enclosing_scope(scope)
                continue
            fn = self.defs.get((id(scope), name))
            if fn is not None:
                return fn
            if isinstance(scope, ast.Module):
                return None
            scope = self._enclosing_scope(scope)
        return None

    def resolve_method(self, name: str, at: ast.AST) -> ast.AST | None:
        """``self.<name>`` resolved against the enclosing class."""
        cur = self.parents.get(at)
        while cur is not None and not isinstance(cur, ast.ClassDef):
            cur = self.parents.get(cur)
        if cur is None:
            return None
        return self.defs.get((id(cur), name))


def _unwrap_partial(node: ast.AST) -> ast.AST:
    """``functools.partial(f, ...)`` -> ``f``."""
    if isinstance(node, ast.Call) and call_name(node) in (
        "functools.partial",
        "partial",
    ):
        if node.args:
            return node.args[0]
    return node


def _seed_traced(tree: ast.AST, resolver: _Resolver) -> set[ast.AST]:
    seeds: set[ast.AST] = set()

    def add_ref(ref: ast.AST, at: ast.AST) -> None:
        ref = _unwrap_partial(ref)
        fn = None
        if isinstance(ref, ast.Name):
            fn = resolver.resolve_name(ref.id, at)
        elif isinstance(ref, ast.Attribute) and (
            isinstance(ref.value, ast.Name) and ref.value.id == "self"
        ):
            fn = resolver.resolve_method(ref.attr, at)
        if fn is not None:
            seeds.add(fn)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name in _JIT_WRAPPERS:
                for arg in node.args[:1]:
                    add_ref(arg, node)
                for kw in node.keywords:
                    if kw.arg == "fun":
                        add_ref(kw.value, node)
            elif name in _TRACE_CONSUMERS:
                for arg in node.args:
                    add_ref(arg, node)
                for kw in node.keywords:
                    add_ref(kw.value, node)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                dname = call_name(dec) if isinstance(dec, ast.Call) else None
                if dname is None and isinstance(dec, (ast.Name, ast.Attribute)):
                    dname = dotted_name(dec)
                if dname in _JIT_WRAPPERS:
                    seeds.add(node)
    return seeds


def _propagate(seeds: set[ast.AST], resolver: _Resolver) -> set[ast.AST]:
    """Extend seeds through direct local calls (``f(...)`` by bare name)."""
    traced = set(seeds)
    frontier = list(seeds)
    while frontier:
        fn = frontier.pop()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                callee = resolver.resolve_name(node.func.id, node)
                if callee is not None and callee not in traced:
                    traced.add(callee)
                    frontier.append(callee)
    return traced


def _array_params(fn: ast.AST) -> set[str]:
    names: set[str] = set()
    args = fn.args
    for a in [*args.posonlyargs, *args.args, *args.kwonlyargs]:
        if a.annotation is not None:
            try:
                ann = ast.unparse(a.annotation)
            except Exception:
                continue
            if any(marker in ann for marker in _ARRAY_ANN_MARKERS):
                names.add(a.arg)
    return names


def _dynamic_array_ref(
    expr: ast.AST, array_params: set[str], parents: dict[ast.AST, ast.AST]
) -> bool:
    """Does ``expr`` reference an array param *as a value* (not just its
    static metadata)?"""
    for node in ast.walk(expr):
        if not (isinstance(node, ast.Name) and node.id in array_params):
            continue
        static = False
        cur: ast.AST = node
        parent = parents.get(cur)
        while parent is not None:
            if isinstance(parent, ast.Attribute) and parent.value is cur:
                if parent.attr in _STATIC_ATTRS:
                    static = True
                break
            if isinstance(parent, ast.Call) and isinstance(parent.func, ast.Name):
                if parent.func.id in ("len", "isinstance", "type"):
                    static = True
                    break
            if isinstance(parent, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in parent.ops
            ):
                static = True
                break
            if parent is expr:
                break
            cur, parent = parent, parents.get(parent)
        if not static:
            return True
    return False


def _is_none_check(test: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` (possibly and/or-combined)."""
    if isinstance(test, ast.BoolOp):
        return all(_is_none_check(v) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_none_check(test.operand)
    return isinstance(test, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
    )


def _check_traced_fn(
    sf: SourceFile,
    fn: ast.AST,
    parents: dict[ast.AST, ast.AST],
) -> list[Finding]:
    findings: list[Finding] = []
    array_params = _array_params(fn)
    scope = scope_of(fn, parents)

    def emit(node: ast.AST, message: str) -> None:
        findings.append(
            Finding(
                rule=RULE,
                path=sf.rel,
                line=node.lineno,
                scope=scope,
                message=message,
                snippet=sf.line_text(node.lineno),
            )
        )

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = call_name(node)
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _ALWAYS_BAD_METHODS
            ):
                emit(
                    node,
                    f".{node.func.attr}() forces a host sync inside a traced "
                    "function",
                )
            elif name == "jax.block_until_ready":
                emit(node, "jax.block_until_ready() inside a traced function")
            elif name is not None and (
                name.startswith("np.") or name.startswith("numpy.")
            ):
                if array_params and any(
                    _dynamic_array_ref(arg, array_params, parents)
                    for arg in [*node.args, *[kw.value for kw in node.keywords]]
                ):
                    emit(
                        node,
                        f"{name}() on a traced array argument (host numpy "
                        "inside a traced function; use jnp)",
                    )
            elif (
                name in _COERCIONS
                and array_params
                and node.args
                and _dynamic_array_ref(node.args[0], array_params, parents)
            ):
                emit(
                    node,
                    f"{name}() coerces a traced value to a Python scalar "
                    "(implicit device sync / ConcretizationTypeError)",
                )
        elif isinstance(node, (ast.If, ast.While)):
            if (
                array_params
                and not _is_none_check(node.test)
                and _dynamic_array_ref(node.test, array_params, parents)
            ):
                kind = "if" if isinstance(node, ast.If) else "while"
                emit(
                    node,
                    f"Python `{kind}` on a traced value (use lax.cond / "
                    "jnp.where)",
                )
        elif isinstance(node, ast.For):
            if array_params and _dynamic_array_ref(node.iter, array_params, parents):
                emit(
                    node,
                    "Python `for` over a traced value (use lax.fori_loop / "
                    "lax.scan)",
                )
    return findings


def _check_dispatch_file(sf: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    parents = build_parents(sf.tree)
    for fn in ast.walk(sf.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        peel_lines = [
            node.lineno
            for node in ast.walk(fn)
            if isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "peel"
        ]
        if not peel_lines:
            continue
        first_dispatch = min(peel_lines)
        scope = scope_of(fn, parents)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call) and node.lineno < first_dispatch):
                continue
            if call_name(node) not in _DISPATCH_READBACKS or not node.args:
                continue
            touches_packed = any(
                isinstance(sub, ast.Attribute) and sub.attr == "problem"
                for sub in ast.walk(node.args[0])
            )
            if touches_packed:
                findings.append(
                    Finding(
                        rule=RULE,
                        path=sf.rel,
                        line=node.lineno,
                        scope=scope,
                        message=(
                            f"{call_name(node)}() reads a packed device array "
                            "back to host before dispatch (blocks the dispatch "
                            "path on a device sync)"
                        ),
                        snippet=sf.line_text(node.lineno),
                    )
                )
    return findings


def check(ctx: AnalysisContext) -> list[Finding]:
    findings: list[Finding] = []
    for rel in ctx.config.trace_files:
        sf = ctx.get(rel)
        if sf is None:
            continue
        parents = build_parents(sf.tree)
        resolver = _Resolver(sf.tree, parents)
        traced = _propagate(_seed_traced(sf.tree, resolver), resolver)
        # Skip traced fns nested inside another traced fn: the outer walk
        # already visits their bodies.
        for fn in traced:
            enclosing = parents.get(fn)
            skip = False
            while enclosing is not None:
                if enclosing in traced:
                    skip = True
                    break
                enclosing = parents.get(enclosing)
            if not skip:
                findings.extend(_check_traced_fn(sf, fn, parents))
    for rel in ctx.config.dispatch_files:
        sf = ctx.get(rel)
        if sf is not None:
            findings.extend(_check_dispatch_file(sf))
    return findings
