"""R4 — fault-site coverage.

Fault injection only means anything if the site names line up end to
end: an ``inject("dispatch_", ...)`` typo is a fault that never fires,
and a declared site no test exercises is a recovery path that has never
run.  Two checks:

* every string literal passed to ``inject(...)`` (positionally or as
  ``site=``) anywhere in ``src`` must be a member of
  ``resilience.faults.FAULT_SITES``;
* every member of ``FAULT_SITES`` must appear, as a string literal, in
  at least one test file.
"""

from __future__ import annotations

import ast

from .engine import (
    AnalysisContext,
    Finding,
    SourceFile,
    build_parents,
    call_name,
    const_str,
    scope_of,
)

RULE = "R4"

_SITES_NAME = "FAULT_SITES"


def _declared_sites(sf: SourceFile) -> tuple[set[str], int, int]:
    """(site names, first line, last line) of ``FAULT_SITES = (...)``."""
    for node in ast.walk(sf.tree):
        targets: list[ast.expr] = []
        value: ast.expr | None = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        for t in targets:
            if isinstance(t, ast.Name) and t.id == _SITES_NAME:
                sites = {
                    s
                    for elt in getattr(value, "elts", [])
                    if (s := const_str(elt)) is not None
                }
                return sites, node.lineno, node.end_lineno or node.lineno
    return set(), 1, 1


def _inject_site_literals(sf: SourceFile) -> list[tuple[str, int, str]]:
    """(site, line, scope) for every literal-site ``inject`` call."""
    parents = build_parents(sf.tree)
    out: list[tuple[str, int, str]] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name is None or name.split(".")[-1] != "inject":
            continue
        site_expr: ast.AST | None = node.args[0] if node.args else None
        for kw in node.keywords:
            if kw.arg == "site":
                site_expr = kw.value
        site = const_str(site_expr) if site_expr is not None else None
        if site is not None:
            out.append((site, node.lineno, scope_of(node, parents)))
    return out


def check(ctx: AnalysisContext) -> list[Finding]:
    faults_sf = ctx.get(ctx.config.faults_file)
    if faults_sf is None:
        return []
    sites, decl_line, decl_end = _declared_sites(faults_sf)
    if not sites:
        return [
            Finding(
                rule=RULE,
                path=faults_sf.rel,
                line=decl_line,
                scope="<module>",
                message=f"{_SITES_NAME} declaration not found or empty",
                snippet=faults_sf.line_text(decl_line),
            )
        ]

    findings: list[Finding] = []
    for rel, sf in ctx.files.items():
        for site, line, scope in _inject_site_literals(sf):
            if site not in sites:
                findings.append(
                    Finding(
                        rule=RULE,
                        path=rel,
                        line=line,
                        scope=scope,
                        message=(
                            f"inject() site {site!r} is not declared in "
                            f"{_SITES_NAME} (the fault can never be armed)"
                        ),
                        snippet=sf.line_text(line),
                    )
                )

    covered: set[str] = set()
    for sf in ctx.test_sources():
        for node in ast.walk(sf.tree):
            s = const_str(node)
            if s not in sites:
                continue
            # the declaration itself is not coverage (matters when the
            # faults file doubles as a test file, as in the fixtures)
            if sf.rel == faults_sf.rel and decl_line <= node.lineno <= decl_end:
                continue
            covered.add(s)
    for site in sorted(sites - covered):
        findings.append(
            Finding(
                rule=RULE,
                path=faults_sf.rel,
                line=decl_line,
                scope="<module>",
                message=(
                    f"fault site {site!r} is declared but no test references "
                    "it (untested recovery path)"
                ),
                snippet=f"site:{site}",
            )
        )
    return findings
