"""R3 — lock-discipline.

Lock invariants are written down next to the data they protect, and this
rule makes the annotations load-bearing:

* ``self.X = ...  # guarded-by: _lock`` on an ``__init__`` assignment
  declares that every touch of ``self.X`` outside ``__init__`` must
  happen while ``self._lock`` is held.
* ``def _register(self, ...):  # requires-lock: _lock`` declares that a
  method runs with the lock already held — its body is checked as
  locked, and every same-class call site must hold the lock.
* ``self._lock = threading.Lock()  # trusslint: io-lock`` designates a
  lock whose held regions are *allowed* to block on IO (a per-connection
  send/recv lock), opting it out of the blocking-call check only.

With those inputs the rule tracks ``with self.<lock>:`` regions through
each method and flags (a) guarded attribute access outside the guarding
lock, (b) blocking calls — socket/RPC/dispatch/subprocess/sleep — while
any non-io lock is held (lock-convoy on the routing/session path), and
(c) calls to ``requires-lock`` methods without the lock.

Scope limits, by design: only ``self.``-rooted locks and attributes are
tracked, and only within the class that declares them.  Cross-object
locking (``session._cv`` from a future) is the annotation owner's
responsibility.
"""

from __future__ import annotations

import ast
import re

from .engine import AnalysisContext, Finding, SourceFile, build_parents

RULE = "R3"

_GUARD_RE = re.compile(r"self\.(\w+)\s*(?::[^=]+)?=.*#.*guarded-by:\s*(\w+)")
_REQUIRES_RE = re.compile(r"#\s*requires-lock:\s*(\w+)")
_IO_LOCK_RE = re.compile(r"self\.(\w+)\s*(?::[^=]+)?=.*#\s*trusslint:\s*io-lock")

# Callee terminal names that block: wire IO, dispatch, subprocess, sleep.
_BLOCKING_CALLS = {
    "accept",
    "block_until_ready",
    "check_call",
    "check_output",
    "communicate",
    "connect",
    "create_connection",
    "dispatch",
    "execute",
    "health",
    "peel",
    "ping",
    "Popen",
    "recv",
    "recv_msg",
    "result",
    "rpc",
    "run_batch",
    "send_msg",
    "sendall",
    "shutdown_replica",
    "sleep",
    "solve",
    "submit",
}


def _class_span(cls: ast.ClassDef) -> tuple[int, int]:
    return cls.lineno, cls.end_lineno or cls.lineno


def _annotations(
    sf: SourceFile, cls: ast.ClassDef
) -> tuple[dict[str, str], set[str], dict[str, str]]:
    """(guarded attr -> lock, io locks, requires-lock method -> lock)."""
    lo, hi = _class_span(cls)
    guards: dict[str, str] = {}
    io_locks: set[str] = set()
    for lineno in range(lo, min(hi, len(sf.lines)) + 1):
        text = sf.lines[lineno - 1]
        m = _GUARD_RE.search(text)
        if m:
            guards[m.group(1)] = m.group(2)
        m = _IO_LOCK_RE.search(text)
        if m:
            io_locks.add(m.group(1))
    requires: dict[str, str] = {}
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            m = _REQUIRES_RE.search(sf.lines[node.lineno - 1])
            if m:
                requires[node.name] = m.group(1)
    return guards, io_locks, requires


def _with_locks(node: ast.With, lock_names: set[str]) -> set[str]:
    """Locks acquired by ``with self.<lock>[, ...]:``."""
    acquired: set[str] = set()
    for item in node.items:
        expr = item.context_expr
        if (
            isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"
            and expr.attr in lock_names
        ):
            acquired.add(expr.attr)
    return acquired


class _MethodChecker(ast.NodeVisitor):
    def __init__(
        self,
        sf: SourceFile,
        cls: ast.ClassDef,
        method: ast.AST,
        guards: dict[str, str],
        io_locks: set[str],
        requires: dict[str, str],
    ):
        self.sf = sf
        self.cls = cls
        self.method = method
        self.guards = guards
        self.io_locks = io_locks
        self.requires = requires
        self.lock_names = set(guards.values()) | io_locks | set(requires.values())
        self.held: set[str] = set()
        self.in_init = method.name == "__init__"
        self.findings: list[Finding] = []
        req = requires.get(method.name)
        if req:
            self.held.add(req)

    def _emit(self, node: ast.AST, message: str) -> None:
        self.findings.append(
            Finding(
                rule=RULE,
                path=self.sf.rel,
                line=node.lineno,
                scope=f"{self.cls.name}.{self.method.name}",
                message=message,
                snippet=self.sf.line_text(node.lineno),
            )
        )

    def visit_With(self, node: ast.With) -> None:
        acquired = _with_locks(node, self.lock_names) - self.held
        for item in node.items:
            self.visit(item.context_expr)
        self.held |= acquired
        for stmt in node.body:
            self.visit(stmt)
        self.held -= acquired

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # Nested defs (worker closures, futures) run on their own thread
        # or later in time; lock state does not flow into them.
        saved, self.held = self.held, set()
        self.generic_visit(node)
        self.held = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (
            not self.in_init
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in self.guards
            and self.guards[node.attr] not in self.held
        ):
            self._emit(
                node,
                f"self.{node.attr} is guarded-by {self.guards[node.attr]} "
                "but accessed without it",
            )
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        # requires-lock call sites (self.method(...))
        if (
            isinstance(func, ast.Attribute)
            and isinstance(func.value, ast.Name)
            and func.value.id == "self"
            and func.attr in self.requires
            and self.requires[func.attr] not in self.held
        ):
            self._emit(
                node,
                f"self.{func.attr}() requires-lock "
                f"{self.requires[func.attr]} but is called without it",
            )
        # blocking calls under a non-io lock
        hot = self.held - self.io_locks
        if hot:
            name = func.attr if isinstance(func, ast.Attribute) else (
                func.id if isinstance(func, ast.Name) else None
            )
            if name in _BLOCKING_CALLS:
                locks = ", ".join(sorted(hot))
                self._emit(
                    node,
                    f"blocking call {name}() while holding {locks} "
                    "(stalls every thread contending for the lock)",
                )
        self.generic_visit(node)


def _check_class(sf: SourceFile, cls: ast.ClassDef) -> list[Finding]:
    guards, io_locks, requires = _annotations(sf, cls)
    if not guards and not requires and not io_locks:
        return []
    findings: list[Finding] = []
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            checker = _MethodChecker(sf, cls, node, guards, io_locks, requires)
            for stmt in node.body:
                checker.visit(stmt)
            findings.extend(checker.findings)
    return findings


def check(ctx: AnalysisContext) -> list[Finding]:
    findings: list[Finding] = []
    for rel in ctx.config.lock_files:
        sf = ctx.get(rel)
        if sf is None:
            continue
        build_parents(sf.tree)
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(_check_class(sf, node))
    return findings
