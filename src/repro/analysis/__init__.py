"""repro.analysis — repo-native static-analysis suite.

Six AST-based lint rules encode invariants the generic linters cannot
see because they are *this repo's* invariants:

========  ==============================================================
R1        trace-purity: no host-side numpy / coercion / control flow on
          traced values inside jit or pallas call graphs, and no
          device->host readback on the dispatch path before ``.peel``.
R2        recompile-hazard: every attribute a jitted builder closes
          over must be folded into the compile-cache variant key.
R3        lock-discipline: ``# guarded-by:`` annotated attributes only
          touched under their lock; no blocking IO while holding a
          non-``io-lock`` lock.
R4        fault-site coverage: ``inject(site)`` literals exist in
          ``FAULT_SITES`` and every site appears in at least one test.
R5        metric-name drift: every metric name at every call site is
          declared in ``repro.obs.names``.
R6        wire-schema safety: error context whitelist stays in sync
          with the ``repro.errors`` taxonomy and every error class is
          re-raisable by name from a bare message.
========  ==============================================================

Findings carry stable fingerprints (line-number independent), so the
checked-in ``analysis/baseline.json`` survives unrelated drift.  A
finding is silenced either by the baseline or by a trailing
``# trusslint: disable=R<n>`` comment on the flagged line.

Run it: ``make lint-analysis`` or ``python -m repro.analysis``.
"""

from .engine import (
    AnalysisConfig,
    AnalysisContext,
    Finding,
    apply_baseline,
    load_baseline,
    render_text,
    report_dict,
    run,
    write_baseline,
)

__all__ = [
    "AnalysisConfig",
    "AnalysisContext",
    "Finding",
    "apply_baseline",
    "load_baseline",
    "render_text",
    "report_dict",
    "run",
    "write_baseline",
]
