"""R5 — metric-name drift.

Metrics are stringly-typed at every call site, so a renamed or typo'd
counter fails *open*: the writer happily creates a fresh series and the
dashboard/test reading the old name sees zeros forever.  This rule
closes the loop against the declared registry
(:mod:`repro.obs.names`): every string-literal metric name passed to an
``inc`` / ``observe`` / ``set_gauge`` / ``value`` / ``gauge_value`` /
``histogram`` call — in src, tests, and benchmarks — must be declared,
and so must every key of a dict literal passed to ``ingest``.

Dynamic names (variables, f-strings) are skipped; the rule checks what
it can prove, not what it can guess.
"""

from __future__ import annotations

import ast

from .engine import (
    AnalysisContext,
    Finding,
    SourceFile,
    build_parents,
    const_str,
    scope_of,
)

RULE = "R5"

_NAME_CALLS = {
    "inc",
    "observe",
    "set_gauge",
    "value",
    "gauge_value",
    "histogram",
}
_INGEST_CALLS = {"ingest"}
_DECL_NAMES = {"COUNTERS", "GAUGES", "HISTOGRAMS"}


def _declared(sf: SourceFile) -> set[str]:
    declared: set[str] = set()
    for node in ast.walk(sf.tree):
        targets: list[ast.expr] = []
        value: ast.expr | None = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        if not any(
            isinstance(t, ast.Name) and t.id in _DECL_NAMES for t in targets
        ):
            continue
        for sub in ast.walk(value):
            s = const_str(sub)
            if s is not None:
                declared.add(s)
    return declared


def _check_file(sf: SourceFile, declared: set[str]) -> list[Finding]:
    findings: list[Finding] = []
    parents = build_parents(sf.tree)

    def emit(node: ast.AST, name: str, via: str) -> None:
        findings.append(
            Finding(
                rule=RULE,
                path=sf.rel,
                line=node.lineno,
                scope=scope_of(node, parents),
                message=(
                    f"metric name {name!r} (via .{via}) is not declared in "
                    "repro.obs.names — drift between writer and reader"
                ),
                snippet=sf.line_text(node.lineno),
            )
        )

    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
            continue
        attr = node.func.attr
        if attr in _NAME_CALLS and node.args:
            name = const_str(node.args[0])
            if name is not None and name not in declared:
                emit(node, name, attr)
        elif attr in _INGEST_CALLS and node.args:
            payload = node.args[0]
            if isinstance(payload, ast.Dict):
                for key in payload.keys:
                    name = const_str(key) if key is not None else None
                    if name is not None and name not in declared:
                        emit(key, name, attr)
    return findings


def check(ctx: AnalysisContext) -> list[Finding]:
    names_sf = ctx.get(ctx.config.names_file)
    if names_sf is None:
        return [
            Finding(
                rule=RULE,
                path=ctx.config.names_file,
                line=1,
                scope="<module>",
                message="metric-name registry file is missing",
                snippet="",
            )
        ]
    declared = _declared(names_sf)
    if not declared:
        return [
            Finding(
                rule=RULE,
                path=names_sf.rel,
                line=1,
                scope="<module>",
                message="metric-name registry declares no names",
                snippet=names_sf.line_text(1),
            )
        ]
    findings: list[Finding] = []
    for rel in ctx.config.metric_ref_files:
        sf = ctx.get(rel)
        if sf is not None and rel != ctx.config.names_file:
            findings.extend(_check_file(sf, declared))
    return findings
