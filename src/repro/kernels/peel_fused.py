"""Fused persistent peel megakernel: one Pallas launch per truss level.

The unfused Pallas backend mirrors the XLA formulation — per while-loop
trip it re-gathers (E, W) neighbor windows in XLA, calls the intersection
kernel over *every* edge tile, and returns to HBM for the prune — so a
level costs several full passes over the packed problem even when most
lanes are long dead.  PKT's observation is that the whole level peel is
one synchronized parallel region; this kernel is that formulation: a
single ``pl.pallas_call`` (no grid — one persistent program holding the
whole packed problem in kernel memory) runs support computation, the
per-slot threshold compare, and the edge-removal scatter to a fixed
point, with the frontier (``alive``) and per-slot ``cur_k``/``done``
state living in on-chip refs for the entire level.  The host dispatches
one kernel per level instead of one support + one prune round-trip per
*iteration*.

Being resident pays twice:

- **Dead-block skipping** — edge lanes are tiled by ``block`` and a tile
  with no free alive lane is skipped via ``lax.cond`` (its window
  gathers and intersections never execute).  Retired slots of an
  imbalanced batch and the long tail of late peel levels are exactly
  where most tiles are dead — the unfused path pays full price there.
- **No HBM round-trips inside a level** — the support→prune→converge
  loop is ``lax.while_loop`` *inside* the kernel over on-chip state.

Bit-identity with the XLA/unfused peel is structural: slots are
block-diagonal and independent, supports are exact integer counts, and
the per-slot iteration/level bookkeeping below replays ``build_peel``'s
trajectory per slot (a per-slot convergence latch counts exactly the
trips the unfused loop would have counted).  ``block`` and ``schedule``
are the autotuned knobs (``repro.kernels.autotune``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..exec.peel import PeelState
from .ops import on_tpu

__all__ = ["make_fused_level"]

_LANES = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _intersect_compare(a_nav, a_ok, b_nav, b_ok):
    """Chunked O(W²) broadcast-equality count (VPU slab schedule)."""
    w = a_nav.shape[1]
    found = jnp.zeros(a_nav.shape, jnp.bool_)
    for c0 in range(0, w, _LANES):
        bn = b_nav[:, c0 : c0 + _LANES]
        bo = b_ok[:, c0 : c0 + _LANES]
        eq = (a_nav[:, :, None] == bn[:, None, :]) & bo[:, None, :]
        found |= jnp.any(eq, axis=2)
    return jnp.sum((found & a_ok).astype(jnp.int32), axis=1)


def _intersect_bsearch(a_nav, a_ok, b_nav, b_ok):
    """Branchless binary-search count — needs strictly ascending b rows."""
    w = b_nav.shape[1]
    lo = jnp.zeros(a_nav.shape, jnp.int32)
    hi = jnp.full(a_nav.shape, w, jnp.int32)
    big = jnp.iinfo(b_nav.dtype).max
    for _ in range(max(1, int(np.ceil(np.log2(w + 1))))):
        mid = (lo + hi) >> 1
        bm = jnp.take_along_axis(
            b_nav, jnp.clip(mid, 0, w - 1), axis=1, mode="clip"
        )
        bm = jnp.where(mid >= w, big, bm)
        go_right = bm < a_nav
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    safe = jnp.minimum(lo, w - 1)
    hit = jnp.take_along_axis(b_nav, safe, axis=1, mode="clip") == a_nav
    hit &= jnp.take_along_axis(b_ok, safe, axis=1, mode="clip") & a_ok & (lo < w)
    return jnp.sum(hit.astype(jnp.int32), axis=1)


def _level_kernel(
    # problem refs
    colidx_ref,
    edge_row_ref,
    urowptr_ref,
    ucolidx_ref,
    u2d_ref,
    udeg_ref,
    # peel-state refs (bools as int32)
    alive_ref,
    truss_ref,
    cur_k_ref,
    kmax_ref,
    levels_ref,
    iters_ref,
    done_ref,
    edges_ref,
    titers_ref,
    # frozen-lane refs
    frozen_ref,
    ftruss_ref,
    single_ref,
    # outputs
    o_alive,
    o_supp,
    o_truss,
    o_cur_k,
    o_kmax,
    o_levels,
    o_iters,
    o_done,
    o_titers,
    o_edges,
    *,
    block: int,
    w: int,
    slot_nnz: int,
    num_slots: int,
    schedule: str,
    inner_limit: int,
):
    colidx = colidx_ref[...]
    edge_row = edge_row_ref[...]
    urowptr = urowptr_ref[...]
    ucolidx = ucolidx_ref[...]
    u2d = u2d_ref[...]
    udeg = udeg_ref[...]
    truss = truss_ref[...]
    cur_k = cur_k_ref[...]
    done = done_ref[...] != 0
    frozen = frozen_ref[...] != 0
    ftruss = ftruss_ref[...]
    single = single_ref[...] != 0

    nnzp = colidx.shape[0]
    unnzp = ucolidx.shape[0]
    large = jnp.int32((urowptr.shape[0] - 1) + 2)  # p.n + 2 sentinel
    offs = jnp.arange(w, dtype=jnp.int32)[None, :]
    cur_k_lane = jnp.repeat(cur_k, slot_nnz)
    # The threshold is fixed for the whole level, so the frozen lanes'
    # effective-alive contribution is too — computed once per launch.
    frozen_live = frozen & (ftruss >= cur_k_lane)
    intersect = _intersect_compare if schedule == "compare" else _intersect_bsearch

    def row_window(v, ualive):
        start = urowptr[jnp.maximum(v, 1) - 1] * (v > 0)
        idx = start[:, None] + offs
        n_in = offs < udeg[v][:, None]
        idx_c = jnp.clip(idx, 0, unnzp - 1)
        nav = jnp.where(n_in, ucolidx[idx_c], large)
        return nav, n_in & ualive[idx_c]

    def support_of(alive_free):
        eff = alive_free | frozen_live
        eff_pad = jnp.concatenate([eff, jnp.zeros((1,), jnp.bool_)])
        ualive = eff_pad[jnp.minimum(u2d, nnzp)] & (ucolidx != 0)

        def blk(i, s_acc):
            at = i * block
            alive_blk = jax.lax.dynamic_slice(alive_free, (at,), (block,))

            def compute(_):
                a = jax.lax.dynamic_slice(edge_row, (at,), (block,))
                b = jax.lax.dynamic_slice(colidx, (at,), (block,))
                eff_blk = jax.lax.dynamic_slice(eff, (at,), (block,))
                valid_t = (b != 0) & eff_blk
                a_nav, a_al = row_window(a, ualive)
                b_nav, b_al = row_window(b, ualive)
                a_ok = a_al & valid_t[:, None] & (a_nav < large)
                counts = intersect(a_nav, a_ok, b_nav, b_al)
                return counts * valid_t.astype(jnp.int32)

            # The fused win: a tile with no *free* alive lane contributes
            # nothing downstream (outputs are masked by the next alive
            # set, which only shrinks), so its gathers + intersection are
            # skipped outright.  Retired slots and late-level tails make
            # most tiles dead — the unfused path still pays for them.
            counts = jax.lax.cond(
                jnp.any(alive_blk),
                compute,
                lambda _: jnp.zeros((block,), jnp.int32),
                operand=None,
            )
            return jax.lax.dynamic_update_slice(s_acc, counts, (at,))

        return jax.lax.fori_loop(
            0, nnzp // block, blk, jnp.zeros((nnzp,), jnp.int32)
        )

    # Support → prune to this level's fixed point without leaving the
    # kernel.  Per-slot latch replays build_peel's bookkeeping exactly:
    # a live slot counts one iteration per trip until the trip where none
    # of its lanes changed (its convergence trip), then waits uncounted —
    # in the unfused trajectory it would already be peeling its next
    # level, whose trips the next launch counts instead.
    def fcond(carry):
        _alive_c, _s, latch, _iters_c, trips = carry
        return jnp.any(~latch & ~done) & (trips < inner_limit)

    def fbody(carry):
        alive_c, _s, latch, iters_c, trips = carry
        s = support_of(alive_c)
        new_alive = alive_c & (s >= cur_k_lane - 2)
        changed = (
            (new_alive ^ alive_c)
            .astype(jnp.int32)
            .reshape(num_slots, slot_nnz)
            .sum(axis=1)
        )
        live = ~latch & ~done
        iters_c = iters_c + live.astype(jnp.int32)
        latch = latch | (live & (changed == 0))
        return new_alive, s, latch, iters_c, trips + jnp.int32(1)

    alive0 = alive_ref[...] != 0
    carry = (
        alive0,
        jnp.zeros((nnzp,), jnp.int32),
        jnp.zeros((num_slots,), jnp.bool_),
        iters_ref[...],
        jnp.int32(0),
    )
    alive_fp, s_fp, latch, iters_new, trips = jax.lax.while_loop(
        fcond, fbody, carry
    )

    # Level bookkeeping — the same algebra as build_peel's converged
    # branch, applied once per launch.
    converged = latch & ~done
    conv_lane = jnp.repeat(converged, slot_nnz)
    truss_new = jnp.where(conv_lane & alive_fp, cur_k_lane, truss)
    left = alive_fp.astype(jnp.int32).reshape(num_slots, slot_nnz).sum(axis=1)
    nonempty = left > 0
    retired = converged & (~nonempty | single)
    cur_k_new = jnp.where(converged & ~retired, cur_k + 1, cur_k)
    # Prune-ahead against the advanced threshold with the support in hand
    # (identical reasoning to build_peel: support is monotone in alive).
    alive_next = alive_fp & (s_fp >= jnp.repeat(cur_k_new, slot_nnz) - 2)

    o_alive[...] = alive_next.astype(jnp.int32)
    o_supp[...] = s_fp * alive_next.astype(jnp.int32)
    o_truss[...] = truss_new
    o_cur_k[...] = cur_k_new
    o_kmax[...] = jnp.where(converged & nonempty, cur_k, kmax_ref[...])
    o_levels[...] = levels_ref[...] + converged.astype(jnp.int32)
    o_iters[...] = iters_new
    o_done[...] = (done | retired).astype(jnp.int32)
    o_titers[...] = titers_ref[...] + trips
    o_edges[...] = jnp.where(done, edges_ref[...], left)


def make_fused_level(
    *,
    window: int,
    block: int = 128,
    schedule: str = "compare",
    interpret: bool | None = None,
):
    """Build the jitted one-launch-per-level step for one configuration.

    Returns ``level_step(p, state, frozen, frozen_truss, single_level) ->
    PeelState`` advancing every live slot by exactly one truss level in a
    single ``pl.pallas_call``.  ``window`` must cover the bucket's max
    undirected degree; ``block`` must divide the packed ``slot_nnz``
    (validated here and, with slot attribution, by
    ``graphs.pack.validate_fused_tiling``).
    """
    if schedule not in ("compare", "bsearch"):
        raise ValueError(f"unknown fused schedule {schedule!r}")
    if block < 1 or (block & (block - 1)) != 0:
        raise ValueError(f"fused block must be a power of two, got {block}")
    run_interpret = (not on_tpu()) if interpret is None else interpret
    w = _round_up(max(int(window), _LANES), _LANES)

    @jax.jit
    def level_step(p, state, frozen, frozen_truss, single_level):
        nnzp = int(p.colidx.shape[0])
        num_slots = int(state.cur_k.shape[0])
        if num_slots < 1 or nnzp % num_slots:
            raise ValueError(
                f"nnz_pad={nnzp} does not split into {num_slots} slots"
            )
        slot_nnz = nnzp // num_slots
        if slot_nnz % block:
            raise ValueError(
                f"fused block={block} does not divide slot_nnz={slot_nnz}"
            )
        kernel = functools.partial(
            _level_kernel,
            block=block,
            w=w,
            slot_nnz=slot_nnz,
            num_slots=num_slots,
            schedule=schedule,
            # Per level a live slot prunes >= 1 of its <= slot_nnz free
            # lanes per trip until its convergence trip: provable cap.
            inner_limit=slot_nnz + 2,
        )
        shp = jax.ShapeDtypeStruct
        i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
        outs = pl.pallas_call(
            kernel,
            out_shape=[
                shp((nnzp,), jnp.int32),  # alive
                shp((nnzp,), jnp.int32),  # support
                shp((nnzp,), jnp.int32),  # trussness
                shp((num_slots,), jnp.int32),  # cur_k
                shp((num_slots,), jnp.int32),  # kmax
                shp((num_slots,), jnp.int32),  # levels
                shp((num_slots,), jnp.int32),  # iters
                shp((num_slots,), jnp.int32),  # done
                shp((1,), jnp.int32),  # total_iters
                shp((num_slots,), jnp.int32),  # edges_alive
            ],
            interpret=run_interpret,
        )(
            p.colidx,
            p.edge_row,
            p.urowptr,
            p.ucolidx,
            p.u2d,
            p.udeg,
            i32(state.alive),
            state.trussness,
            state.cur_k,
            state.kmax,
            state.levels,
            state.iters,
            i32(state.done),
            state.edges_alive,
            jnp.reshape(state.total_iters, (1,)),
            i32(frozen),
            frozen_truss,
            i32(single_level),
        )
        alive, supp, truss, cur_k, kmax, levels, iters, done, titers, edges = outs
        return PeelState(
            alive=alive != 0,
            support=supp,
            trussness=truss,
            cur_k=cur_k,
            kmax=kmax,
            levels=levels,
            iters=iters,
            done=done != 0,
            total_iters=titers[0],
            edges_alive=edges,
        )

    return level_step
