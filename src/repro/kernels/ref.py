"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

Every kernel in this package has a reference here with identical
input/output semantics; the test suite sweeps shapes/dtypes and asserts
``assert_allclose(kernel(interpret=True), ref)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["support_tiles_ref", "support_dense_ref"]


def support_tiles_ref(
    a_nav: jax.Array,
    a_ok: jax.Array,
    b_nav: jax.Array,
    b_ok: jax.Array,
) -> jax.Array:
    """Per-edge sorted-window intersection counts (owner-mode support).

    Args:
      a_nav: (E, W) int32 — query window per edge (invalid lanes hold a
        sentinel ≥ LARGE; they are excluded via ``a_ok``).
      a_ok:  (E, W) bool — query lane validity (structural ∧ alive).
      b_nav: (E, W) int32 — ascending navigation window (invalid = LARGE).
      b_ok:  (E, W) bool — membership lane validity of ``b_nav``.

    Returns:
      (E,) int32 — |{w : a_ok[e,w] ∧ ∃w': b_nav[e,w'] == a_nav[e,w] ∧ b_ok[e,w']}|
    """
    # O(W²) dense equality — deliberately the most literal semantics.
    eq = a_nav[:, :, None] == b_nav[:, None, :]
    eq &= a_ok[:, :, None] & b_ok[:, None, :]
    return jnp.sum(jnp.any(eq, axis=2), axis=1).astype(jnp.int32)


def support_dense_ref(u_sym: jax.Array) -> jax.Array:
    """Dense linear-algebraic support: S = (U @ U) ∘ U (Algorithm 1)."""
    return (u_sym @ u_sym) * u_sym
