"""jit'd wrappers around the Pallas kernels (padding, window prep, chunking).

``support_fine`` matches the ``alive -> support`` contract of
``repro.core.truss.make_support_fn`` so ``KTrussEngine(backend="pallas")``
drops it in transparently: XLA performs the bandwidth-bound window gathers,
the Pallas kernel performs the compute-bound intersections, and a
``lax.scan`` pipelines edge chunks so peak memory stays at
``chunk × window`` regardless of graph size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.eager_fine import FineProblem
from .support_dense import support_dense_pallas
from .support_fine import support_fine_pallas

__all__ = ["support_fine", "support_fine_stacked", "support_dense", "on_tpu"]

_LANES = 128


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def support_fine(
    p: FineProblem,
    alive: jax.Array,
    *,
    window: int,
    chunk: int = 1024,
    tile: int = 256,
    schedule: str = "compare",
    interpret: bool | None = None,
) -> jax.Array:
    """Owner-mode fine-grained support via the Pallas edge-tile kernel.

    Semantically identical to
    :func:`repro.core.eager_fine.support_fine_owner` (property-tested).
    """
    nnzp = p.nnz_pad
    if nnzp % chunk or chunk % tile:
        raise ValueError(f"need tile | chunk | nnz_pad, got {tile}/{chunk}/{nnzp}")
    w = _round_up(max(int(window), _LANES), _LANES)
    interpret = (not on_tpu()) if interpret is None else interpret

    unnzp = int(p.ucolidx.shape[0])
    large = jnp.int32(p.n + 2)
    offs = jnp.arange(w, dtype=jnp.int32)[None, :]

    alive_pad = jnp.concatenate([alive, jnp.zeros((1,), alive.dtype)])
    ualive = alive_pad[jnp.minimum(p.u2d, nnzp)] & (p.ucolidx != 0)

    def row_window(v: jax.Array):
        start = p.urowptr[jnp.maximum(v, 1) - 1] * (v > 0)
        idx = start[:, None] + offs
        n_in = offs < p.udeg[v][:, None]
        idx_c = jnp.clip(idx, 0, unnzp - 1)
        nav = jnp.where(n_in, p.ucolidx[idx_c], large)
        return nav, n_in & ualive[idx_c]

    def body(_, chunk_start: jax.Array):
        t = chunk_start + jnp.arange(chunk, dtype=jnp.int32)
        a, b = p.edge_row[t], p.colidx[t]
        valid_t = (b != 0) & alive[t]
        a_nav, a_alive = row_window(a)
        b_nav, b_alive = row_window(b)
        a_ok = a_alive & valid_t[:, None] & (a_nav < large)
        counts = support_fine_pallas(
            a_nav,
            a_ok,
            b_nav,
            b_alive,
            tile=tile,
            schedule=schedule,
            interpret=interpret,
        )
        return _, counts * valid_t.astype(jnp.int32)

    starts = jnp.arange(0, nnzp, chunk, dtype=jnp.int32)
    _, s_chunks = jax.lax.scan(body, None, starts)
    return s_chunks.reshape(-1)


def support_fine_stacked(
    p: FineProblem,
    alive: jax.Array,
    *,
    window: int,
    chunk: int = 1024,
    tile: int = 256,
    schedule: str = "compare",
    interpret: bool | None = None,
) -> jax.Array:
    """Batched Pallas ``alive -> support`` over a leading batch axis.

    Mirrors :func:`repro.core.eager_fine.support_fine_stacked` for the
    kernel backend: ``p``'s fields carry a leading ``(B, ...)`` dimension
    (same shape bucket for all members) and the batch runs through one
    ``lax.map``-sequenced program — one dispatch per micro-batch.
    """
    fn = functools.partial(
        support_fine,
        window=window,
        chunk=chunk,
        tile=tile,
        schedule=schedule,
        interpret=interpret,
    )
    return jax.lax.map(lambda pa: fn(pa[0], pa[1]), (p, alive))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def support_dense(
    u_sym: jax.Array, *, block: int = 128, interpret: bool | None = None
) -> jax.Array:
    """S = (U @ U) ∘ U with automatic padding to the block size."""
    interpret = (not on_tpu()) if interpret is None else interpret
    v = u_sym.shape[0]
    vp = _round_up(v, block)
    u = jnp.zeros((vp, vp), jnp.float32).at[:v, :v].set(u_sym.astype(jnp.float32))
    s = support_dense_pallas(u, block=block, interpret=interpret)
    return s[:v, :v]
