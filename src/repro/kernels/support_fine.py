"""Fine-grained edge-tile support kernel (Pallas TPU).

TPU-native adaptation of Algorithm 3 (DESIGN.md §2/§4): the grid iterates
over **uniform tiles of T edge tasks** — the paper's flat nonzero range —
and each tile intersects two pre-gathered sorted neighbor windows of width
``W`` per edge.  Ownership partitioning (each edge's support produced by its
own tile) replaces GPU atomics; the eager triple-update is recovered
algebraically by intersecting *undirected* neighborhoods (property-tested
against the faithful scatter implementation).

Hot loop layout:
  * Tile shapes are (T, W) int32 blocks in VMEM; T=128..512, W a multiple of
    the 128-lane VPU width.  VMEM per tile: 4 inputs × T×W×4B (e.g.
    256×512 → 2.0 MiB — comfortably inside the ~16 MiB v5e VMEM).
  * Two selectable inner schedules:
      - ``compare``: chunked O(W²) broadcast equality over 128-lane slabs of
        the navigation window.  Pure VPU compare/OR-reduce; no gathers; the
        conservative, guaranteed-lowerable schedule.
      - ``bsearch``: branchless binary search, ``ceil(log2(W+1))`` rounds of
        take-along-axis — O(W log W), the schedule the XLA path uses.
  * Output block is (T, 1) int32 counts.

The window gather that feeds this kernel stays in XLA (it is a bandwidth-
bound gather that XLA already emits optimally; the kernel owns the
compute-bound intersection).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["support_fine_pallas"]

_LANES = 128


def _kernel_compare(a_nav_ref, a_ok_ref, b_nav_ref, b_ok_ref, out_ref):
    """Chunked O(W²) broadcast-equality intersection count."""
    a_nav = a_nav_ref[...]  # (T, W)
    a_ok = a_ok_ref[...] != 0
    w = a_nav.shape[1]
    found = jnp.zeros(a_nav.shape, jnp.bool_)
    # Slab over the navigation window in 128-lane chunks: VPU-native
    # compare + OR-reduce; trip count is static (W is a block constant).
    for c0 in range(0, w, _LANES):
        b_nav = b_nav_ref[:, c0 : c0 + _LANES]  # (T, 128)
        b_ok = b_ok_ref[:, c0 : c0 + _LANES] != 0
        eq = (a_nav[:, :, None] == b_nav[:, None, :]) & b_ok[:, None, :]
        found |= jnp.any(eq, axis=2)
    counts = jnp.sum((found & a_ok).astype(jnp.int32), axis=1, keepdims=True)
    out_ref[...] = counts


def _kernel_bsearch(a_nav_ref, a_ok_ref, b_nav_ref, b_ok_ref, out_ref):
    """Branchless binary-search intersection count (O(W log W))."""
    a_nav = a_nav_ref[...]
    a_ok = a_ok_ref[...] != 0
    b_nav = b_nav_ref[...]
    b_ok = b_ok_ref[...] != 0
    w = b_nav.shape[1]
    lo = jnp.zeros(a_nav.shape, jnp.int32)
    hi = jnp.full(a_nav.shape, w, jnp.int32)
    big = jnp.iinfo(b_nav.dtype).max
    for _ in range(max(1, int(np.ceil(np.log2(w + 1))))):
        mid = (lo + hi) >> 1
        bm = jnp.take_along_axis(b_nav, jnp.clip(mid, 0, w - 1), axis=1, mode="clip")
        # Out-of-range probes (lo == hi == w) must never move lo further.
        bm = jnp.where(mid >= w, big, bm)
        go_right = bm < a_nav
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    safe = jnp.minimum(lo, w - 1)
    hit = jnp.take_along_axis(b_nav, safe, axis=1, mode="clip") == a_nav
    hit &= jnp.take_along_axis(b_ok, safe, axis=1, mode="clip") & a_ok & (lo < w)
    out_ref[...] = jnp.sum(hit.astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("tile", "schedule", "interpret")
)
def support_fine_pallas(
    a_nav: jax.Array,
    a_ok: jax.Array,
    b_nav: jax.Array,
    b_ok: jax.Array,
    *,
    tile: int = 256,
    schedule: str = "compare",
    interpret: bool = True,
) -> jax.Array:
    """Intersection counts for E edges from pre-gathered (E, W) windows.

    Args / semantics match :func:`repro.kernels.ref.support_tiles_ref`.
    E must be a multiple of ``tile``; W a multiple of 128 (the wrapper in
    ``ops.py`` pads both).

    Precondition (CSR rows satisfy it by construction): valid lanes of
    ``b_nav`` are **strictly** ascending — the ``bsearch`` schedule locates
    the unique first occurrence, so duplicate values with mixed ``b_ok``
    would under-count.  The ``compare`` schedule has no such requirement.
    """
    e, w = a_nav.shape
    if e % tile:
        raise ValueError(f"E={e} not a multiple of tile={tile}")
    if w % _LANES:
        raise ValueError(f"W={w} not a multiple of {_LANES}")
    kernel = _kernel_compare if schedule == "compare" else _kernel_bsearch

    in_spec = pl.BlockSpec((tile, w), lambda g: (g, 0))
    out = pl.pallas_call(
        kernel,
        grid=(e // tile,),
        in_specs=[in_spec, in_spec, in_spec, in_spec],
        out_specs=pl.BlockSpec((tile, 1), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((e, 1), jnp.int32),
        interpret=interpret,
    )(
        a_nav.astype(jnp.int32),
        a_ok.astype(jnp.int32),
        b_nav.astype(jnp.int32),
        b_ok.astype(jnp.int32),
    )
    return out[:, 0]
