"""Blocked linear-algebraic support kernel: S = (U @ U) ∘ U on the MXU.

This is Algorithm 1 of the paper executed the way a systolic array wants it:
the symmetric 0/1 adjacency is tiled into (B, B) VMEM blocks and the
support matrix block S[i,j] accumulates Σ_k U[i,k] @ U[k,j] on the MXU, with
the elementwise ∘ U[i,j] mask applied on the final k step.  It is the
*dense/coarse* counterpart against which the fine-grained edge-tile kernel
is compared: FLOP-rich and perfectly load balanced, but O(V³/B) work
independent of sparsity — which is exactly the trade the paper's Figure 4
exposes (dense linear-algebra wins only on small, dense graphs).

Grid: (V/B, V/B, V/B) with k innermost ("arbitrary"); f32 accumulation in a
VMEM scratch block (ids are counts ≤ degree, exactly representable in f32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["support_dense_pallas"]


def _kernel(u_ik_ref, u_kj_ref, u_ij_ref, out_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        u_ik_ref[...], u_kj_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _finalize():
        out_ref[...] = acc_ref[...] * u_ij_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def support_dense_pallas(
    u_sym: jax.Array, *, block: int = 128, interpret: bool = True
) -> jax.Array:
    """S = (U @ U) ∘ U for a dense 0/1 symmetric adjacency (f32).

    V must be a multiple of ``block`` (the ops.py wrapper pads; padded
    rows/cols are all-zero so they contribute nothing).
    """
    v = u_sym.shape[0]
    if u_sym.shape != (v, v):
        raise ValueError(f"expected square adjacency, got {u_sym.shape}")
    if v % block:
        raise ValueError(f"V={v} not a multiple of block={block}")
    steps = v // block

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=steps),
        grid=(steps, steps, steps),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),  # U[i,k]
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),  # U[k,j]
            pl.BlockSpec((block, block), lambda i, j, k: (i, j)),  # mask
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((v, v), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        interpret=interpret,
    )(u_sym.astype(jnp.float32), u_sym.astype(jnp.float32), u_sym.astype(jnp.float32))
