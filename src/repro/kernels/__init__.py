"""Pallas TPU kernels for the paper's compute hot-spot (computeSupports).

``support_fine``  — fine-grained edge-tile intersection kernel (Alg. 3).
``support_dense`` — blocked (U@U)∘U MXU kernel (Alg. 1).
Validated in interpret mode against ``ref.py`` on CPU; written for TPU
(BlockSpec VMEM tiling, MXU dots, VPU compare-reduce schedules).
"""

from . import ops, ref
from .support_dense import support_dense_pallas
from .support_fine import support_fine_pallas

__all__ = [
    "ops",
    "ref",
    "support_dense_pallas",
    "support_fine_pallas",
]
