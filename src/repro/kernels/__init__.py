"""Pallas TPU kernels for the paper's compute hot-spot (computeSupports).

``support_fine``  — fine-grained edge-tile intersection kernel (Alg. 3).
``support_dense`` — blocked (U@U)∘U MXU kernel (Alg. 1).
``peel_fused``    — persistent peel megakernel: support + prune + level
                    bookkeeping fused into one launch per truss level.
``autotune``      — per-bucket config sweep/store for the fused kernel.
Validated in interpret mode against ``ref.py`` on CPU; written for TPU
(BlockSpec VMEM tiling, MXU dots, VPU compare-reduce schedules).
"""

from . import autotune, ops, ref
from .autotune import FusedConfig
from .peel_fused import make_fused_level
from .support_dense import support_dense_pallas
from .support_fine import support_fine_pallas

__all__ = [
    "autotune",
    "ops",
    "ref",
    "FusedConfig",
    "make_fused_level",
    "support_dense_pallas",
    "support_fine_pallas",
]
