"""Per-bucket autotuning for the fused peel megakernel.

The fused backend (``repro.kernels.peel_fused``) has real tuning knobs —
the edge-block tile it skips dead work at, the intersection schedule, and
(recorded for the next process start) the XLA flag set — and the best
point differs per shape bucket: small windows favour the compare slab,
large windows the branchless bsearch, and the paying block size tracks
``slot_nnz``.  This module is the saxml-style tuned-config store for
those knobs:

- :class:`FusedConfig` — one immutable candidate point.
- :func:`autotune_fused` — sweep candidates on a representative packed
  batch for one ``(bucket, slots)`` and persist the winner.
- :class:`AutotuneStore` — JSON store living next to the persistent
  compile cache (``<cache_dir>/autotune.json``; wired by
  ``repro.api.cache.enable_persistent_cache``) so a warm process replays
  tuned configs instead of re-sweeping.
- :func:`lookup` — what the planner calls per ``(bucket, slots)`` when it
  builds a fused executor / compile-cache key.

``xla_flags`` is carried and persisted but cannot take effect
mid-process: XLA reads ``XLA_FLAGS`` once at backend init, so the store
records the winning set for the *next* start (launchers can export it);
the in-process sweep dimension is block × schedule.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
from typing import Iterable, Sequence

__all__ = [
    "FusedConfig",
    "AutotuneStore",
    "set_store",
    "get_store",
    "lookup",
    "candidate_configs",
    "autotune_fused",
    "DEFAULT_BLOCKS",
    "DEFAULT_SCHEDULES",
    "DEFAULT_XLA_FLAG_SETS",
]

DEFAULT_BLOCKS = (64, 128, 256)
DEFAULT_SCHEDULES = ("compare", "bsearch")
# Recorded per bucket for the next process start (XLA_FLAGS is read at
# backend init, so flags are a replay-only dimension — see module doc).
DEFAULT_XLA_FLAG_SETS: tuple[tuple[str, ...], ...] = ((),)


@dataclasses.dataclass(frozen=True)
class FusedConfig:
    """One fused-kernel tuning point.

    ``block`` is the edge-lane tile the kernel iterates (and skips) in —
    a power of two that must divide the packed ``slot_nnz``; ``schedule``
    picks the in-kernel intersection ("compare" slab broadcast-equality
    vs branchless "bsearch"); ``xla_flags`` is the recorded flag set.
    """

    block: int = 128
    schedule: str = "compare"
    xla_flags: tuple[str, ...] = ()

    def __post_init__(self):
        if self.block < 1 or (self.block & (self.block - 1)) != 0:
            raise ValueError(f"block must be a power of two, got {self.block}")
        if self.schedule not in DEFAULT_SCHEDULES:
            raise ValueError(
                f"schedule must be one of {DEFAULT_SCHEDULES}, got "
                f"{self.schedule!r}"
            )
        object.__setattr__(self, "xla_flags", tuple(self.xla_flags))

    def signature(self) -> tuple:
        """Hashable identity — folded into the compile-cache variant key."""
        return (self.block, self.schedule, self.xla_flags)

    @classmethod
    def from_signature(cls, sig: Sequence) -> "FusedConfig":
        block, schedule, xla_flags = sig
        return cls(block=int(block), schedule=str(schedule),
                   xla_flags=tuple(xla_flags))

    def clamp(self, slot_nnz: int) -> "FusedConfig":
        """Shrink ``block`` to divide ``slot_nnz`` (both powers of two)."""
        block = min(self.block, int(slot_nnz)) or 1
        if block == self.block:
            return self
        return dataclasses.replace(self, block=block)

    def to_json(self) -> dict:
        return {
            "block": self.block,
            "schedule": self.schedule,
            "xla_flags": list(self.xla_flags),
        }

    @classmethod
    def from_json(cls, d: dict) -> "FusedConfig":
        return cls(
            block=int(d["block"]),
            schedule=str(d["schedule"]),
            xla_flags=tuple(d.get("xla_flags", ())),
        )


def _key(bucket, slots: int) -> str:
    n_pad, nnz_pad, window = bucket[0], bucket[1], bucket[2]
    return f"n{int(n_pad)}-nnz{int(nnz_pad)}-w{int(window)}/s{int(slots)}"


class AutotuneStore:
    """JSON-backed winning-config store, one entry per ``(bucket, slots)``.

    Saves are atomic (tmp file + rename) so concurrent processes sharing
    a cache dir never observe a torn file; a corrupt or missing file
    degrades to an empty store rather than failing warm start.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = str(path)
        self._lock = threading.Lock()
        self._entries: dict[str, dict] = {}
        self._load()

    def _load(self) -> None:
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            return
        configs = data.get("configs", {}) if isinstance(data, dict) else {}
        for k, v in configs.items():
            try:
                FusedConfig.from_json(v)
            except (KeyError, TypeError, ValueError):
                continue
            self._entries[k] = v

    def get(self, bucket, slots: int) -> FusedConfig | None:
        entry = self._entries.get(_key(bucket, slots))
        return FusedConfig.from_json(entry) if entry is not None else None

    def put(self, bucket, slots: int, config: FusedConfig,
            *, stats: dict | None = None) -> None:
        entry = config.to_json()
        if stats:
            entry["stats"] = dict(stats)
        with self._lock:
            self._entries[_key(bucket, slots)] = entry
            self._save()

    def _save(self) -> None:
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        payload = {"version": 1, "configs": self._entries}
        fd, tmp = tempfile.mkstemp(dir=parent, prefix=".autotune-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def __len__(self) -> int:
        return len(self._entries)


_STORE: AutotuneStore | None = None


def set_store(path_or_store: str | os.PathLike | AutotuneStore | None):
    """Install the process-wide store (path or instance; None disables)."""
    global _STORE
    if path_or_store is None or isinstance(path_or_store, AutotuneStore):
        _STORE = path_or_store
    else:
        _STORE = AutotuneStore(path_or_store)
    return _STORE


def get_store() -> AutotuneStore | None:
    return _STORE


def lookup(bucket, slots: int, default: FusedConfig | None = None) -> FusedConfig:
    """Tuned config for ``(bucket, slots)``; stock default on a miss."""
    if _STORE is not None:
        cfg = _STORE.get(bucket, slots)
        if cfg is not None:
            return cfg
    return default if default is not None else FusedConfig()


def candidate_configs(
    slot_nnz: int,
    *,
    blocks: Iterable[int] = DEFAULT_BLOCKS,
    schedules: Iterable[str] = DEFAULT_SCHEDULES,
    xla_flag_sets: Iterable[tuple[str, ...]] = DEFAULT_XLA_FLAG_SETS,
) -> tuple[FusedConfig, ...]:
    """The default sweep grid, clamped to ``slot_nnz`` and deduplicated."""
    out: list[FusedConfig] = []
    seen: set[tuple] = set()
    for block in blocks:
        for schedule in schedules:
            for flags in xla_flag_sets:
                cfg = FusedConfig(
                    block=int(block), schedule=schedule, xla_flags=tuple(flags)
                ).clamp(slot_nnz)
                if cfg.signature() not in seen:
                    seen.add(cfg.signature())
                    out.append(cfg)
    return tuple(out)


def autotune_fused(
    bucket,
    slots: int,
    *,
    graphs: Sequence | None = None,
    chunk: int = 64,
    candidates: Sequence[FusedConfig] | None = None,
    repeats: int = 2,
    store: AutotuneStore | None = None,
    seed: int = 0,
) -> tuple[FusedConfig, list[dict]]:
    """Sweep fused configs on one ``(bucket, slots)`` and persist the winner.

    Times a *warm* full decompose per candidate on a representative
    aligned-packed batch (``graphs``, or synthesized R-MAT members landing
    in ``bucket``), writes the fastest config to ``store`` (defaulting to
    the process store installed by ``enable_persistent_cache``), and
    returns ``(winner, sweep_rows)``.
    """
    import time

    import numpy as np

    from ..exec.peel import PeelExecutor
    from ..graphs.pack import pack_problems

    n_pad, nnz_pad, window = int(bucket[0]), int(bucket[1]), int(bucket[2])
    chunk = min(int(chunk), nnz_pad)
    if graphs is None:
        graphs = _synthesize(bucket, slots, chunk=chunk, seed=seed)
    packed = pack_problems(
        list(graphs),
        slot_n=n_pad,
        slot_nnz=nnz_pad,
        slots=slots,
        chunk=chunk,
        layout="aligned",
    )
    slot_ids = np.repeat(np.arange(slots, dtype=np.int32), nnz_pad)
    k0 = np.full(slots, 3, dtype=np.int32)
    if candidates is None:
        candidates = candidate_configs(nnz_pad)

    rows: list[dict] = []
    best: tuple[FusedConfig, float] | None = None
    for cfg in candidates:
        cfg = cfg.clamp(nnz_pad)
        exe = PeelExecutor(
            granularity="fine",
            mode="owner",
            backend="fused",
            window=window,
            chunk=chunk,
            fused_config=cfg,
        )
        exe.peel(packed.problem, slot_ids=slot_ids, k0=k0)  # warm/compile
        times = []
        for _ in range(max(1, int(repeats))):
            t0 = time.perf_counter()
            st = exe.peel(packed.problem, slot_ids=slot_ids, k0=k0)
            np.asarray(st.done)
            times.append(time.perf_counter() - t0)
        dt = min(times)
        rows.append({"config": cfg.to_json(), "best_s": dt})
        if best is None or dt < best[1]:
            best = (cfg, dt)
    assert best is not None, "empty candidate sweep"
    winner, dt = best
    target = store if store is not None else _STORE
    if target is not None:
        target.put(
            bucket, slots, winner,
            stats={"best_s": round(dt, 6), "candidates": len(rows)},
        )
    return winner, rows


def _synthesize(bucket, slots: int, *, chunk: int, seed: int = 0) -> list:
    """Best-effort representative members for ``bucket`` (R-MAT sweep)."""
    import numpy as np

    from ..api.cache import bucket_for
    from ..graphs import rmat

    n_pad = int(bucket[0])
    scale = max(2, int(np.log2(max(n_pad, 4))))
    graphs = []
    for s in range(seed, seed + 64):
        for edge_factor in (8, 6, 4, 3, 2):
            g = rmat(scale, edge_factor, seed=s)
            if tuple(bucket_for(g, chunk=chunk)) == tuple(bucket):
                graphs.append(g)
                break
        if len(graphs) >= min(int(slots), 2):
            return graphs
    if graphs:
        return graphs
    raise ValueError(f"could not synthesize members for bucket {tuple(bucket)}")
