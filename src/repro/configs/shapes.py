"""Assigned input-shape registry + ShapeDtypeStruct builders for the dry-run.

Four shapes per LM architecture (assignment spec):

  train_4k    : seq 4096,  global_batch 256  -> train_step
  prefill_32k : seq 32768, global_batch 32   -> prefill_step
  decode_32k  : seq 32768, global_batch 128  -> decode_step (cache = seq)
  long_500k   : seq 524288, global_batch 1   -> decode_step, sub-quadratic
                archs only (ssm / hybrid); full-attention archs skip it.

Family conventions (DESIGN.md §8):
  * enc-dec ([audio]): ``seq_len`` is the decoder stream; the encoder sees
    ``cfg.frontend_len`` precomputed frame embeddings (stub frontend).
    train_4k splits seq into src/tgt halves so total token work ≈ seq.
  * vlm: ``cfg.frontend_len`` patch embeddings prefix the token stream.

``input_specs`` returns ShapeDtypeStructs only — nothing is allocated; the
same builders feed ``.lower()`` in the dry-run and the smoke tests (with
concrete arrays via ``materialize``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.registry import Model

__all__ = ["ShapeSpec", "SHAPES", "input_specs", "materialize", "cell_is_valid"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_is_valid(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(valid?, reason) — encodes the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, "long_500k needs sub-quadratic attention (full-attn arch)"
    return True, ""


def _tok(b: int, s: int):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(
    cfg: ModelConfig, shape: ShapeSpec, *, scale_batch: float = 1.0
) -> dict:
    """ShapeDtypeStruct stand-ins for the entry point of (cfg, shape)."""
    b = max(1, int(shape.global_batch * scale_batch))
    s = shape.seq_len
    d = cfg.d_model
    emb_dt = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        if cfg.is_encdec:
            se = s // 2
            st = s - se
            return {
                "src_embeds": jax.ShapeDtypeStruct((b, se, d), emb_dt),
                "tokens": _tok(b, st),
                "labels": _tok(b, st),
            }
        if cfg.family == "vlm":
            f = cfg.frontend_len
            return {
                "embeds": jax.ShapeDtypeStruct((b, f, d), emb_dt),
                "tokens": _tok(b, s - f),
                "labels": _tok(b, s - f),
            }
        return {"tokens": _tok(b, s), "labels": _tok(b, s)}

    if shape.kind == "prefill":
        if cfg.is_encdec:
            return {
                "src_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.frontend_len, d), emb_dt
                ),
                "tokens": _tok(b, s),
            }
        if cfg.family == "vlm":
            f = cfg.frontend_len
            return {
                "embeds": jax.ShapeDtypeStruct((b, f, d), emb_dt),
                "tokens": _tok(b, s - f),
            }
        return {"tokens": _tok(b, s)}

    if shape.kind == "decode":
        model = Model(cfg)
        states = jax.eval_shape(lambda: model.init_states(b, s))
        return {
            "token": _tok(b, 1),
            "states": states,
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)


def materialize(specs, seed: int = 0):
    """Concrete arrays for smoke tests (tokens uniform, embeds gaussian)."""
    rng = np.random.default_rng(seed)

    def mk(s):
        if s.dtype == jnp.int32 and s.ndim <= 2:
            if s.shape == ():
                return jnp.zeros((), jnp.int32)
            return jnp.asarray(rng.integers(0, 97, size=s.shape), jnp.int32)
        return jnp.asarray(rng.normal(0, 0.5, size=s.shape), s.dtype)

    return jax.tree.map(mk, specs)
