"""Named input-shape registry (generic ShapeDtypeStruct builders).

Only the architecture-independent pieces of the seed's dry-run shape
grid survive here: :class:`ShapeSpec` names a (sequence, batch, kind)
cell and :func:`materialize` turns ShapeDtypeStructs into concrete smoke
arrays.  The LM-specific spec builders left with the model stack.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ShapeSpec", "SHAPES", "materialize"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def materialize(specs, seed: int = 0):
    """Concrete arrays for smoke tests (tokens uniform, embeds gaussian)."""
    rng = np.random.default_rng(seed)

    def mk(s):
        if s.dtype == jnp.int32 and s.ndim <= 2:
            if s.shape == ():
                return jnp.zeros((), jnp.int32)
            return jnp.asarray(rng.integers(0, 97, size=s.shape), jnp.int32)
        return jnp.asarray(rng.normal(0, 0.5, size=s.shape), s.dtype)

    return jax.tree.map(mk, specs)
