"""llama4-maverick-400b-a17b — MoE with early fusion, top-1 routing.

[hf:meta-llama/Llama-4-Maverick-17B-128E; unverified]  48L d_model=5120
40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1 (+1 shared),
MoE on every other layer (interleave step 2).  head_dim=128; rope 5e5.
Top-1 routing is the Switch-style worst case for coarse dispatch imbalance
— a primary subject for the paper's fine-grained decomposition.
"""

from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        dispatch="fine",
        first_dense=0,
        period=2,
    ),
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    attn_chunk=64,
    moe=MoEConfig(
        num_experts=4,
        top_k=1,
        d_ff_expert=64,
        num_shared_experts=1,
        dispatch="fine",
        period=2,
    ),
)
