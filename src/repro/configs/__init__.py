"""Workload configs: the paper's K-truss benchmark instances + shape specs.

The LLM architecture registry that used to live here was dead seed code,
removed when ``repro.api`` became the single front door; what remains is
the paper-calibrated graph suite (:mod:`.ktruss`) and the generic shape
registry (:mod:`.shapes`).
"""

from __future__ import annotations

from .ktruss import BENCH_GRAPHS, K_SETTINGS, LARGE_GRAPHS, KTrussBench
from .shapes import SHAPES, ShapeSpec

__all__ = [
    "BENCH_GRAPHS",
    "K_SETTINGS",
    "LARGE_GRAPHS",
    "KTrussBench",
    "SHAPES",
    "ShapeSpec",
]
