"""Config registry: the 10 assigned architectures + shapes + paper workload.

``get_config(name, smoke=False)`` resolves arch ids (dashes ok) to
:class:`~repro.models.config.ModelConfig`.
"""

from __future__ import annotations

from ..models.config import ModelConfig
from . import (
    gemma2_9b,
    internvl2_1b,
    kimi_k2_1t_a32b,
    llama3_2_1b,
    llama4_maverick_400b_a17b,
    qwen2_0_5b,
    recurrentgemma_9b,
    rwkv6_7b,
    seamless_m4t_medium,
    smollm_360m,
)
from .shapes import SHAPES, ShapeSpec, cell_is_valid, input_specs, materialize

_MODULES = {
    "seamless-m4t-medium": seamless_m4t_medium,
    "gemma2-9b": gemma2_9b,
    "qwen2-0.5b": qwen2_0_5b,
    "smollm-360m": smollm_360m,
    "llama3.2-1b": llama3_2_1b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "internvl2-1b": internvl2_1b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "rwkv6-7b": rwkv6_7b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    key = name.replace("_", "-")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = _MODULES[key]
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = [
    "ARCH_NAMES",
    "get_config",
    "SHAPES",
    "ShapeSpec",
    "cell_is_valid",
    "input_specs",
    "materialize",
]
