"""The paper's own workload configs: K-truss problem instances.

Mirrors the experimental grid of the paper (50 SNAP graphs × {coarse,fine}
× K ∈ {3, K_max}) at laptop scale with calibrated synthetic families
(DESIGN.md §3).  ``BENCH_GRAPHS`` is sized so that the coarse-grained
baseline — whose padded cost is O(n·W²) — still completes on one CPU core;
``LARGE_GRAPHS`` extends the fine-only scaling study.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from ..graphs import CSRGraph, barabasi, clustered, erdos, rmat, road

__all__ = ["KTrussBench", "BENCH_GRAPHS", "LARGE_GRAPHS", "K_SETTINGS"]


@dataclasses.dataclass(frozen=True)
class KTrussBench:
    name: str
    factory: Callable[[], CSRGraph]
    regime: str  # which paper-graph family this calibrates to

    def build(self) -> CSRGraph:
        g = self.factory()
        return CSRGraph(g.n, g.rowptr, g.colidx, name=self.name)


# Ordered by edge count, like the paper's plots.
BENCH_GRAPHS: tuple[KTrussBench, ...] = (
    KTrussBench("er-4k", lambda: erdos(4_000, 8.0, seed=11), "p2p-Gnutella"),
    KTrussBench("road-64", lambda: road(64, 0.08, seed=12), "roadNet"),
    KTrussBench("clustered-32x40", lambda: clustered(32, 40, 0.5, seed=13), "ca-/email-"),
    KTrussBench("ba-6k", lambda: barabasi(6_000, 4, seed=14), "oregon/as"),
    KTrussBench("rmat-12", lambda: rmat(12, 6, seed=15), "soc-/cit-"),
    KTrussBench("road-128", lambda: road(128, 0.06, seed=16), "roadNet"),
    KTrussBench("er-12k", lambda: erdos(12_000, 8.0, seed=17), "p2p-Gnutella"),
    KTrussBench("ba-12k", lambda: barabasi(12_000, 5, seed=18), "oregon/as"),
)

# Fine-grained-only scaling set (coarse padded cost would be prohibitive —
# which is itself the paper's point; reported as such).
LARGE_GRAPHS: tuple[KTrussBench, ...] = (
    KTrussBench("rmat-15", lambda: rmat(15, 8, seed=21), "soc-Slashdot"),
    KTrussBench("ba-50k", lambda: barabasi(50_000, 6, seed=22), "loc-brightkite"),
    KTrussBench("road-512", lambda: road(512, 0.05, seed=23), "roadNet"),
)

K_SETTINGS = ("k3", "kmax")
