"""seamless-m4t-medium — enc-dec multimodal (speech-to-text) backbone.

[arXiv:2308.11596; hf]  12L d_model=1024 16H (GQA kv=16 = MHA) d_ff=4096
vocab=256206.  Assignment: the transformer backbone only; the speech
frontend is a stub (``input_specs`` supplies precomputed frame embeddings).
We instantiate 12 encoder + 12 decoder layers (M4T's text decoder depth);
the encoder consumes ``frontend_len`` = 1024 stub frames on serve shapes.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    encoder_layers=12,
    encoder_pattern=("attn",),
    layer_pattern=("attn",),
    frontend="audio",
    frontend_len=1024,
    act="gelu",
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    frontend_len=8,
    attn_chunk=64,
)
