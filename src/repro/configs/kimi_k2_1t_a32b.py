"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table entry).

[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared expert, DeepSeek-style).
head_dim=112 (= d_model/H).  All layers MoE per the assignment line (the
HF K2 uses one leading dense layer; the assignment config takes precedence
— recorded in DESIGN.md).  This is the flagship target for the paper's
fine-grained dispatch: 384 experts × top-8 routing is maximal irregular
parallelism.
"""

from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163_840,
    rope_theta=50_000.0,
    # 1T params: bf16 master weights + int8 Adam moments are what make the
    # 512-chip v5e fit close (DESIGN.md §7; EXPERIMENTS.md §Dry-run).
    param_dtype="bfloat16",
    moe=MoEConfig(
        num_experts=384,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        dispatch="fine",
        first_dense=0,
        period=1,
    ),
)

SMOKE = CONFIG.replace(
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=503,
    attn_chunk=64,
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_ff_expert=64,
        num_shared_experts=1,
        dispatch="fine",
    ),
)
