"""internvl2-1b — VLM: InternViT frontend (stub) + qwen2-0.5b LM backbone.

[arXiv:2404.16821; hf]  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  The ViT supplies 256 patch embeddings per image as a stub
(``input_specs`` provides them precomputed, per the assignment spec); the
backbone matches qwen2-0.5b with the InternVL vocab.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_len=256,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    frontend_len=8,
    attn_chunk=64,
)
