"""rwkv6-7b — "Finch": attention-free, data-dependent decay linear RNN.

[arXiv:2404.05892; hf]  32L d_model=4096 (attn-free) d_ff=14336
vocab=65536.  64 heads of dim 64 in the WKV state; O(1) decode state →
runs long_500k.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65_536,
    layer_pattern=("rwkv",),
    rwkv_head_dim=64,
)

SMOKE = CONFIG.replace(
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    rwkv_head_dim=16,
    attn_chunk=64,
)
