"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention (2:1).

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000.  head_dim=256; pattern (rglru, rglru, attn) with the
38 = 12×3 + 2 leftover handled by the stack's suffix path; local attention
window 2048 → sub-quadratic, runs long_500k.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    layer_pattern=("rglru", "rglru", "local"),
    sliding_window=2048,
    rglru_width=4096,
    conv1d_width=4,
    tie_embeddings=True,
    embed_scale=True,
    act="gelu",
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=5,  # 1 group of 3 + 2 leftover: exercises the suffix path
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    sliding_window=32,
    rglru_width=64,
    attn_chunk=64,
)
