"""smollm-360m — llama-arch small dense model.

[hf:HuggingFaceTB/SmolLM-360M; hf]  32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152.  head_dim=64; tied embeddings.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49_152,
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=60,
    num_heads=3,
    num_kv_heads=1,
    head_dim=20,
    d_ff=128,
    vocab_size=503,
    attn_chunk=64,
)
