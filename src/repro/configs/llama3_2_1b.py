"""llama3.2-1b — small llama3 dense model.

[hf:meta-llama/Llama-3.2-1B; unverified]  16L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=128256.  head_dim=64; rope theta 5e5; tied embeddings.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128_256,
    tie_embeddings=True,
    rope_theta=500_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    attn_chunk=64,
)
