"""gemma2-9b — dense, local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf]  42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000.  head_dim=256 (explicit, not d_model/H); sliding window 4096
on local layers; attn softcap 50, final softcap 30; GeGLU; sandwich norms;
tied embeddings scaled by sqrt(d_model).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    layer_pattern=("local", "global"),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    tie_embeddings=True,
    embed_scale=True,
    sandwich_norm=True,
    act="gelu",
    rope_theta=10_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    sliding_window=32,
    attn_chunk=64,
)
