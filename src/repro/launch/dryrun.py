import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # XLA:CPU upcasts bf16 dot operands to f32 and then LICM hoists the
    # converted *weight stacks* out of scan loops — an emulation artifact
    # that inflates the per-device memory report by 2× param bytes (kimi
    # decode: 30 GB -> 9.2 GB temp with the pass off).  TPU executes bf16
    # dots natively, so the hoisted f32 copies do not exist on the target;
    # disabling the pass makes the fit report faithful to v5e.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

THE two lines above must execute before any other import (jax locks the
device count on first init) — do not move them.

For each cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. constructs the cell's entry point (train_step / prefill_step /
     decode_step) with the execution plan (grad-accum, moment dtype,
     remat) chosen for that (arch, shape),
  3. ``jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)``
     with ShapeDtypeStruct inputs — **no arrays are allocated**,
  4. ``.compile()`` — sharding mismatches, unpartitionable ops, or compile
     OOM fail here and are bugs in the system,
  5. records ``memory_analysis()`` (proves the per-device fit) and
     ``cost_analysis()`` + the collective ops parsed from the compiled HLO
     (feeds benchmarks/roofline.py and EXPERIMENTS.md §Dry-run/§Roofline).

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out dryrun.json
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from ..configs import ARCH_NAMES, SHAPES, cell_is_valid, get_config, input_specs  # noqa: E402
from ..distributed import batch_specs, named, param_specs, state_specs  # noqa: E402
from ..distributed.context import sharding_context  # noqa: E402
from ..models import Model  # noqa: E402
from ..train import AdamWConfig, TrainStepConfig, make_train_step  # noqa: E402
from ..train.optimizer import adamw_init  # noqa: E402
from .hlo_stats import analyze_hlo  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


# ---------------------------------------------------------------------- #
# Execution plans: how each (arch, shape) cell is configured to fit.
# ---------------------------------------------------------------------- #
def exec_plan(cfg, shape, mesh) -> dict:
    """Per-cell knobs (microbatching, moment precision) chosen by napkin
    math over HBM (16 GB/chip v5e); recorded in EXPERIMENTS.md §Dry-run."""
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names]))
    plan = {"moment_dtype": "float32", "accum_dtype": "float32", "grad_accum": 1}
    if shape.kind != "train":
        return plan
    per_dev_seqs = max(1, shape.global_batch // dp)
    # Microbatch sized from the activation budget: the remat boundary
    # stack is L × b_micro × S × D × 2B ≤ ~4 GB.  Bigger microbatches cut
    # grad-accum trips — each trip re-all-gathers the FSDP weight shards
    # (gemma2 at accum=16 measured 567 GB/device of weight gathers; §Perf).
    layers = cfg.num_layers + cfg.encoder_layers
    stack_per_seq = layers * shape.seq_len * cfg.d_model * 2
    # Cap at 4 seqs: beyond that, attention/frontend transients (which
    # scale with the microbatch) dominate the boundary-stack estimate
    # (seamless/internvl regressed to 33/21 GB uncapped — §Perf iter 13).
    target = max(1, min(4, int(4e9 // max(stack_per_seq, 1))))
    # accum must divide the per-device batch (the microbatch reshape):
    # pick the fewest trips whose microbatch fits the activation budget.
    accum = per_dev_seqs
    for a in range(1, per_dev_seqs + 1):
        if per_dev_seqs % a == 0 and per_dev_seqs // a <= target:
            accum = a
            break
    plan["grad_accum"] = accum
    big = cfg.moe is not None and cfg.moe.num_experts >= 64
    if big:
        plan["moment_dtype"] = "int8"
        plan["accum_dtype"] = "bfloat16"
    return plan


def _collectives(hlo_text: str) -> dict:
    """Sum per-device bytes by collective kind from compiled HLO."""
    dtype_bytes = {
        "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
        "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    }
    out: dict[str, dict] = {}
    pat = re.compile(
        r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\("
    )
    shape_pat = re.compile(r"(\w+?)\[([\d,]*)\]")
    for m in pat.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for sm in shape_pat.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in dtype_bytes:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * dtype_bytes[dt]
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def _spec_to_jsonable(x):
    return float(x) if isinstance(x, (int, float, np.floating)) else x


def top_shapes(hlo_text: str, k: int = 12) -> list[tuple[float, str, str]]:
    """Largest result tensors in the compiled module (fit debugging)."""
    dtype_bytes = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "s8": 1, "pred": 1,
                   "f64": 8, "s64": 8, "f16": 2, "u8": 1}
    out = []
    pat = re.compile(r"%([\w\.\-]+) = (\w+)\[([\d,]+)\][^ ]* (\w[\w\-]*)\(")
    for m in pat.finditer(hlo_text):
        name, dt, dims, op = m.groups()
        if dt not in dtype_bytes:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        out.append((n * dtype_bytes[dt], f"{dt}[{dims}]", op))
    out.sort(reverse=True)
    seen, uniq = set(), []
    for b, shape, op in out:
        if (shape, op) in seen:
            continue
        seen.add((shape, op))
        uniq.append((b, shape, op))
        if len(uniq) >= k:
            break
    return uniq


def build_and_lower(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_valid(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    plan = exec_plan(cfg, shape, mesh)
    t0 = time.time()

    with sharding_context(mesh):
        specs = input_specs(cfg, shape)
        if shape.kind == "train":
            tcfg = TrainStepConfig(
                optimizer=AdamWConfig(moment_dtype=plan["moment_dtype"]),
                grad_accum=plan["grad_accum"],
                accum_dtype=plan["accum_dtype"],
            )
            step = make_train_step(model, tcfg)
            key = jax.random.PRNGKey(0)
            state_shapes = jax.eval_shape(
                lambda: {
                    "params": model.init(key),
                    "opt": adamw_init(
                        jax.eval_shape(model.init, key), tcfg.optimizer
                    ),
                    "step": jax.ShapeDtypeStruct((), jax.numpy.int32),
                }
            )
            state_sh = named(mesh, param_specs(state_shapes, mesh))
            batch_sh = named(mesh, batch_specs(specs, mesh))
            metric_sh = jax.tree.map(
                lambda _: NamedSharding(mesh, P()),
                jax.eval_shape(step, state_shapes, specs)[1],
            )
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, metric_sh),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, specs)
        elif shape.kind == "prefill":
            key = jax.random.PRNGKey(0)
            pshapes = jax.eval_shape(model.init, key)
            psh = named(mesh, param_specs(pshapes, mesh))
            batch_sh = named(mesh, batch_specs(specs, mesh))

            def prefill_step(params, batch):
                return model.prefill(params, batch, max_len=shape.seq_len)

            out_state = jax.eval_shape(prefill_step, pshapes, specs)[1]
            out_sh = (
                NamedSharding(mesh, P()),
                named(mesh, state_specs(out_state, mesh)),
            )
            jitted = jax.jit(
                prefill_step, in_shardings=(psh, batch_sh), out_shardings=out_sh
            )
            lowered = jitted.lower(pshapes, specs)
        else:  # decode
            key = jax.random.PRNGKey(0)
            pshapes = jax.eval_shape(model.init, key)
            psh = named(mesh, param_specs(pshapes, mesh))
            st_sh = named(mesh, state_specs(specs["states"], mesh))
            tok_sh = named(mesh, batch_specs({"t": specs["token"]}, mesh))["t"]

            def decode_step(params, token, states, pos):
                return model.decode(params, token, states, pos)

            out_sh = (NamedSharding(mesh, P()), st_sh)
            jitted = jax.jit(
                decode_step,
                in_shardings=(psh, tok_sh, st_sh, NamedSharding(mesh, P())),
                out_shardings=out_sh,
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                pshapes, specs["token"], specs["states"], specs["pos"]
            )

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = _collectives(hlo)  # raw (once-per-body) counts, for reference
    stats = analyze_hlo(hlo)  # trip-count-scaled totals (roofline inputs)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "plan": plan,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "cost": {
            "flops_once": _spec_to_jsonable(cost.get("flops", 0.0)),
            "bytes_accessed_once": _spec_to_jsonable(cost.get("bytes accessed", 0.0)),
            "transcendentals_once": _spec_to_jsonable(
                cost.get("transcendentals", 0.0)
            ),
        },
        # Trip-count-scaled per-device totals (launch/hlo_stats.py):
        "hlo_flops": stats["flops"],
        "hlo_traffic_bytes": stats["traffic"],
        "collectives_scaled": stats["collectives"],
        "collectives_raw": coll,
    }
    if _PRINT_BIGBUF:
        result["top_tensors"] = [
            {"gb": round(b / 1e9, 3), "shape": s, "op": o}
            for b, s, o in top_shapes(hlo)
        ]
    return result


_PRINT_BIGBUF = False


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument(
        "--multi-pod", default="both", choices=["single", "multi", "both"]
    )
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument(
        "--bigbuf", action="store_true", help="also print the largest tensors"
    )
    args = ap.parse_args()
    global _PRINT_BIGBUF
    _PRINT_BIGBUF = args.bigbuf

    archs = list(ARCH_NAMES) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                try:
                    res = build_and_lower(arch, shape_name, mp)
                except Exception as e:  # noqa: BLE001 — report and continue
                    res = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    n_fail += 1
                line = json.dumps(res)
                print(line, flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
