"""Production mesh construction (assignment-prescribed topology).

Defined as functions (never module-level constants) so importing this
module does not touch JAX device state — the dry-run must set XLA_FLAGS
*before* any jax initialization.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_device_count"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_device_count(*, multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
