"""Trip-count-aware HLO accounting for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — a 10-trip scan reports the same flops as a single call), so a
scan-over-layers train step under-reports flops by ~L×grad_accum.  This
module parses ``compiled.as_text()`` instead:

  * dot flops        = 2 × |output| × |contracting dims|, resolved from the
    per-computation symbol table (operand result types),
  * while loops      scale their body by ``backend_config known_trip_count``
    (XLA records it for counted loops; unknown → 1 and flagged),
  * collective bytes = operand/result bytes × ring factor, × enclosing trip
    counts (a collective inside the layer scan costs L× its single shot),
  * HBM traffic      ≈ Σ (output + resolvable operand bytes) per op × trips
    — an upper estimate (ignores on-chip reuse); used for the memory term.

Elementwise flops are excluded (≤ few % of LM step flops, dominated by
dots); transcendentals likewise.  Methodology recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

import re
from typing import Iterable

__all__ = ["analyze_hlo", "HLO_COLLECTIVES"]

HLO_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OP_LINE = re.compile(
    r"^\s+(?:ROOT )?%([\w\.\-]+) = ((?:\([^)]*\))|(?:[\w\[\],\{\}]+))\s+([\w\-]+)\("
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_CALLED = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: list[str] | None = None
    for line in text.splitlines():
        if line and not line.startswith(" ") and "->" in line and "{" in line:
            m = _COMP_HDR.match(line)
            if m:
                cur = comps.setdefault(m.group(1), [])
                continue
        if line.startswith("}"):
            cur = None
        elif cur is not None:
            cur.append(line)
    return comps


def analyze_hlo(text: str) -> dict:
    """Returns totals: flops, collective bytes per kind, traffic bytes."""
    comps = _split_computations(text)

    # Symbol tables: per computation, op name -> result type string.
    symbols: dict[str, dict[str, str]] = {}
    for cname, lines in comps.items():
        tab: dict[str, str] = {}
        for line in lines:
            m = _OP_LINE.match(line)
            if m:
                tab[m.group(1)] = m.group(2)
        symbols[cname] = tab

    skip_ops = {
        "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
        "after-all", "iota",
    }

    memo: dict[tuple[str, bool], dict] = {}

    def comp_cost(cname: str, in_fusion: bool = False) -> dict:
        key = (cname, in_fusion)
        if key in memo:
            return memo[key]
        # Mark in-progress to break cycles defensively.
        memo[key] = {"flops": 0.0, "traffic": 0.0, "coll": {}}
        tab = symbols.get(cname, {})
        flops = 0.0
        traffic = 0.0
        coll: dict[str, dict] = {}

        for line in comps.get(cname, []):
            m = _OP_LINE.match(line)
            if not m:
                continue
            name, rtype, op = m.group(1), m.group(2), m.group(3)
            if op in skip_ops:
                continue
            out_bytes = _shape_bytes(rtype)

            if op == "dot":
                out_dims = _shape_dims(rtype)
                # contraction size from the lhs operand's shape
                ops_m = _OPERANDS.findall(line.split("dot(", 1)[1])
                lhs_shape: list[int] = []
                if ops_m:
                    lhs_type = tab.get(ops_m[0], "")
                    lhs_shape = _shape_dims(lhs_type)
                cm = _CONTRACT.search(line)
                csize = 1
                if cm and lhs_shape:
                    for d in cm.group(1).split(","):
                        if d and int(d) < len(lhs_shape):
                            csize *= lhs_shape[int(d)]
                out_n = 1
                for d in out_dims:
                    out_n *= d
                flops += 2.0 * out_n * csize
                if not in_fusion:
                    traffic += out_bytes
                    for oname in ops_m[:2]:
                        traffic += _shape_bytes(tab.get(oname, ""))
            elif op == "while":
                tm = _TRIP.search(line)
                trips = int(tm.group(1)) if tm else 1
                bm = _CALLED.search(line)
                if bm:
                    sub = comp_cost(bm.group(1), in_fusion)
                    flops += trips * sub["flops"]
                    traffic += trips * sub["traffic"]
                    for k, v in sub["coll"].items():
                        rec = coll.setdefault(k, {"count": 0, "bytes": 0.0})
                        rec["count"] += trips * v["count"]
                        rec["bytes"] += trips * v["bytes"]
                cm2 = _COND.search(line)
                if cm2:
                    sub = comp_cost(cm2.group(1), in_fusion)
                    flops += trips * sub["flops"]
            elif (op[:-6] if op.endswith("-start") else op) in HLO_COLLECTIVES:
                kind = op[:-6] if op.endswith("-start") else op
                rec = coll.setdefault(kind, {"count": 0, "bytes": 0.0})
                rec["count"] += 1
                rec["bytes"] += out_bytes
                traffic += out_bytes
            elif op in ("fusion", "call", "conditional", "custom-call", "map",
                        "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
                # Ops inside a fused computation read/write VMEM/registers,
                # not HBM — only the fusion's boundary (its output and the
                # already-counted producer outputs it consumes) is traffic.
                sub_fused = op != "call"
                for sub_name in _CALLED.findall(line):
                    sub = comp_cost(sub_name, in_fusion or sub_fused)
                    flops += sub["flops"]
                    traffic += sub["traffic"]
                    for k, v in sub["coll"].items():
                        rec = coll.setdefault(k, {"count": 0, "bytes": 0.0})
                        rec["count"] += v["count"]
                        rec["bytes"] += v["bytes"]
                if not in_fusion:
                    traffic += out_bytes
            else:
                if not in_fusion:
                    traffic += out_bytes

        result = {"flops": flops, "traffic": traffic, "coll": coll}
        memo[cname] = result
        return result

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        return {"flops": 0.0, "traffic": 0.0, "collectives": {}}
    total = comp_cost(entry)
    return {
        "flops": total["flops"],
        "traffic": total["traffic"],
        "collectives": total["coll"],
    }
