"""Training driver: end-to-end loop with checkpointing + fault tolerance.

CPU-runnable at smoke scale (the quickstart path) and mesh-aware at
production scale (same code path the dry-run lowers).

Example (≈100M-param model, a few hundred steps on one CPU):

  PYTHONPATH=src python -m repro.launch.train \
      --arch qwen2-0.5b --smoke --steps 200 --batch 8 --seq 64 \
      --ckpt-dir /tmp/run1 --ckpt-every 50
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..distributed.context import sharding_context
from ..models import Model
from ..train import (
    AdamWConfig,
    Checkpointer,
    StepWatchdog,
    TrainStepConfig,
    batch_for,
    init_train_state,
    make_train_step,
    warmup_cosine,
)

__all__ = ["run_training", "main"]


def run_training(
    *,
    arch: str,
    smoke: bool,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 100,
    grad_accum: int = 1,
    base_lr: float = 1e-3,
    seed: int = 0,
    mesh=None,
    log_every: int = 10,
    straggler_threshold: float = 5.0,
) -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = Model(cfg)
    tcfg = TrainStepConfig(
        optimizer=AdamWConfig(),
        schedule_fn=lambda s: warmup_cosine(
            s, base_lr=base_lr, warmup_steps=max(10, steps // 20), total_steps=steps
        ),
        grad_accum=grad_accum,
    )

    with sharding_context(mesh):
        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        state = init_train_state(model, jax.random.PRNGKey(seed), tcfg)

        start_step = 0
        ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        if ckpt is not None:
            try:
                state, start_step = ckpt.restore(state)
                print(f"[train] resumed from step {start_step}")
            except FileNotFoundError:
                pass

        watchdog = StepWatchdog(threshold=straggler_threshold)
        losses = []
        t_start = time.perf_counter()
        for step in range(start_step, steps):
            b = batch_for(cfg, batch, seq, step, seed=seed)
            b = jax.tree.map(jax.numpy.asarray, b)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, b)
            jax.block_until_ready(metrics["loss"])
            watchdog.observe(time.perf_counter() - t0)
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                print(
                    f"[train] step {step:5d} loss {losses[-1]:.4f} "
                    f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f}",
                    flush=True,
                )
            if ckpt is not None and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, state)
        if ckpt is not None:
            ckpt.save(steps, state)
            ckpt.wait()
        wall = time.perf_counter() - t_start
    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "first_loss": losses[0] if losses else float("nan"),
        "losses": losses,
        "wall_s": wall,
        "straggler_stats": watchdog.stats.as_dict(),
        "steps": steps,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run_training(
        arch=args.arch,
        smoke=args.smoke,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        grad_accum=args.grad_accum,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        base_lr=args.lr,
        seed=args.seed,
    )
    print(
        f"[train] done: loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
        f"in {out['wall_s']:.1f}s; stragglers: {out['straggler_stats']}"
    )


if __name__ == "__main__":
    main()
