"""Launch: production mesh, multi-pod dry-run, train/serve drivers.

NOTE: ``dryrun`` is intentionally not imported here — it must set XLA_FLAGS
before jax initializes and is only ever run as ``python -m
repro.launch.dryrun``.
"""

from .mesh import make_production_mesh, mesh_device_count

__all__ = ["make_production_mesh", "mesh_device_count"]
