"""Serving driver: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --batch 4 --prompt-len 16 --max-new 32
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config
from ..distributed.context import sharding_context
from ..models import Model
from ..serve import ServeEngine

__all__ = ["run_serving", "main"]


def run_serving(
    *,
    arch: str,
    smoke: bool,
    batch: int,
    prompt_len: int,
    max_new: int,
    temperature: float = 0.0,
    seed: int = 0,
    mesh=None,
) -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = Model(cfg)
    rng = np.random.default_rng(seed)
    with sharding_context(mesh):
        params = model.init(jax.random.PRNGKey(seed))
        prompt = {"tokens": rng.integers(1, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)}
        if cfg.family == "vlm":
            prompt["embeds"] = rng.normal(0, 0.5, (batch, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        if cfg.is_encdec:
            prompt["src_embeds"] = rng.normal(0, 0.5, (batch, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        prefix = cfg.frontend_len if cfg.family == "vlm" else 0
        engine = ServeEngine(
            model, params, max_len=prefix + prompt_len + max_new, seed=seed
        )
        res = engine.generate(
            jax.tree.map(jax.numpy.asarray, prompt),
            max_new_tokens=max_new,
            temperature=temperature,
        )
    return {
        "tokens": res.tokens,
        "prefill_s": res.prefill_s,
        "decode_s": res.decode_s,
        "decode_tok_s": res.decode_tokens_per_s(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out = run_serving(
        arch=args.arch,
        smoke=args.smoke,
        batch=args.batch,
        prompt_len=args.prompt_len,
        max_new=args.max_new,
        temperature=args.temperature,
    )
    print(f"[serve] generated {out['tokens'].shape} tokens")
    print(
        f"[serve] prefill {out['prefill_s']*1e3:.1f} ms, "
        f"decode {out['decode_tok_s']:.1f} tok/s"
    )
    print(out["tokens"][:2])


if __name__ == "__main__":
    main()
