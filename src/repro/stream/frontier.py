"""Affected-edge frontier: which trussness values can an update change?

The fine-grained formulation makes each support contribution a per-triangle
quantity, and trussness has a per-triangle fixed-point characterization:

    t(f) = max k such that #{triangles {f,e,g} : min(t(e), t(g)) >= k} >= k-2

so ``t(f)`` depends only on the multiset of ``min(t(e), t(g))`` over f's
triangles.  An update can change ``t(f)`` only by changing that multiset
*at a level that matters for f* — which yields the classic conservative
propagation bound used by incremental truss maintenance (Huang et al.,
"Querying k-truss communities in large and dynamic graphs"):

* drift bounds: one edge insertion raises any trussness by at most 1 and
  one deletion lowers it by at most 1, so after a batch with ``nI``
  inserts / ``nD`` deletes every surviving edge satisfies
  ``lo(e) = max(2, t_old(e) - nD) <= t_new(e) <= t_old(e) + nI = hi(e)``
  (inserted edges: ``lo = 2``, ``hi = 2 + #triangles``);
* seed rule: f is affected directly if it gains or loses a triangle whose
  other two edges satisfy ``min(hi(e), hi(g)) >= lo(f)`` — a triangle
  whose min-trussness ceiling is below f's trussness floor cannot move
  f's count at any level f could occupy;
* propagation rule: an affected edge e spreads to a triangle partner f
  (through any surviving triangle {f, e, g}) under the same
  ``min(hi(e), hi(g)) >= lo(f)`` level test, iterated to closure.

Every edge outside the closure provably keeps its trussness, so the
streaming session may freeze it (``repro.exec.build_peel``'s frozen lanes)
and re-peel only the frontier — the bit-identical-to-from-scratch
guarantee the tests pin.

Triangle enumeration reuses the fine-grained suffix-window idiom of
``support_fine_eager`` (one task per nonzero, row-i suffix intersected
with row kappa via searchsorted on the sorted composite keys), vectorized
in numpy and chunked to bound the (chunk x window) working set.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Mapping

import numpy as np

from ..graphs.csr import CSRGraph
from ..obs import current_registry, get_registry
from .delta import GraphDelta, edge_keys

__all__ = [
    "ENUM_COUNTS",
    "FrontierResult",
    "edge_triangles",
    "compute_frontier",
    "union_graph",
]


class _EnumCounts(Mapping):
    """Deprecated process-global alias over the metrics registry.

    .. deprecated::
        Triangle-enumeration counts are per-session metrics now — the
        ``stream_enumerations{kind=full|incident}`` counter in the
        owning session's :class:`repro.obs.MetricsRegistry` (read via
        ``session.obs.metrics.value("stream_enumerations", kind=...)``
        or any metrics snapshot).  This mapping mirrors the
        process-global registry, which aggregates every session, so
        legacy whole-process reads (``ENUM_COUNTS["full"]``) keep
        working; it is no longer the store, just a view.

    "full" counts whole-graph triangle enumerations (the per-update cost
    this module had before the session's TriangleCache), "incident"
    counts the cheap insert-wedge enumerations the cache does instead
    (repro.stream.tricache).  stream_bench asserts the cached path stays
    at one "full" per session.
    """

    _KINDS = ("full", "incident")

    def __getitem__(self, kind: str) -> int:
        if kind not in self._KINDS:
            raise KeyError(kind)
        return int(get_registry().value("stream_enumerations", kind=kind))

    def __setitem__(self, kind: str, value: int) -> None:
        # Legacy read-modify-write (`ENUM_COUNTS["full"] += 1`) support:
        # adjust the global counter by the implied delta.
        get_registry().inc(
            "stream_enumerations", float(value) - self[kind], kind=kind
        )

    def __iter__(self):
        return iter(self._KINDS)

    def __len__(self) -> int:
        return len(self._KINDS)

    def __repr__(self) -> str:
        return repr(dict(self))


ENUM_COUNTS = _EnumCounts()


@dataclasses.dataclass(frozen=True)
class FrontierResult:
    """The affected-edge closure of one update batch.

    ``frontier`` is a mask over the **new** graph's edge ids; everything
    outside it keeps its old trussness.  ``lo``/``hi`` are the per-new-edge
    trussness drift bounds the closure used; ``rounds`` is how many
    propagation sweeps reached the fixed point; ``num_triangles`` counts
    the union graph's triangles (the closure's work set).
    """

    frontier: np.ndarray  # (new_nnz,) bool
    lo: np.ndarray  # (new_nnz,) int32
    hi: np.ndarray  # (new_nnz,) int32
    rounds: int
    num_triangles: int

    @property
    def size(self) -> int:
        return int(self.frontier.sum())

    @property
    def frac(self) -> float:
        n = int(self.frontier.shape[0])
        return self.size / n if n else 0.0


def edge_triangles(g: CSRGraph, *, chunk: int = 8192) -> np.ndarray:
    """All triangles of an upper-triangular CSR as (T, 3) edge-id triples.

    Triangle (i < j < k) is reported as the edge ids of
    ``[(i,j), (i,k), (j,k)]``.  Same dataflow as the fine-grained support
    task: edge (i,j)'s row-i suffix supplies the k candidates, and a
    searchsorted over the global sorted edge keys resolves (j,k) — but in
    numpy, since the frontier machinery is host-side control logic, not a
    device kernel.  Chunked so the (chunk, max_degree) window stays small.
    """
    current_registry().inc("stream_enumerations", kind="full")
    nnz = g.nnz
    if nnz == 0:
        return np.zeros((0, 3), np.int64)
    keys = edge_keys(g)
    rows = g.row_of_edge().astype(np.int64)
    deg = g.degrees()
    rowptr = g.rowptr
    stride = np.int64(g.n + 1)
    w = int(np.max(deg)) if deg.size else 0
    if w <= 1:
        return np.zeros((0, 3), np.int64)
    offs = np.arange(1, w, dtype=np.int64)[None, :]
    out: list[np.ndarray] = []
    for start in range(0, nnz, chunk):
        t = np.arange(start, min(start + chunk, nnz), dtype=np.int64)[:, None]
        i = rows[t[:, 0]]
        j = g.colidx[t[:, 0]].astype(np.int64)
        # Row-i suffix after position of (i, j): candidate third vertices k.
        # Row v (1-based) spans [rowptr[v-1], rowptr[v]), so rowptr[i] is
        # exactly row i's end.
        q = t + offs  # global candidate edge ids (i, k)
        in_row = q < rowptr[i][:, None]
        q_c = np.minimum(q, nnz - 1)
        k = g.colidx[q_c].astype(np.int64)
        # Does (j, k) exist?  One searchsorted on the sorted keys.
        jk = j[:, None] * stride + k
        pos = np.searchsorted(keys, jk)
        pos_c = np.minimum(pos, nnz - 1)
        hit = in_row & (keys[pos_c] == jk)
        if hit.any():
            ti, tj = np.nonzero(hit)
            out.append(
                np.stack(
                    [t[ti, 0], q_c[ti, tj], pos_c[ti, tj]], axis=1
                )
            )
    return np.concatenate(out, axis=0) if out else np.zeros((0, 3), np.int64)


def union_graph(delta: GraphDelta) -> tuple[CSRGraph, np.ndarray]:
    """G_old ∪ inserts, with its sorted edge keys.

    The union holds every triangle of either snapshot: gained triangles
    (contain an insert, no delete), lost triangles (a delete, no insert)
    and persistent ones are all subsets of it.
    """
    n = delta.old_graph.n
    ukeys = np.union1d(edge_keys(delta.old_graph), edge_keys(delta.new_graph))
    u = (ukeys // (n + 1)).astype(np.int64)
    v = (ukeys % (n + 1)).astype(np.int32)
    rowptr = np.zeros(n + 1, dtype=np.int64)
    rowptr[1:] = np.cumsum(np.bincount(u, minlength=n + 1)[1:])
    return CSRGraph(n, rowptr, v, name=delta.old_graph.name + "+union"), ukeys


def compute_frontier(
    trussness_old: np.ndarray,
    delta: GraphDelta,
    *,
    chunk: int = 8192,
    tri_keys: np.ndarray | None = None,
    union: tuple[CSRGraph, np.ndarray] | None = None,
) -> FrontierResult:
    """Conservative affected-edge closure of ``delta`` (see module doc).

    Args:
      trussness_old: (old_nnz,) trussness of every old edge (>= 2), e.g.
        from ``KTrussEngine.decompose()`` or the previous session state.
      delta: the applied batch (:func:`repro.stream.delta.apply_batch`).
      tri_keys: optional precomputed union-graph triangle list as (T, 3)
        edge-key triples (``repro.stream.tricache.TriangleCache``); when
        given, the per-update full triangle enumeration is skipped.
      union: optional prebuilt ``union_graph(delta)`` result, so callers
        that already needed it (the triangle cache) don't rebuild it.

    Returns a :class:`FrontierResult` over the **new** graph's edges.
    Inserted edges are always in the frontier; an empty batch (or one
    touching no triangles at a relevant level) yields an empty frontier.
    """
    g_old, g_new = delta.old_graph, delta.new_graph
    trussness_old = np.asarray(trussness_old, np.int64)
    if trussness_old.shape[0] != g_old.nnz:
        raise ValueError(
            f"trussness has {trussness_old.shape[0]} entries, graph has {g_old.nnz}"
        )
    union, ukeys = union if union is not None else union_graph(delta)
    nu = union.nnz
    old_keys, new_keys = edge_keys(g_old), edge_keys(g_new)
    nI, nD = delta.num_inserts, delta.num_deletes

    # Union-edge classification + old-trussness lift.
    is_old = np.isin(ukeys, old_keys, assume_unique=True)
    is_new = np.isin(ukeys, new_keys, assume_unique=True)
    is_ins = is_new & ~is_old
    is_del = is_old & ~is_new
    t_old_u = np.zeros(nu, np.int64)
    if g_old.nnz:
        pos = np.minimum(np.searchsorted(old_keys, ukeys), g_old.nnz - 1)
        t_old_u[is_old] = trussness_old[pos[is_old]]

    if tri_keys is None:
        tri = edge_triangles(union, chunk=chunk)
    elif tri_keys.size:
        # Union triangles by construction, so every key resolves exactly.
        tri = np.searchsorted(ukeys, np.asarray(tri_keys, np.int64))
    else:
        tri = np.zeros((0, 3), np.int64)
    num_tri = int(tri.shape[0])

    # Per-union-edge drift bounds (valid for BOTH snapshots' trussness).
    lo = np.maximum(2, t_old_u - nD)
    hi = t_old_u + nI
    lo[is_ins] = 2
    if num_tri:
        tri_has_del = is_del[tri].any(axis=1)
        tri_has_ins = is_ins[tri].any(axis=1)
        # Inserted edges: trussness <= 2 + (# surviving triangles through them).
        surv_cnt = np.bincount(
            tri[~tri_has_del].ravel(), minlength=nu
        )
        hi[is_ins] = 2 + surv_cnt[is_ins]
    else:
        tri_has_del = tri_has_ins = np.zeros(0, bool)
        hi[is_ins] = 2

    frontier_u = is_ins.copy()
    rounds = 0
    if num_tri:
        hi_t = hi[tri]  # (T, 3)
        lo_t = lo[tri]
        # min over the OTHER two edges' ceilings, per triangle member.
        min_others = np.stack(
            [
                np.minimum(hi_t[:, 1], hi_t[:, 2]),
                np.minimum(hi_t[:, 0], hi_t[:, 2]),
                np.minimum(hi_t[:, 0], hi_t[:, 1]),
            ],
            axis=1,
        )
        relevant = min_others >= lo_t  # the level test, per (triangle, member)

        # Seeds: members of gained/lost triangles that pass the level test.
        changed_tri = tri_has_ins ^ tri_has_del  # in exactly one snapshot
        seed_hit = relevant & changed_tri[:, None] & ~is_del[tri]
        frontier_u[tri[seed_hit]] = True

        # Propagation closure over the NEW graph's triangles only (lost
        # triangles were fully accounted as seeds; deleted edges never
        # appear in a surviving triangle, so they cannot spread).
        surv = ~tri_has_del
        tri_s, rel_s = tri[surv], relevant[surv]
        while True:
            rounds += 1
            in_f = frontier_u[tri_s]  # (Ts, 3)
            others_in = np.stack(
                [
                    in_f[:, 1] | in_f[:, 2],
                    in_f[:, 0] | in_f[:, 2],
                    in_f[:, 0] | in_f[:, 1],
                ],
                axis=1,
            )
            add = others_in & rel_s & ~in_f
            if not add.any():
                break
            frontier_u[tri_s[add]] = True

    # Project union-edge quantities onto the new graph's edge ids.
    sel = is_new
    return FrontierResult(
        frontier=frontier_u[sel],
        lo=lo[sel].astype(np.int32),
        hi=hi[sel].astype(np.int32),
        rounds=rounds,
        num_triangles=num_tri,
    )
