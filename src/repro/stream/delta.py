"""CSR delta application: mutate an upper-triangular CSR graph in place(-ish).

The streaming subsystem treats a graph update as an :class:`EdgeBatch` —
a set of edge insertions plus a set of deletions over a fixed vertex set —
and :func:`apply_batch` produces the mutated :class:`~repro.graphs.csr.CSRGraph`
together with the edge-id correspondences the incremental machinery needs:

* ``old2new`` / ``new2old`` — where each surviving edge moved (CSR edge ids
  are positional, so inserting an edge shifts every id after it);
* ``inserted_new`` / ``deleted_old`` — which lanes are structurally new or
  gone, the seeds of the affected-edge frontier (``repro.stream.frontier``).

Everything is host-side numpy on sorted edge keys (``u * (n + 1) + v``, the
same composite key ``prepare_fine`` uses for its u2d searchsorted), so a
delta costs O((nnz + batch) log) — no device work until the frontier peel.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from ..graphs.csr import CSRGraph

__all__ = ["EdgeBatch", "GraphDelta", "edge_keys", "apply_batch"]


class EdgeBatch(NamedTuple):
    """One batched graph update: edges to insert and edges to delete.

    Endpoints are **0-based** vertex ids in ``[0, n)`` (the same convention
    as :func:`repro.graphs.csr.from_edges`); orientation and duplicates are
    canonicalized by :func:`apply_batch`.  Empty arrays mean "no-op side".
    """

    inserts: np.ndarray  # (mi, 2) int
    deletes: np.ndarray  # (md, 2) int

    @staticmethod
    def of(inserts=(), deletes=()) -> "EdgeBatch":
        def arr(x):
            a = np.asarray(list(x) if not isinstance(x, np.ndarray) else x, np.int64)
            return a.reshape(-1, 2) if a.size else np.zeros((0, 2), np.int64)

        return EdgeBatch(arr(inserts), arr(deletes))


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """The applied batch: mutated graph + edge-id correspondences.

    ``num_inserts``/``num_deletes`` are the *effective* counts after
    canonicalization (dedup, self-loop drop) — the counts the frontier's
    trussness drift bounds use.
    """

    old_graph: CSRGraph
    new_graph: CSRGraph
    old2new: np.ndarray  # (old_nnz,) int64 — new edge id, -1 if deleted
    new2old: np.ndarray  # (new_nnz,) int64 — old edge id, -1 if inserted
    inserted_new: np.ndarray  # (new_nnz,) bool
    deleted_old: np.ndarray  # (old_nnz,) bool
    num_inserts: int
    num_deletes: int


def edge_keys(g: CSRGraph) -> np.ndarray:
    """(nnz,) strictly-increasing composite keys ``u * (n + 1) + v`` (1-based).

    CSR stores rows ascending and columns ascending within a row, so the
    key sequence is already sorted — every correspondence below is one
    ``searchsorted``.
    """
    return g.row_of_edge().astype(np.int64) * (g.n + 1) + g.colidx


def _canonical_keys(n: int, pairs: np.ndarray, what: str) -> np.ndarray:
    """0-based endpoint pairs -> unique sorted 1-based upper-tri keys."""
    if pairs.size == 0:
        return np.zeros(0, np.int64)
    pairs = np.asarray(pairs, np.int64)
    if pairs.min() < 0 or pairs.max() >= n:
        raise ValueError(f"{what} endpoints must lie in [0, {n})")
    u = np.minimum(pairs[:, 0], pairs[:, 1])
    v = np.maximum(pairs[:, 0], pairs[:, 1])
    keep = u != v  # self loops are never edges; drop silently like from_edges
    u, v = u[keep] + 1, v[keep] + 1
    return np.unique(u * (n + 1) + v)


def apply_batch(g: CSRGraph, batch: EdgeBatch, *, strict: bool = True) -> GraphDelta:
    """Apply ``batch`` to ``g`` and return the mutated graph + id maps.

    With ``strict=True`` (default) inserting an existing edge, deleting a
    missing edge, or inserting and deleting the same edge in one batch
    raises ``ValueError`` — a streaming session's source of truth should
    never disagree with its updates.  ``strict=False`` drops the
    conflicting entries instead (at-least-once delivery feeds).
    """
    n = g.n
    old_keys = edge_keys(g)
    ins = _canonical_keys(n, batch.inserts, "insert")
    dele = _canonical_keys(n, batch.deletes, "delete")

    both = np.intersect1d(ins, dele, assume_unique=True)
    if both.size:
        if strict:
            raise ValueError(
                f"{both.size} edge(s) appear in both inserts and deletes"
            )
        ins = np.setdiff1d(ins, both, assume_unique=True)
        dele = np.setdiff1d(dele, both, assume_unique=True)

    ins_exists = np.isin(ins, old_keys, assume_unique=True)
    if ins_exists.any():
        if strict:
            raise ValueError(f"{int(ins_exists.sum())} inserted edge(s) already exist")
        ins = ins[~ins_exists]
    del_exists = np.isin(dele, old_keys, assume_unique=True)
    if not del_exists.all():
        if strict:
            raise ValueError(
                f"{int((~del_exists).sum())} deleted edge(s) do not exist"
            )
        dele = dele[del_exists]

    deleted_old = np.isin(old_keys, dele, assume_unique=True)
    new_keys = np.union1d(old_keys[~deleted_old], ins)

    # Rebuild the CSR from the merged key set.
    u = (new_keys // (n + 1)).astype(np.int64)
    v = (new_keys % (n + 1)).astype(np.int32)
    rowptr = np.zeros(n + 1, dtype=np.int64)
    rowptr[1:] = np.cumsum(np.bincount(u, minlength=n + 1)[1:])
    new_graph = CSRGraph(n, rowptr, v, name=g.name)

    old2new = np.searchsorted(new_keys, old_keys)
    old2new[deleted_old] = -1
    new2old = np.searchsorted(old_keys, new_keys)
    inserted_new = np.isin(new_keys, ins, assume_unique=True)
    # Guard the searchsorted clip (an inserted key past every old key).
    new2old = np.minimum(new2old, g.nnz - 1) if g.nnz else np.zeros_like(new2old)
    new2old[inserted_new] = -1
    return GraphDelta(
        old_graph=g,
        new_graph=new_graph,
        old2new=old2new,
        new2old=new2old,
        inserted_new=inserted_new,
        deleted_old=deleted_old,
        num_inserts=int(ins.size),
        num_deletes=int(dele.size),
    )
