"""Incremental triangle cache: stop re-enumerating the graph per update.

``compute_frontier`` needs the *union* graph's (old ∪ inserts) triangle
list; before this cache every update paid a full O(nnz · max_degree)
enumeration of a graph that barely changed.  The union's triangles
partition exactly:

* triangles whose three edges all exist in the old graph — the cached
  list, maintained across commits;
* triangles containing at least one **inserted** edge — enumerable from
  the inserts alone: triangle {u, v, w} through inserted edge (u, v)
  means w is a common neighbor of u and v, so a per-insert sorted-
  neighborhood intersection (the same wedge idiom as the fine-grained
  support task) finds them all in O(Σ deg(u) + deg(v)) instead of
  O(nnz · max_degree).

On commit the deleted edges' triangles are dropped (a triangle survives
iff none of its edges was deleted), leaving exactly the new graph's
triangle list for the next update.  Triangles are stored as (T, 3)
composite *edge-key* triples — positional edge ids shift on every CSR
rebuild, keys don't.

The ``stream_enumerations{kind=full|incident}`` metric (recorded into
the active session's :mod:`repro.obs` registry; the deprecated
``ENUM_COUNTS`` alias mirrors the process-global aggregate) tracks full
vs. incident enumerations; ``stream_bench`` asserts a cached session
does exactly one full enumeration regardless of how many updates it
applies.
"""

from __future__ import annotations

import numpy as np

from ..graphs.csr import CSRGraph
from ..obs import current_registry
from .delta import GraphDelta, edge_keys
from .frontier import edge_triangles, union_graph

__all__ = ["TriangleCache", "triangles_incident"]


def _triple_keys(n: int, tri_verts: np.ndarray) -> np.ndarray:
    """(T, 3) sorted 1-based vertex triples -> (T, 3) edge-key triples."""
    stride = np.int64(n + 1)
    a, b, c = tri_verts[:, 0], tri_verts[:, 1], tri_verts[:, 2]
    return np.stack([a * stride + b, a * stride + c, b * stride + c], axis=1)


def triangles_incident(g: CSRGraph, keys: np.ndarray) -> np.ndarray:
    """Triangles of ``g`` containing >= 1 edge of ``keys``, as key triples.

    ``keys`` are 1-based upper-triangular composite keys (``edge_keys``
    convention).  Each key's triangles are the common neighbors of its
    endpoints in the symmetrized adjacency; a triangle touched by several
    listed edges is deduplicated.
    """
    current_registry().inc("stream_enumerations", kind="incident")
    keys = np.asarray(keys, np.int64)
    if keys.size == 0 or g.nnz == 0:
        return np.zeros((0, 3), np.int64)
    und = g.undirected_csr()
    rowptr, col = und.rowptr, und.colidx
    out: list[np.ndarray] = []
    for key in keys.tolist():
        u, v = divmod(int(key), g.n + 1)
        nu = col[rowptr[u - 1] : rowptr[u]]
        nv = col[rowptr[v - 1] : rowptr[v]]
        w = np.intersect1d(nu, nv)  # sorted unique common neighbors
        if not w.size:
            continue
        verts = np.sort(
            np.stack(
                [
                    np.full(w.size, u, np.int64),
                    np.full(w.size, v, np.int64),
                    w.astype(np.int64),
                ],
                axis=1,
            ),
            axis=1,
        )
        out.append(_triple_keys(g.n, verts))
    if not out:
        return np.zeros((0, 3), np.int64)
    return np.unique(np.concatenate(out, axis=0), axis=0)


class TriangleCache:
    """The current graph's triangle list, maintained across updates."""

    def __init__(self, g: CSRGraph, *, tri_keys: np.ndarray | None = None):
        self.graph = g
        if tri_keys is not None:
            # Checkpoint restore (repro.resilience.checkpoint): adopt the
            # serialized triangle list instead of re-enumerating — the
            # restored session keeps the "one full enumeration" contract.
            self.tri_keys = np.asarray(tri_keys, np.int64).reshape(-1, 3)
            return
        # The one full enumeration this cache ever does.
        tri = edge_triangles(g)
        self.tri_keys = (
            edge_keys(g)[tri] if tri.size else np.zeros((0, 3), np.int64)
        )

    @property
    def num_triangles(self) -> int:
        return int(self.tri_keys.shape[0])

    def union_triangles(self, delta: GraphDelta, union=None) -> np.ndarray:
        """(T, 3) key triples of the union graph (old ∪ inserts).

        Cached old-graph triangles plus the wedge-enumerated triangles
        through the batch's inserted edges — the exact set
        ``edge_triangles(union)`` would produce, without touching the
        rest of the graph.  ``union`` is an optional prebuilt
        ``frontier.union_graph(delta)`` pair (the session builds it once
        and shares it with ``compute_frontier``).
        """
        if delta.old_graph is not self.graph:
            raise RuntimeError(
                "triangle cache is out of sync: delta.old_graph is not the "
                "cached graph (commit() every update in order)"
            )
        ins_keys = edge_keys(delta.new_graph)[delta.inserted_new]
        if not ins_keys.size:
            return self.tri_keys
        g_union, _ukeys = union if union is not None else union_graph(delta)
        gained = triangles_incident(g_union, ins_keys)
        if not gained.size:
            return self.tri_keys
        return np.concatenate([self.tri_keys, gained], axis=0)

    def commit(self, delta: GraphDelta, union_tri_keys: np.ndarray) -> None:
        """Advance to ``delta.new_graph``: drop deleted edges' triangles."""
        del_keys = edge_keys(delta.old_graph)[delta.deleted_old]
        kept = union_tri_keys
        if union_tri_keys.size and del_keys.size:
            has_del = np.isin(union_tri_keys, del_keys).any(axis=1)
            kept = union_tri_keys[~has_del]
        self.tri_keys = kept
        self.graph = delta.new_graph
