"""Streaming K-truss: incremental truss maintenance under live edge updates.

Layers (bottom-up):

* :mod:`.delta`    — CSR delta application: an :class:`EdgeBatch` of
                     inserts/deletes becomes the mutated graph plus edge-id
                     correspondences (host numpy on sorted edge keys).
* :mod:`.frontier` — the affected-edge closure: the classic trussness
                     drift bounds (±1 per unit update) plus per-triangle
                     level tests bound exactly which edges an update can
                     re-rank; everything else provably keeps its trussness.
* :mod:`.session`  — :class:`StreamingTrussSession`: maintains the graph +
                     decomposition, freezes non-frontier edges at their
                     known trussness, and lowers each update onto ONE
                     :class:`repro.exec.PeelExecutor` dispatch via the
                     owning :class:`repro.service.TrussService` (so many
                     sessions' updates coalesce like ordinary requests).

Incremental results are bit-identical to from-scratch ``decompose()`` on
the mutated graph (hypothesis-tested in ``tests/test_stream.py``).
"""

from .delta import EdgeBatch, GraphDelta, apply_batch, edge_keys
from .frontier import FrontierResult, compute_frontier, edge_triangles
from .session import PendingUpdate, StreamingTrussSession, StreamUpdateResult

__all__ = [
    "EdgeBatch",
    "GraphDelta",
    "apply_batch",
    "edge_keys",
    "FrontierResult",
    "compute_frontier",
    "edge_triangles",
    "PendingUpdate",
    "StreamingTrussSession",
    "StreamUpdateResult",
]
