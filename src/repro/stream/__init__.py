"""Streaming K-truss: incremental truss maintenance under live edge updates.

Layers (bottom-up):

* :mod:`.delta`    — CSR delta application: an :class:`EdgeBatch` of
                     inserts/deletes becomes the mutated graph plus edge-id
                     correspondences (host numpy on sorted edge keys).
* :mod:`.frontier` — the affected-edge closure: the classic trussness
                     drift bounds (±1 per unit update) plus per-triangle
                     level tests bound exactly which edges an update can
                     re-rank; everything else provably keeps its trussness.
* :mod:`.tricache` — incremental triangle state: the union graph's
                     triangle list cached as edge-key triples, maintained
                     per update by enumerating only the wedges through
                     inserted edges (one full enumeration per session).
* :mod:`.session`  — :class:`StreamingTrussSession`: maintains the graph +
                     decomposition, freezes non-frontier edges at their
                     known trussness, and lowers each update onto ONE
                     :class:`repro.exec.PeelExecutor` dispatch as a
                     ``stream_update`` query on the owning
                     :class:`repro.api.Session` (so many sessions'
                     updates coalesce like ordinary queries).

Incremental results are bit-identical to from-scratch ``decompose()`` on
the mutated graph (hypothesis-tested in ``tests/test_stream.py``).
"""

from .delta import EdgeBatch, GraphDelta, apply_batch, edge_keys
from .frontier import ENUM_COUNTS, FrontierResult, compute_frontier, edge_triangles
from .session import PendingUpdate, StreamingTrussSession, StreamUpdateResult
from .tricache import TriangleCache, triangles_incident

__all__ = [
    "EdgeBatch",
    "GraphDelta",
    "apply_batch",
    "edge_keys",
    "ENUM_COUNTS",
    "FrontierResult",
    "compute_frontier",
    "edge_triangles",
    "PendingUpdate",
    "StreamingTrussSession",
    "StreamUpdateResult",
    "TriangleCache",
    "triangles_incident",
]
