"""StreamingTrussSession: truss maintenance for one mutating graph.

A session owns one evolving graph and its current truss decomposition.
Each :meth:`update` applies an :class:`~repro.stream.delta.EdgeBatch`,
computes the affected-edge frontier (``repro.stream.frontier``), and —
only if the frontier is non-empty — submits ONE frontier-bounded
``stream_update`` :class:`repro.api.TrussQuery` through the owning
:class:`repro.api.Session`: the frontier lanes start alive, every other
edge is frozen at its maintained trussness (the exec layer's frozen
lanes), so the update costs one device dispatch over the sub-problem
instead of a full decompose.  Updates whose frontier is
empty (e.g. deleting an edge in no triangle) cost zero dispatches.

The maintained state is exact, not approximate: the frontier closure is a
proven superset of every edge whose trussness can change, and the frozen
re-peel restricted to it reproduces from-scratch ``decompose()``
bit-for-bit (property-tested in ``tests/test_stream.py``).

Two maintained-state optimizations keep the host-side cost per update
sub-linear in the graph:

* the union-graph **triangle list is cached** across updates
  (:class:`repro.stream.tricache.TriangleCache`): only wedges through the
  batch's inserted edges are enumerated, instead of re-enumerating every
  triangle per update (``cache_triangles=False`` restores the old path);
* deltas themselves are sorted-key merges (``repro.stream.delta``).

Sessions ride the api session's queue, micro-batcher and compile cache,
so updates from many concurrent sessions — and ordinary declarative
queries — coalesce into shared dispatches.  Use the two-phase form for
that::

    pend_a = session_a.submit_update(batch_a)   # enqueue only
    pend_b = session_b.submit_update(batch_b)
    s.flush()                                   # one packed dispatch
    res_a, res_b = pend_a.result(), pend_b.result()

``update()`` is submit + result in one call.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import TYPE_CHECKING

import numpy as np

from ..errors import InvalidGraphError
from ..graphs.csr import CSRGraph
from ..obs import MetricsRegistry, use_registry, use_tracer
from .delta import EdgeBatch, GraphDelta, apply_batch
from .frontier import FrontierResult, compute_frontier, union_graph
from .tricache import TriangleCache

if TYPE_CHECKING:  # pragma: no cover
    from ..api.session import Session, TrussFuture

__all__ = ["StreamUpdateResult", "PendingUpdate", "StreamingTrussSession"]


@dataclasses.dataclass(frozen=True)
class StreamUpdateResult:
    """One committed update: the new decomposition + what it cost."""

    trussness: np.ndarray  # (new_nnz,) int32 — full, exact decomposition
    kmax: int
    frontier_size: int  # edges re-peeled
    frontier_frac: float  # frontier_size / new_nnz
    num_inserts: int
    num_deletes: int
    dispatches: int  # 0 (frontier empty) or 1
    num_edges: int  # new graph's edge count


class PendingUpdate:
    """Deferred half of :meth:`StreamingTrussSession.submit_update`.

    ``result()`` resolves the underlying api future (running the
    session's batch group if needed), merges the re-peeled frontier with
    the carried trussness, commits the session state, and returns the
    :class:`StreamUpdateResult`.
    """

    def __init__(
        self,
        session: "StreamingTrussSession",
        delta: GraphDelta,
        frontier: FrontierResult,
        carry: np.ndarray,
        future: "TrussFuture | None",
        union_tri_keys: np.ndarray | None = None,
    ):
        self._session = session
        self._delta = delta
        self._frontier = frontier
        self._carry = carry
        self._future = future
        self._union_tri_keys = union_tri_keys
        self._result: StreamUpdateResult | None = None

    def done(self) -> bool:
        return self._result is not None or (
            self._future is not None and self._future.done()
        )

    def result(self) -> StreamUpdateResult:
        if self._result is None:
            t_new = self._carry if self._future is None else self._future.result()
            self._result = self._session._commit(
                self._delta,
                self._frontier,
                np.asarray(t_new, np.int32),
                self._union_tri_keys,
            )
        return self._result


class StreamingTrussSession:
    """Incremental truss maintenance for one graph on a ``repro.api.Session``.

    Construct via :meth:`repro.api.Session.open_stream` (shared session —
    concurrent streams coalesce), the legacy ``TrussService.open_stream``
    adapter, or :meth:`for_graph` (private single-slot session).
    ``trussness`` seeds the state; omitted, the initial full decompose
    runs through the session's batched path.
    """

    def __init__(
        self,
        session,
        graph: CSRGraph,
        *,
        trussness: np.ndarray | None = None,
        cache_triangles: bool = True,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 1,
    ):
        # Accept a repro.api.Session or anything wrapping one under
        # ``.session`` (the legacy TrussService adapter).
        self.api: "Session" = getattr(session, "session", session)
        self.service = session  # legacy spelling; .stats() works on both
        # Per-stream metrics, chained to the owning api session's registry
        # (which chains to the process-global one): counts stay isolated
        # per stream while every aggregate view still sees them.
        self.metrics = MetricsRegistry(parent=self.api.obs.metrics)
        self.graph = graph
        if trussness is None:
            from ..api.query import TrussQuery  # lazy: no import cycle

            trussness = self.api.submit(TrussQuery.decompose(graph)).result().trussness
        trussness = np.asarray(trussness, np.int32)
        if trussness.shape[0] != graph.nnz:
            raise InvalidGraphError(
                f"trussness has {trussness.shape[0]} entries, graph has "
                f"{graph.nnz}",
                kind="trussness_len",
                graph=graph.name,
            )
        self.trussness = trussness
        self.cache_triangles = bool(cache_triangles)
        self._tri_cache: TriangleCache | None = None
        self._pending: PendingUpdate | None = None
        # Crash durability (repro.resilience.checkpoint): with a
        # checkpoint_dir, every `checkpoint_every`-th commit serializes
        # graph + trussness + triangle cache at the update boundary.
        if checkpoint_every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got {checkpoint_every}")
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = int(checkpoint_every)
        self._ckpt_seq = 0  # monotone auto-checkpoint sequence number
        self._updates_total = 0  # lifetime commits, surviving restore

    # Maintenance counters — views over this stream's metrics registry -- #
    @property
    def updates_applied(self) -> int:
        return int(self.metrics.value("stream_updates"))

    @property
    def updates_total(self) -> int:
        """Lifetime committed updates **across restores**.

        Unlike :attr:`updates_applied` (a per-instance metric that resets
        to 0 in a restored session), this is the durable sequence number a
        checkpoint's ``updates_applied`` meta records — the serving tier's
        exactly-once replay anchor."""
        return self._updates_total

    @property
    def update_dispatches(self) -> int:
        return int(self.metrics.value("stream_update_dispatches"))

    @property
    def edges_repeeled(self) -> int:
        return int(self.metrics.value("stream_edges_repeeled"))

    def _instrumented(self):
        """Scope where this stream's metrics + the api session's tracer
        are the context-current sinks (frontier/tricache record here)."""
        ctx = contextlib.ExitStack()
        ctx.enter_context(use_registry(self.metrics))
        ctx.enter_context(use_tracer(self.api.obs.tracer))
        return ctx

    # ------------------------------------------------------------------ #
    @classmethod
    def for_graph(cls, graph: CSRGraph, **session_kwargs) -> "StreamingTrussSession":
        """Standalone session over a private one-slot ``repro.api.Session``.

        Stream-level knobs (``trussness``, ``cache_triangles``,
        ``checkpoint_dir``, ``checkpoint_every``) are split off; the rest
        configures the private api session.
        """
        from ..api.session import Session

        stream_kwargs = {
            k: session_kwargs.pop(k)
            for k in (
                "trussness",
                "cache_triangles",
                "checkpoint_dir",
                "checkpoint_every",
            )
            if k in session_kwargs
        }
        session_kwargs.setdefault("max_batch", 1)
        return cls(Session(**session_kwargs), graph, **stream_kwargs)

    @property
    def kmax(self) -> int:
        return int(self.trussness.max(initial=0)) if self.graph.nnz else 0

    # ------------------------------------------------------------------ #
    def submit_update(self, batch: EdgeBatch, *, strict: bool = True) -> PendingUpdate:
        """Apply ``batch``, enqueue the frontier re-peel, return a handle.

        The graph/trussness state commits when the handle resolves; one
        update may be in flight per session (deltas are relative to the
        committed graph), so concurrency comes from many sessions sharing
        one api session, not from pipelining a single session.
        """
        if self._pending is not None and self._pending._result is None:
            raise RuntimeError(
                "session already has an in-flight update; resolve it first"
            )
        tracer = self.api.obs.tracer
        with self._instrumented():
            with tracer.span(
                "stream.delta", inserts=len(batch.inserts), deletes=len(batch.deletes)
            ):
                delta = apply_batch(self.graph, batch, strict=strict)

            # Incremental triangle state: reuse the cached list, enumerating
            # only the wedges the batch's inserts touch.  The union graph is
            # built once and shared between the cache and the frontier.
            union_tri_keys = union_pair = None
            if self.cache_triangles:
                with tracer.span("stream.triangles") as span:
                    if self._tri_cache is None:
                        self._tri_cache = TriangleCache(self.graph)
                    union_pair = union_graph(delta)
                    union_tri_keys = self._tri_cache.union_triangles(
                        delta, union=union_pair
                    )
                    span.attrs["triangles"] = int(union_tri_keys.shape[0])
            with tracer.span("stream.frontier") as span:
                fr = compute_frontier(
                    self.trussness, delta, tri_keys=union_tri_keys, union=union_pair
                )
                span.attrs["frontier"] = fr.size
            self.metrics.observe(
                "stream_frontier_frac",
                fr.frac,
                buckets=(0.01, 0.05, 0.1, 0.25, 0.5, 1.0),
            )
        g_new = delta.new_graph

        # Trussness carried over from the committed state (inserted edges
        # start at the vacuous floor 2 and are always in the frontier).
        carry = np.full(g_new.nnz, 2, np.int32)
        shared = delta.new2old >= 0
        carry[shared] = self.trussness[delta.new2old[shared]]

        future = None
        if fr.size:
            from ..api.query import TrussQuery  # lazy: no import cycle

            future = self.api.submit(
                TrussQuery.stream_update(
                    g_new,
                    frontier=fr.frontier,
                    frozen_truss=np.where(fr.frontier, 0, carry).astype(np.int32),
                )
            )
        self._pending = PendingUpdate(self, delta, fr, carry, future, union_tri_keys)
        return self._pending

    def update(self, batch: EdgeBatch, *, strict: bool = True) -> StreamUpdateResult:
        """Submit + resolve in one call (single-session convenience)."""
        return self.submit_update(batch, strict=strict).result()

    # ------------------------------------------------------------------ #
    def _commit(
        self,
        delta: GraphDelta,
        fr: FrontierResult,
        t_new: np.ndarray,
        union_tri_keys: np.ndarray | None,
    ) -> StreamUpdateResult:
        self.graph = delta.new_graph
        self.trussness = t_new
        if self._tri_cache is not None and union_tri_keys is not None:
            self._tri_cache.commit(delta, union_tri_keys)
        self._pending = None
        dispatches = 1 if fr.size else 0
        self._updates_total += 1
        self.metrics.inc("stream_updates")
        self.metrics.inc("stream_update_dispatches", dispatches)
        self.metrics.inc("stream_edges_repeeled", fr.size)
        if (
            self.checkpoint_dir is not None
            and self._updates_total % self.checkpoint_every == 0
        ):
            self._auto_checkpoint()
        return StreamUpdateResult(
            trussness=t_new,
            kmax=self.kmax,
            frontier_size=fr.size,
            frontier_frac=fr.frac,
            num_inserts=delta.num_inserts,
            num_deletes=delta.num_deletes,
            dispatches=dispatches,
            num_edges=delta.new_graph.nnz,
        )

    # ------------------------------------------------------------------ #
    # Crash durability (repro.resilience.checkpoint)
    # ------------------------------------------------------------------ #
    @property
    def checkpoints_written(self) -> int:
        return int(self.metrics.value("stream_checkpoints"))

    def checkpoint(self, path: str) -> str:
        """Serialize the committed state (CSR + trussness + triangle cache)
        to ``path`` atomically; returns ``path``.  Restoring it
        (:meth:`restore`) continues bit-identically to this session."""
        from ..resilience.checkpoint import save_checkpoint  # lazy: no cycle

        if self._pending is not None and self._pending._result is None:
            raise RuntimeError(
                "cannot checkpoint with an in-flight update; resolve it first"
            )
        out = save_checkpoint(
            path,
            graph=self.graph,
            trussness=self.trussness,
            tri_keys=self._tri_cache.tri_keys if self._tri_cache else None,
            updates_applied=self.updates_total,
        )
        self.metrics.inc("stream_checkpoints")
        return out

    def _auto_checkpoint(self) -> None:
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        self._ckpt_seq += 1
        path = os.path.join(self.checkpoint_dir, f"ckpt-{self._ckpt_seq:08d}.npz")
        self.checkpoint(path)
        # Keep the newest two: a crash mid-write of checkpoint N still
        # leaves N-1 intact (the write itself is atomic, this is belt
        # and suspenders for partial-directory states).
        kept = sorted(
            f
            for f in os.listdir(self.checkpoint_dir)
            if f.startswith("ckpt-") and f.endswith(".npz")
        )
        for stale in kept[:-2]:
            with contextlib.suppress(OSError):
                os.unlink(os.path.join(self.checkpoint_dir, stale))

    @classmethod
    def restore(cls, path: str, session=None, **session_kwargs):
        """Rebuild a session from a :meth:`checkpoint` file — no decompose
        dispatch, no triangle re-enumeration (``resilience.restore_session``)."""
        from ..resilience.checkpoint import restore_session  # lazy: no cycle

        return restore_session(path, session=session, **session_kwargs)

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return {
            "updates_applied": self.updates_applied,
            "update_dispatches": self.update_dispatches,
            "edges_repeeled": self.edges_repeeled,
            "edges": self.graph.nnz,
            "kmax": self.kmax,
            "cached_triangles": (
                self._tri_cache.num_triangles if self._tri_cache else 0
            ),
            "checkpoints_written": self.checkpoints_written,
        }
