"""StreamingTrussSession: truss maintenance for one mutating graph.

A session owns one evolving graph and its current truss decomposition.
Each :meth:`update` applies an :class:`~repro.stream.delta.EdgeBatch`,
computes the affected-edge frontier (``repro.stream.frontier``), and —
only if the frontier is non-empty — submits ONE frontier-bounded re-peel
through the owning :class:`~repro.service.TrussService`: the frontier
lanes start alive, every other edge is frozen at its maintained trussness
(``repro.exec.build_peel``'s frozen lanes), so the update costs one device
dispatch over the sub-problem instead of a full decompose.  Updates whose
frontier is empty (e.g. deleting an edge in no triangle) cost zero
dispatches.

The maintained state is exact, not approximate: the frontier closure is a
proven superset of every edge whose trussness can change, and the frozen
re-peel restricted to it reproduces from-scratch ``decompose()``
bit-for-bit (property-tested in ``tests/test_stream.py``).

Sessions ride the service's bucket queue, micro-batcher and compile
cache, so updates from many concurrent sessions — and ordinary
ktruss/kmax/decompose requests — coalesce into shared dispatches.  Use
the two-phase form for that::

    pend_a = session_a.submit_update(batch_a)   # enqueue only
    pend_b = session_b.submit_update(batch_b)
    svc.flush()                                 # one packed dispatch
    res_a, res_b = pend_a.result(), pend_b.result()

``update()`` is submit + result in one call.  Session state (graph +
trussness) is host numpy: the frozen state rides into the dispatch with
the packed batch, and the CSR delta/frontier themselves are host-side
work (moving them onto the device is the ROADMAP async-pipeline item).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np

from ..graphs.csr import CSRGraph
from .delta import EdgeBatch, GraphDelta, apply_batch
from .frontier import FrontierResult, compute_frontier

if TYPE_CHECKING:  # pragma: no cover
    from ..service.service import TrussFuture, TrussService

__all__ = ["StreamUpdateResult", "PendingUpdate", "StreamingTrussSession"]


@dataclasses.dataclass(frozen=True)
class StreamUpdateResult:
    """One committed update: the new decomposition + what it cost."""

    trussness: np.ndarray  # (new_nnz,) int32 — full, exact decomposition
    kmax: int
    frontier_size: int  # edges re-peeled
    frontier_frac: float  # frontier_size / new_nnz
    num_inserts: int
    num_deletes: int
    dispatches: int  # 0 (frontier empty) or 1
    num_edges: int  # new graph's edge count


class PendingUpdate:
    """Deferred half of :meth:`StreamingTrussSession.submit_update`.

    ``result()`` resolves the underlying service future (running the
    session's bucket if needed), merges the re-peeled frontier with the
    carried trussness, commits the session state, and returns the
    :class:`StreamUpdateResult`.
    """

    def __init__(
        self,
        session: "StreamingTrussSession",
        delta: GraphDelta,
        frontier: FrontierResult,
        carry: np.ndarray,
        future: "TrussFuture | None",
    ):
        self._session = session
        self._delta = delta
        self._frontier = frontier
        self._carry = carry
        self._future = future
        self._result: StreamUpdateResult | None = None

    def done(self) -> bool:
        return self._result is not None or (
            self._future is not None and self._future.done()
        )

    def result(self) -> StreamUpdateResult:
        if self._result is None:
            t_new = self._carry if self._future is None else self._future.result()
            self._result = self._session._commit(
                self._delta, self._frontier, np.asarray(t_new, np.int32)
            )
        return self._result


class StreamingTrussSession:
    """Incremental truss maintenance for one graph on a ``TrussService``.

    Construct via :meth:`TrussService.open_stream` (shared service —
    concurrent sessions coalesce) or :meth:`for_graph` (private
    single-slot service).  ``trussness`` seeds the session; omitted, the
    initial full decompose runs through the service's batched path.
    """

    def __init__(
        self,
        service: "TrussService",
        graph: CSRGraph,
        *,
        trussness: np.ndarray | None = None,
    ):
        self.service = service
        self.graph = graph
        if trussness is None:
            trussness = service.submit_decompose(graph).result().trussness
        trussness = np.asarray(trussness, np.int32)
        if trussness.shape[0] != graph.nnz:
            raise ValueError(
                f"trussness has {trussness.shape[0]} entries, graph has {graph.nnz}"
            )
        self.trussness = trussness
        self._pending: PendingUpdate | None = None
        self.updates_applied = 0
        self.update_dispatches = 0
        self.edges_repeeled = 0

    # ------------------------------------------------------------------ #
    @classmethod
    def for_graph(cls, graph: CSRGraph, **service_kwargs) -> "StreamingTrussSession":
        """Standalone session over a private one-slot service."""
        from ..service.service import TrussService

        service_kwargs.setdefault("max_batch", 1)
        return cls(TrussService(**service_kwargs), graph)

    @property
    def kmax(self) -> int:
        return int(self.trussness.max(initial=0)) if self.graph.nnz else 0

    # ------------------------------------------------------------------ #
    def submit_update(self, batch: EdgeBatch, *, strict: bool = True) -> PendingUpdate:
        """Apply ``batch``, enqueue the frontier re-peel, return a handle.

        The graph/trussness state commits when the handle resolves; one
        update may be in flight per session (deltas are relative to the
        committed graph), so concurrency comes from many sessions sharing
        one service, not from pipelining a single session.
        """
        if self._pending is not None and self._pending._result is None:
            raise RuntimeError(
                "session already has an in-flight update; resolve it first"
            )
        delta = apply_batch(self.graph, batch, strict=strict)
        fr = compute_frontier(self.trussness, delta)
        g_new = delta.new_graph

        # Trussness carried over from the committed state (inserted edges
        # start at the vacuous floor 2 and are always in the frontier).
        carry = np.full(g_new.nnz, 2, np.int32)
        shared = delta.new2old >= 0
        carry[shared] = self.trussness[delta.new2old[shared]]

        future = None
        if fr.size:
            future = self.service.submit_stream(
                g_new,
                frontier=fr.frontier,
                frozen_truss=np.where(fr.frontier, 0, carry).astype(np.int32),
            )
        self._pending = PendingUpdate(self, delta, fr, carry, future)
        return self._pending

    def update(self, batch: EdgeBatch, *, strict: bool = True) -> StreamUpdateResult:
        """Submit + resolve in one call (single-session convenience)."""
        return self.submit_update(batch, strict=strict).result()

    # ------------------------------------------------------------------ #
    def _commit(
        self, delta: GraphDelta, fr: FrontierResult, t_new: np.ndarray
    ) -> StreamUpdateResult:
        self.graph = delta.new_graph
        self.trussness = t_new
        self._pending = None
        self.updates_applied += 1
        dispatches = 1 if fr.size else 0
        self.update_dispatches += dispatches
        self.edges_repeeled += fr.size
        return StreamUpdateResult(
            trussness=t_new,
            kmax=self.kmax,
            frontier_size=fr.size,
            frontier_frac=fr.frac,
            num_inserts=delta.num_inserts,
            num_deletes=delta.num_deletes,
            dispatches=dispatches,
            num_edges=delta.new_graph.nnz,
        )

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return {
            "updates_applied": self.updates_applied,
            "update_dispatches": self.update_dispatches,
            "edges_repeeled": self.edges_repeeled,
            "edges": self.graph.nnz,
            "kmax": self.kmax,
        }
