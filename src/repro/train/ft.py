"""Fault-tolerance orchestration: watchdog, retries, straggler accounting.

On real multi-host deployments the failure modes are (a) hard node loss —
handled by checkpoint/restart + elastic remesh (``repro.distributed.
elastic`` + ``checkpoint.restore``), and (b) soft stragglers — steps that
complete but late.  This module provides the host-side instrumentation for
both; on the single-host container the mechanisms are exercised by tests
via injected faults (documented simulation, DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

__all__ = ["StepWatchdog", "run_with_retries", "StragglerStats"]


@dataclasses.dataclass
class StragglerStats:
    steps: int = 0
    stragglers: int = 0
    retries: int = 0
    failures: int = 0
    worst_ratio: float = 1.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class StepWatchdog:
    """EMA-based step-time watchdog.

    A step slower than ``threshold ×`` the EMA is flagged as a straggler.
    In a real deployment the flag triggers hot-spare substitution /
    re-execution on the replica group; here it feeds StragglerStats and an
    optional callback (tests inject sleeps to verify detection).
    """

    def __init__(self, threshold: float = 3.0, ema: float = 0.9, on_straggler=None):
        self.threshold = threshold
        self.ema_coef = ema
        self.ema: float | None = None
        self.stats = StragglerStats()
        self.on_straggler = on_straggler

    def observe(self, dt: float) -> bool:
        self.stats.steps += 1
        is_straggler = False
        if self.ema is not None and dt > self.threshold * self.ema:
            self.stats.stragglers += 1
            self.stats.worst_ratio = max(self.stats.worst_ratio, dt / self.ema)
            is_straggler = True
            if self.on_straggler:
                self.on_straggler(dt, self.ema)
        # Straggler steps don't poison the EMA.
        if self.ema is None:
            self.ema = dt
        elif not is_straggler:
            self.ema = self.ema_coef * self.ema + (1 - self.ema_coef) * dt
        return is_straggler

    def timed(self, fn: Callable, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        self.observe(time.perf_counter() - t0)
        return out


def run_with_retries(
    fn: Callable,
    *args,
    retries: int = 2,
    stats: StragglerStats | None = None,
    recover: Callable | None = None,
):
    """Execute ``fn``; on exception, optionally run ``recover`` and retry.

    This is the step-level restart path: ``recover`` typically restores the
    latest checkpoint and/or re-derives the mesh (elastic downscale).
    """
    last = None
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except Exception as e:  # noqa: BLE001 — deliberate containment
            last = e
            if stats is not None:
                stats.retries += 1
            if recover is not None:
                args = recover(attempt, e, *args) or args
    if stats is not None:
        stats.failures += 1
    raise last
