"""Fused unembedding + cross-entropy, chunked over the sequence.

Materializing train logits (B, S, V) in fp32 is the single largest
activation at 1T scale (kimi: 1 seq × 4096 × 163840 × 4B ≈ 2.7 GB per
device *per microbatch*).  This computes the unembed matmul and the CE
reduction together in sequence chunks under ``jax.checkpoint``, so peak
logit memory is (B, chunk, V) and the backward recomputes each chunk's
logits instead of storing them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fused_unembed_xent"]


def fused_unembed_xent(
    feats: jax.Array,  # (B, S, D) features aligned with labels
    labels: jax.Array,  # (B, S) int32; negative = masked
    unembed: jax.Array,  # (V, D) embedding (tied) or (D, V) head kernel
    *,
    transposed: bool,  # True when unembed is (V, D)
    softcap: float | None = None,
    z_loss: float = 1e-4,
    chunk: int = 512,
) -> tuple[jax.Array, dict]:
    b, s, d = feats.shape
    chunk = min(chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    pad = n_chunks * chunk - s
    if pad:
        feats = jnp.pad(feats, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)

    fc = jnp.moveaxis(feats.reshape(b, n_chunks, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n_chunks, chunk), 1, 0)

    def chunk_stats(f, lab):
        logits = (
            jnp.einsum("bcd,vd->bcv", f, unembed)
            if transposed
            else jnp.einsum("bcd,dv->bcv", f, unembed)
        ).astype(jnp.float32)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = (lab >= 0).astype(jnp.float32)
        safe = jnp.maximum(lab, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        return (
            jnp.sum((lse - gold) * mask),
            jnp.sum(jnp.square(lse) * mask),
            jnp.sum(mask),
        )

    body = jax.checkpoint(chunk_stats)

    def scan_body(carry, xs):
        nll, zsq, cnt = carry
        f, lab = xs
        a, bz, c = body(f, lab)
        return (nll + a, zsq + bz, cnt + c), None

    (nll, zsq, cnt), _ = jax.lax.scan(
        scan_body,
        (jnp.float32(0), jnp.float32(0), jnp.float32(0)),
        (fc, lc),
    )
    denom = jnp.maximum(cnt, 1.0)
    ce = nll / denom
    zl = zsq / denom * z_loss
    metrics = {
        "ce_loss": ce,
        "z_loss": zl,
        "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0)),
        "tokens": cnt,
    }
    return ce + zl, metrics
