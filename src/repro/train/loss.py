"""Cross-entropy + z-loss for LM training (fp32 logits path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["softmax_xent"]


def softmax_xent(
    logits: jax.Array,  # (B, S, V) float32
    labels: jax.Array,  # (B, S) int32, ignore_index < 0 masked out
    *,
    z_loss: float = 1e-4,
) -> tuple[jax.Array, dict]:
    mask = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll) / denom
    zl = jnp.sum(jnp.square(lse) * mask) / denom * z_loss
    metrics = {
        "ce_loss": ce,
        "z_loss": zl,
        "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0)),
        "tokens": jnp.sum(mask),
    }
    return ce + zl, metrics
