"""Train-step factory: loss → grads (with microbatch accumulation) → AdamW.

The returned ``train_step(state, batch)`` is a single jit-able function —
the object the multi-pod dry-run lowers.  Gradient accumulation runs as a
``lax.scan`` over microbatches (fp32 grad accumulators), which composes
with the scan-over-layers remat so peak activation memory is
O(microbatch × one layer group).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models.blocks import AUX_KEYS
from ..models.registry import Model
from .fused_loss import fused_unembed_xent
from .loss import softmax_xent
from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm

__all__ = ["TrainStepConfig", "init_train_state", "make_train_step"]


class TrainStepConfig:
    def __init__(
        self,
        *,
        optimizer: AdamWConfig | None = None,
        schedule_fn: Callable | None = None,
        grad_accum: int = 1,
        clip_norm: float = 1.0,
        z_loss: float = 1e-4,
        fused_loss: bool = True,
        loss_chunk: int = 512,
        accum_dtype: str = "float32",
    ):
        self.optimizer = optimizer or AdamWConfig()
        self.schedule_fn = schedule_fn or (lambda step: jnp.float32(3e-4))
        self.grad_accum = grad_accum
        self.clip_norm = clip_norm
        self.z_loss = z_loss
        self.fused_loss = fused_loss
        self.loss_chunk = loss_chunk
        self.accum_dtype = accum_dtype


def init_train_state(model: Model, key: jax.Array, tcfg: TrainStepConfig) -> dict:
    params = model.init(key)
    return {
        "params": params,
        "opt": adamw_init(params, tcfg.optimizer),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(model: Model, tcfg: TrainStepConfig):
    def loss_fn(params, batch):
        if tcfg.fused_loss:
            feats, unembed, transposed, aux = model.train_features(params, batch)
            loss, metrics = fused_unembed_xent(
                feats,
                batch["labels"],
                unembed,
                transposed=transposed,
                softcap=model.cfg.final_logit_softcap,
                z_loss=tcfg.z_loss,
                chunk=tcfg.loss_chunk,
            )
        else:
            logits, aux = model.train_logits(params, batch)
            loss, metrics = softmax_xent(
                logits, batch["labels"], z_loss=tcfg.z_loss
            )
        for k in AUX_KEYS:
            if k.endswith("loss"):
                loss = loss + aux[k]
            metrics[k] = aux[k]
        metrics["loss"] = loss
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        if tcfg.grad_accum <= 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        a = tcfg.grad_accum

        def split(x):
            b = x.shape[0]
            return x.reshape(a, b // a, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        acc_dt = jnp.dtype(tcfg.accum_dtype)

        def body(carry, mb):
            acc, _ = carry
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda s, g: s + g.astype(acc_dt) / a, acc, grads
            )
            return (acc, metrics), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        (grads, metrics), _ = jax.lax.scan(
            body, (zeros, _zero_metrics()), micro
        )
        return grads, metrics

    def train_step(state: dict, batch: dict):
        grads, metrics = accumulate(state["params"], batch)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, tcfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        lr = tcfg.schedule_fn(state["step"])
        new_params, new_opt = adamw_update(
            grads, state["opt"], state["params"], lr, tcfg.optimizer
        )
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return train_step


def _zero_metrics() -> dict:
    base = {
        "ce_loss": jnp.float32(0),
        "z_loss": jnp.float32(0),
        "ppl_proxy": jnp.float32(0),
        "tokens": jnp.float32(0),
        "loss": jnp.float32(0),
    }
    for k in AUX_KEYS:
        base[k] = jnp.float32(0)
    return base
