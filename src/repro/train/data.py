"""Deterministic data pipeline: synthetic LM streams + binary token shards.

Determinism contract (fault tolerance): ``batch_at(step)`` is a pure
function of (seed, step) — resuming from a checkpoint at step k replays
exactly the batches k, k+1, … with no iterator state to persist.  The
file-backed store memory-maps binary token shards and indexes them with the
same step arithmetic.
"""

from __future__ import annotations

import os

import numpy as np

from ..models.config import ModelConfig

__all__ = ["SyntheticLM", "TokenShardStore", "batch_for"]


class SyntheticLM:
    """Markov-flavored synthetic token stream (not iid — loss can drop)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # A learnable structure: tokens follow t_{i+1} = (a·t_i + b + noise) % V
        # with (a, b) fixed per stream so the mapping is stationary.
        a = 31
        b = int(np.random.default_rng(self.seed).integers(0, self.vocab))
        t0 = rng.integers(0, self.vocab, size=(self.batch, 1))
        toks = [t0]
        for _ in range(self.seq):
            noise = rng.integers(0, 7, size=(self.batch, 1))
            toks.append((a * toks[-1] + b + noise) % self.vocab)
        seq = np.concatenate(toks, axis=1)  # (B, S+1)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }


class TokenShardStore:
    """Flat binary uint32 token shards with step-indexed batch reads."""

    def __init__(self, path: str):
        self.path = path

    @staticmethod
    def write(path: str, tokens: np.ndarray) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tokens.astype(np.uint32).tofile(path)

    def batch_at(self, step: int, batch: int, seq: int) -> dict:
        data = np.memmap(self.path, dtype=np.uint32, mode="r")
        need = batch * (seq + 1)
        n_slots = max(1, (data.shape[0] - 1) // need)
        off = (step % n_slots) * need
        chunk = np.asarray(data[off : off + need])
        if chunk.shape[0] < need:  # wrap
            chunk = np.concatenate([chunk, data[: need - chunk.shape[0]]])
        seqs = chunk.reshape(batch, seq + 1).astype(np.int32)
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


def batch_for(
    cfg: ModelConfig, batch: int, seq: int, step: int, seed: int = 0
) -> dict:
    """Family-correct synthetic train batch (embeds stubs included)."""
    rng = np.random.default_rng((seed << 21) ^ step)
    if cfg.is_encdec:
        se = seq // 2
        st = seq - se
        lm = SyntheticLM(cfg.vocab_size, batch, st, seed).batch_at(step)
        return {
            "src_embeds": rng.normal(0, 0.5, (batch, se, cfg.d_model)).astype(
                np.float32
            ),
            **lm,
        }
    if cfg.family == "vlm":
        f = cfg.frontend_len
        lm = SyntheticLM(cfg.vocab_size, batch, seq - f, seed).batch_at(step)
        return {
            "embeds": rng.normal(0, 0.5, (batch, f, cfg.d_model)).astype(
                np.float32
            ),
            **lm,
        }
    return SyntheticLM(cfg.vocab_size, batch, seq, seed).batch_at(step)
