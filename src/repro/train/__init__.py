"""Training substrate: optimizer, loss, train step, checkpoint, data, FT."""

from .checkpoint import Checkpointer, latest_step, restore_checkpoint, save_checkpoint
from .data import SyntheticLM, TokenShardStore, batch_for
from .ft import StepWatchdog, StragglerStats, run_with_retries
from .loss import softmax_xent
from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedule import constant, warmup_cosine
from .train_step import TrainStepConfig, init_train_state, make_train_step

__all__ = [
    "Checkpointer",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
    "SyntheticLM",
    "TokenShardStore",
    "batch_for",
    "StepWatchdog",
    "StragglerStats",
    "run_with_retries",
    "softmax_xent",
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "constant",
    "warmup_cosine",
    "TrainStepConfig",
    "init_train_state",
    "make_train_step",
]
